"""Prefill memoization — KV-bearing memo entries (AttnCache; DESIGN.md §2.13).

AttMemo memoizes the attention-probability matrix; its sequel AttnCache
(arXiv:2510.25979, PAPERS.md) memoizes LLM *prefill*, where a hit must
hand back more than the attention output: autoregressive decode needs the
layer's K/V cache, so the memo entry becomes "APM + per-layer K/V".

``PrefillCodec`` extends the PR 3 codec-part arena machinery instead of
inventing a second store: it wraps any base APM codec and APPENDS the KV
parts after the base parts, so every consumer of the parts tuple — the
host/device arenas, delta sync, the capacity tier's mmap files + WAL,
save format 3, per-row CRC32s, ``put_parts`` promotion, the sharded
arenas — carries KV without modification. Order matters: the fused memo
kernel indexes ``db_parts[0]``/``db_parts[1]`` positionally (int8
codes/scales), which is why KV parts must come AFTER the base parts;
``decode``/``decode_rows`` keep the base codec's contract (APM out) by
slicing the prefix, and ``decode_kv_rows`` is the new device-side read.

KV layout per entry: one stacked plane ``(2, S, D)`` — plane 0 is K,
plane 1 is V, ``S`` the arena (calibration) sequence length, ``D =
n_kv_heads * head_dim`` flattened. K is stored POST-RoPE (exactly what
``gqa_prefill_cache`` caches): prefill positions are absolute from 0, so
the rotation is identical for every prompt of the same length and the
stored K drops into the decode cache as-is. Rows past an entry's true
length are zero — the same convention as the exact prefill path, which
zero-pads the cache to ``cache_len``.

KV compression mirrors the APM codecs: ``f16`` identity, ``int8``
per-row symmetric quant (rows are the ``D``-vectors of one position ×
plane), and ``lowrank`` an SVD factorization of each ``(S, D)`` plane
with int8-quantized factors. ``kv_codec="auto"`` matches the base codec
(f16 base → f16 KV, compressed base → int8 KV — low-rank KV is opt-in
because K/V spectra decay slower than softmax rows).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.codec import ApmCodec, PartSpec, _quantize_rows


def _kv_mode(base_name: str, kv_codec: str,
             kv_rank: Optional[int]) -> str:
    """Resolve the KV storage mode. An explicit rank opts into lowrank."""
    if kv_codec == "auto":
        if kv_rank is not None:
            return "lowrank"
        return "f16" if base_name == "f16" else "int8"
    return kv_codec


class PrefillCodec(ApmCodec):
    """Base APM codec + appended K/V parts (one memo entry serves both
    the memoized attention AND the decode cache)."""

    def __init__(self, base: ApmCodec, kv_dim: int, *,
                 kv_codec: str = "auto", kv_rank: Optional[int] = None):
        super().__init__(base.apm_shape)
        self.base = base
        self.kv_dim = int(kv_dim)
        self.seq_len = int(self.apm_shape[-1])
        self.kv_mode = _kv_mode(base.name, kv_codec, kv_rank)
        if self.kv_mode not in ("f16", "int8", "lowrank"):
            raise ValueError(f"unknown kv codec {self.kv_mode!r} "
                             "(f16 | int8 | lowrank)")
        lim = min(self.seq_len, self.kv_dim)
        self.kv_rank = (min(lim, max(1, int(kv_rank))) if kv_rank
                        else min(lim, max(4, lim // 8)))
        self.n_base_parts = len(base.parts)

    # the wrapped codec's name is THE codec name: the fused kernel path
    # branches on it positionally (parts[0]/parts[1]), which stays valid
    # because KV parts are appended after the base parts
    @property
    def name(self):  # type: ignore[override]
        return self.base.name

    @property
    def key(self):
        kv = (self.kv_mode, self.kv_dim,
              self.kv_rank if self.kv_mode == "lowrank" else None)
        return ("prefill", self.base.key, kv)

    @property
    def parts(self) -> Tuple[PartSpec, ...]:
        s, d = self.seq_len, self.kv_dim
        if self.kv_mode == "f16":
            kv = (PartSpec("kv", (2, s, d), np.dtype(np.float16)),)
        elif self.kv_mode == "int8":
            kv = (PartSpec("kv", (2, s, d), np.dtype(np.int8)),
                  PartSpec("kv_scale", (2, s), np.dtype(np.float16)))
        else:
            r = self.kv_rank
            kv = (PartSpec("kv_u", (2, s, r), np.dtype(np.int8)),
                  PartSpec("kv_us", (2, s), np.dtype(np.float16)),
                  PartSpec("kv_v", (2, r, d), np.dtype(np.int8)),
                  PartSpec("kv_vs", (2, r), np.dtype(np.float16)))
        return self.base.parts + kv

    # ------------------------------------------------------------- encode
    def encode(self, apms, aux=None):
        """``aux``: the stacked KV plane (B, 2, S, D) f32/f16 — K post-
        RoPE in plane 0, V in plane 1, zero past each entry's true
        length. ``None`` falls back to zero KV (legacy callers that
        admit APM-only entries — their decode caches replay as zeros, so
        the engine gates prefill capture to KV-bearing batches)."""
        base_parts = self.base.encode(apms)
        b = np.asarray(apms).shape[0]
        if aux is None:
            kv = np.zeros((b, 2, self.seq_len, self.kv_dim), np.float32)
        else:
            kv = np.asarray(aux, np.float32)
            if kv.shape != (b, 2, self.seq_len, self.kv_dim):
                raise ValueError(
                    f"kv aux shape {kv.shape} != "
                    f"{(b, 2, self.seq_len, self.kv_dim)}")
        if self.kv_mode == "f16":
            kv_parts = (kv.astype(np.float16),)
        elif self.kv_mode == "int8":
            kv_parts = _quantize_rows(kv)
        else:
            r = self.kv_rank
            u, s, vt = np.linalg.svd(kv, full_matrices=False)
            root = np.sqrt(s[..., :r])
            uf = u[..., :, :r] * root[..., None, :]      # (B, 2, S, r)
            vf = vt[..., :r, :] * root[..., :, None]     # (B, 2, r, D)
            uq, us = _quantize_rows(uf)
            vq, vs = _quantize_rows(vf)
            kv_parts = (uq, us, vq, vs)
        return base_parts + kv_parts

    # ------------------------------------------------------------- decode
    def decode(self, parts):
        """Host decode keeps the base contract: parts → f16 APMs. The KV
        suffix is ignored here; ``decode_kv`` is the explicit read."""
        return self.base.decode(tuple(parts)[: self.n_base_parts])

    def decode_rows(self, parts):
        return self.base.decode_rows(tuple(parts)[: self.n_base_parts])

    def _kv_parts(self, parts):
        kv = tuple(parts)[self.n_base_parts:]
        if not kv:
            raise ValueError("parts tuple carries no KV suffix")
        return kv

    def decode_kv(self, parts) -> np.ndarray:
        """Host KV decode: numpy parts → (B, 2, S, D) f16 planes."""
        kv = self._kv_parts(parts)
        if self.kv_mode == "f16":
            return np.asarray(kv[0])
        if self.kv_mode == "int8":
            codes, scales = kv
            return (np.asarray(codes, np.float32)
                    * np.asarray(scales, np.float32)[..., None]
                    ).astype(np.float16)
        uq, us, vq, vs = kv
        u = np.asarray(uq, np.float32) * np.asarray(us, np.float32)[..., None]
        v = np.asarray(vq, np.float32) * np.asarray(vs, np.float32)[..., None]
        return np.einsum("...sr,...rd->...sd", u, v).astype(np.float16)

    def decode_kv_rows(self, parts) -> jnp.ndarray:
        """Device KV decode, traceable: jnp parts → (B, 2, S, D) f16 —
        mirrors ``decode_kv`` op-for-op (the same host/device parity
        contract as the APM codecs)."""
        kv = self._kv_parts(parts)
        if self.kv_mode == "f16":
            return kv[0]
        if self.kv_mode == "int8":
            codes, scales = kv
            return (codes.astype(jnp.float32)
                    * scales.astype(jnp.float32)[..., None]
                    ).astype(jnp.float16)
        uq, us, vq, vs = kv
        u = uq.astype(jnp.float32) * us.astype(jnp.float32)[..., None]
        v = vq.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]
        return jnp.einsum("...sr,...rd->...sd", u, v).astype(jnp.float16)


def stack_kv(k: np.ndarray, v: np.ndarray) -> np.ndarray:
    """(B, S, Hkv, dh) K and V → the stored (B, 2, S, Hkv*dh) plane."""
    k = np.asarray(k)
    b, s = k.shape[0], k.shape[1]
    return np.stack([k.reshape(b, s, -1),
                     np.asarray(v).reshape(b, s, -1)], axis=1)


def unstack_kv_rows(kv: jnp.ndarray, n_kv_heads: int,
                    head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Traceable inverse of ``stack_kv``: (B, 2, S, D) → K, V each
    (B, S, Hkv, dh) — the decode-cache layout ``gqa_decode`` consumes."""
    b, _, s, _ = kv.shape
    shaped = kv.reshape(b, 2, s, n_kv_heads, head_dim)
    return shaped[:, 0], shaped[:, 1]

"""APM codecs — compressed storage formats for both memo tiers.

AttMemo's capacity→hit-rate curve (paper Fig. 13) makes the memo DB's
bytes-per-entry the scaling bottleneck: the device tier's HBM holds the
serving copy, and every lookup gathers one entry across the HBM bus.
Attention-map caches tolerate aggressive compression (AttnCache,
arXiv:2510.25979), so the store treats the on-tier representation as a
pluggable codec (DESIGN.md §2.6):

* ``f16``     — identity: one float16 arena (the original layout).
* ``int8``    — symmetric per-row int8 with float16 scales. Each APM row
                (one softmax distribution of length L) quantizes as
                ``codes = round(x / scale)``, ``scale = amax(|row|)/127``
                — rows are probability vectors so ``amax ≤ 1`` and the
                worst-case error is ``scale/2 ≈ 0.004``. ~0.53× the f16
                bytes (codes are half, scales add 1/L).
* ``lowrank`` — rank-r factorization APM ≈ U·Vᵀ (softmax rows
                concentrate mass, so the spectrum decays fast), with the
                factors themselves per-row int8 quantized: bytes ratio
                ≈ (r+2)/L — ~0.19× at L=32, r=4. Lossier than int8;
                the accuracy/bytes trade-off is measured in
                ``benchmarks/serve_compress.py``.

A codec is a set of named *parts* (arena-shaped arrays): the host
``AttentionDB`` allocates one numpy arena per part, ``DeviceDB`` mirrors
them as device arrays, and the delta sync ships part rows — so sync
bytes shrink by the same ratio as storage. ``decode_rows`` is pure jnp
and traceable, which is what lets the engine's fused layer jit (and the
memo_attention kernel for int8) dequantize on device, right before the
APM·V matmul, instead of ever materializing f16 APMs in HBM.

Parity note: ``decode`` (numpy, host path) and ``decode_rows`` (jnp,
device path) perform the identical float32-multiply→float16-round
sequence for ``int8``, so select/bucket/kernel modes consume
bit-identical APMs regardless of which tier served them. ``lowrank``
reconstructs through a matmul whose summation order may differ between
numpy and XLA — parity holds within float tolerance, not bit-exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PartSpec:
    """One arena of a codec: per-entry shape suffix + storage dtype."""
    name: str
    shape: Tuple[int, ...]
    dtype: np.dtype

    @property
    def entry_nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize


def _quantize_rows(x: np.ndarray):
    """Symmetric per-row int8: x (..., n) → (codes int8 (..., n),
    scales f16 (...)). The f16-rounded scale is the one used for
    encoding, so decode(encode(x)) is exactly reproducible. The scale
    floor 1e-4 keeps all-zero/near-zero rows finite: a tinier floor
    underflows float16 to 0 and the code divide becomes 0/0."""
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1)
    scale = np.maximum(amax / 127.0, 1e-4).astype(np.float16)
    codes = np.clip(np.rint(x / scale.astype(np.float32)[..., None]),
                    -127, 127).astype(np.int8)
    return codes, scale


class ApmCodec:
    """Base: a codec is its part specs + encode/decode both ways."""

    name = "abstract"

    def __init__(self, apm_shape: Tuple[int, ...]):
        self.apm_shape = tuple(apm_shape)

    @property
    def parts(self) -> Tuple[PartSpec, ...]:
        raise NotImplementedError

    @property
    def entry_nbytes(self) -> int:
        """Codec-true bytes per entry (what budgets and sync receipts
        must report — NOT the logical f16 shape)."""
        return sum(p.entry_nbytes for p in self.parts)

    @property
    def key(self):
        """Hashable identity for jit-cache keys."""
        return (self.name, self.apm_shape)

    def encode(self, apms: np.ndarray, aux=None) -> Tuple[np.ndarray, ...]:
        """Encode a batch of APMs into per-part rows. ``aux`` carries
        side-channel payload for codecs whose entries hold more than the
        APM (the prefill KV codec, ``core/prefill.py``); plain APM codecs
        ignore it."""
        raise NotImplementedError

    def decode(self, parts) -> np.ndarray:
        """Host decode: numpy parts (B, ...) → f16 APMs (B, *apm_shape)."""
        raise NotImplementedError

    def decode_rows(self, parts):
        """Device decode, traceable: jnp parts → f16 APM rows. Must
        mirror ``decode`` op-for-op (see parity note in module doc)."""
        raise NotImplementedError


class F16Codec(ApmCodec):
    """Identity storage (optionally in a caller-chosen dtype)."""

    name = "f16"

    def __init__(self, apm_shape, dtype=np.float16):
        super().__init__(apm_shape)
        self.dtype = np.dtype(dtype)

    @property
    def parts(self):
        return (PartSpec("apm", self.apm_shape, self.dtype),)

    def encode(self, apms, aux=None):
        return (np.asarray(apms, self.dtype),)

    def decode(self, parts):
        return np.asarray(parts[0])

    def decode_rows(self, parts):
        return parts[0]


class Int8Codec(ApmCodec):
    """Symmetric per-row int8 codes + per-row f16 scales."""

    name = "int8"

    @property
    def parts(self):
        h, l, _ = self.apm_shape
        return (PartSpec("codes", self.apm_shape, np.dtype(np.int8)),
                PartSpec("scales", (h, l), np.dtype(np.float16)))

    def encode(self, apms, aux=None):
        return _quantize_rows(np.asarray(apms, np.float32))

    def decode(self, parts):
        codes, scales = parts
        return (np.asarray(codes, np.float32)
                * np.asarray(scales, np.float32)[..., None]
                ).astype(np.float16)

    def decode_rows(self, parts):
        codes, scales = parts
        return (codes.astype(jnp.float32)
                * scales.astype(jnp.float32)[..., None]
                ).astype(jnp.float16)


class LowRankCodec(ApmCodec):
    """Rank-r factorization with int8-quantized factors.

    APM ≈ U·Vᵀ where U, V absorb √Σ from the SVD; each factor row is
    then per-row int8 quantized. Decoded rows approximately (not
    exactly) sum to 1 — consumers that rely on the rows-sum-to-1
    shortcut (the memo kernel's no-renormalization finalizer) stay
    within the documented tolerance because the truncation error is
    bounded by the discarded singular mass."""

    name = "lowrank"

    def __init__(self, apm_shape, rank=None):
        super().__init__(apm_shape)
        l = self.apm_shape[-1]
        # clamp to [1, L]: an (L, L) matrix has L singular values, so a
        # larger rank would declare arenas the SVD cannot fill
        self.rank = min(l, max(1, int(rank))) if rank else min(
            l, max(4, l // 8))

    @property
    def key(self):
        return (self.name, self.apm_shape, self.rank)

    @property
    def parts(self):
        h, l, _ = self.apm_shape
        r = self.rank
        return (PartSpec("u", (h, l, r), np.dtype(np.int8)),
                PartSpec("us", (h, l), np.dtype(np.float16)),
                PartSpec("v", (h, l, r), np.dtype(np.int8)),
                PartSpec("vs", (h, l), np.dtype(np.float16)))

    def encode(self, apms, aux=None):
        x = np.asarray(apms, np.float32)
        u, s, vt = np.linalg.svd(x)                    # batched over (B, H)
        r = self.rank
        root = np.sqrt(s[..., :r])
        uf = u[..., :, :r] * root[..., None, :]        # (..., L, r)
        vf = np.swapaxes(vt[..., :r, :], -1, -2) * root[..., None, :]
        uq, us = _quantize_rows(uf)
        vq, vs = _quantize_rows(vf)
        return uq, us, vq, vs

    def decode(self, parts):
        uq, us, vq, vs = parts
        u = np.asarray(uq, np.float32) * np.asarray(us, np.float32)[..., None]
        v = np.asarray(vq, np.float32) * np.asarray(vs, np.float32)[..., None]
        return np.einsum("...qr,...kr->...qk", u, v).astype(np.float16)

    def decode_rows(self, parts):
        uq, us, vq, vs = parts
        u = uq.astype(jnp.float32) * us.astype(jnp.float32)[..., None]
        v = vq.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]
        return jnp.einsum("...qr,...kr->...qk", u, v).astype(jnp.float16)


# --- registry wiring (repro.memo public API v1) -------------------------
# Built-in codecs self-register; third-party codecs use
# ``repro.memo.register_codec`` with the same factory contract.
from repro.core.registry import CODECS  # noqa: E402

CODECS.register("f16",
                lambda shape, *, rank=None, dtype=np.float16, **_:
                F16Codec(shape, dtype=dtype))
CODECS.register("int8",
                lambda shape, *, rank=None, dtype=None, **_:
                Int8Codec(shape))
CODECS.register("lowrank",
                lambda shape, *, rank=None, dtype=None, **_:
                LowRankCodec(shape, rank=rank))


def get_codec(name, apm_shape, *, rank=None, dtype=np.float16) -> ApmCodec:
    """Resolve a codec key through the registry (``f16`` | ``int8`` |
    ``lowrank`` | anything registered via ``register_codec``); an
    ApmCodec instance passes through. Unknown keys raise with the
    registered choices listed."""
    if isinstance(name, ApmCodec):
        return name
    if name in ("none", None):
        name = "f16"
    return CODECS.resolve(name)(apm_shape, rank=rank, dtype=dtype)

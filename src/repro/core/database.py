"""Attention database — the big-memory APM store (paper §5.1, §5.3).

Two tiers (DESIGN.md §2):

* ``AttentionDB`` — host-RAM tier. APMs live in one large preallocated
  float16 arena (the pod host's RAM is the "big memory"); fetches are
  zero-copy numpy views into the arena, batched into a single device
  transfer — the engine-level analogue of the paper's mmap gathering.
  Reuse counts are tracked for the Fig-11 analysis and feed the
  MemoStore eviction clock. Slots freed by eviction go on a free-list
  and are recycled in place by ``put`` (no compaction, so slot ids stay
  stable and the device tier can be delta-patched).

* ``DeviceDB`` — device-resident tier for the pure-JAX serving path: the DB
  is a jnp array (shardable over the ``data`` mesh axis); lookup is a fused
  gather the memo_attention Pallas kernel can consume directly by index
  (the TPU "zero-copy": the APM tile flows HBM→VMEM exactly once). The
  arena is preallocated with slack so MemoStore's incremental sync can
  land admissions/overwrites with ``.at[slots].set`` deltas instead of a
  full re-transfer; ``transfer_bytes`` accounts every host→device byte.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pad_delta_pow2(slots: np.ndarray, values: Optional[np.ndarray] = None):
    """Pad a scatter delta to the next power-of-2 row count by repeating
    the first (slot, value) pair. A duplicate index writing the identical
    value is a no-op, and the padding bounds the number of distinct
    compiled scatter shapes to log2(N) — otherwise every novel delta size
    pays a fresh XLA compile (~100ms+ on CPU) on the serving boundary."""
    n = slots.size
    p = 1
    while p < n:
        p *= 2
    if p != n:
        slots = np.concatenate([slots, np.repeat(slots[:1], p - n)])
        if values is not None:
            values = np.concatenate(
                [values, np.repeat(values[:1], p - n, axis=0)])
    return slots, values


class AttentionDB:
    def __init__(self, apm_shape: Tuple[int, int, int], capacity: int = 1024,
                 dtype=np.float16):
        """apm_shape: (H, L, L) per entry."""
        self.apm_shape = tuple(apm_shape)
        self.capacity = capacity
        self.dtype = dtype
        self._arena = np.zeros((capacity,) + self.apm_shape, dtype)
        self._n = 0
        self.reuse_counts = np.zeros(capacity, np.int64)
        self._live = np.zeros(capacity, bool)
        self._free: List[int] = []           # released slots, LIFO recycled

    def __len__(self):
        return self._n

    @property
    def entry_nbytes(self) -> int:
        return int(np.prod(self.apm_shape)) * self._arena.itemsize

    @property
    def live_count(self) -> int:
        return self._n - len(self._free)

    @property
    def live_mask(self) -> np.ndarray:
        return self._live[: self._n]

    @property
    def nbytes(self) -> int:
        """Bytes of live entries (budget accounting); the allocation is
        ``capacity * entry_nbytes``."""
        return self.live_count * self.entry_nbytes

    def add(self, apms: np.ndarray) -> np.ndarray:
        """apms: (B, H, L, L). Appends at the arena tail; returns indices.

        Growth is geometric but tight: the arena doubles (amortized O(1)
        appends) or jumps straight to the requested size, whichever is
        larger — never both, so capacity always equals the allocation."""
        b = apms.shape[0]
        if self._n + b > self.capacity:
            new_cap = max(2 * self.capacity, self._n + b)
            arena = np.zeros((new_cap,) + self.apm_shape, self.dtype)
            arena[: self._n] = self._arena[: self._n]
            self._arena = arena
            counts = np.zeros(new_cap, np.int64)
            counts[: self._n] = self.reuse_counts[: self._n]
            self.reuse_counts = counts
            live = np.zeros(new_cap, bool)
            live[: self._n] = self._live[: self._n]
            self._live = live
            self.capacity = new_cap
        idx = np.arange(self._n, self._n + b)
        self._arena[idx] = np.asarray(apms, self.dtype)
        self._live[idx] = True
        self._n += b
        return idx

    def put(self, apms: np.ndarray) -> np.ndarray:
        """Admit entries, recycling released slots first (LIFO) and
        appending the remainder — the arena never compacts, so live slot
        ids are stable across admissions/evictions."""
        apms = np.asarray(apms, self.dtype)
        b = apms.shape[0]
        n_reuse = min(b, len(self._free))
        slots = np.asarray([self._free.pop() for _ in range(n_reuse)],
                           np.int64)
        if n_reuse:
            self._arena[slots] = apms[:n_reuse]
            self.reuse_counts[slots] = 0
            self._live[slots] = True
        if b > n_reuse:
            slots = np.concatenate([slots, self.add(apms[n_reuse:])])
        return slots

    def overwrite(self, slots: Sequence[int], apms: np.ndarray) -> None:
        """In-place update of existing slots (no allocation, no id churn)."""
        slots = np.asarray(slots).reshape(-1)
        self._arena[slots] = np.asarray(apms, self.dtype)

    def release(self, slots: Sequence[int]) -> None:
        """Evict entries: mark slots dead and queue them for recycling.
        Idempotent per slot; released slots keep their arena rows until
        ``put`` overwrites them (readers must go through the index, which
        tombstones the slot first)."""
        for s in np.asarray(slots).reshape(-1):
            s = int(s)
            if 0 <= s < self._n and self._live[s]:
                self._live[s] = False
                self.reuse_counts[s] = 0
                self._free.append(s)

    def get(self, indices, count_reuse: bool = True) -> np.ndarray:
        """Batched fetch: one fancy-index gather out of the arena (no
        per-entry copies) — compare benchmarks/table6_gather.py."""
        indices = np.asarray(indices).reshape(-1)
        if count_reuse:
            np.add.at(self.reuse_counts, indices, 1)
        return self._arena[indices]

    def get_naive(self, indices) -> np.ndarray:
        """The paper's 'memory copy' strawman: per-entry slice + copy +
        re-stack (what PyTorch-style per-tensor gathering does)."""
        parts = [self._arena[int(i)].copy() for i in np.asarray(indices)]
        return np.stack(parts, 0)

    def reuse_histogram(self):
        used = self.reuse_counts[: self._n]
        return np.bincount(used[used >= 0])


class DeviceDB:
    """Device-resident APM store; shard over the data axis for pods.

    ``capacity`` rows are preallocated (``capacity >= n``): the slack lets
    MemoStore land admissions as ``.at[slots].set`` deltas without changing
    the array shape (stable shapes = no fused-jit recompiles), and a
    generation counter upstream decides when a delta suffices. Every
    host→device byte is tallied in ``transfer_bytes``."""

    def __init__(self, apms, capacity: Optional[int] = None, sharding=None):
        apms = np.asarray(apms)
        n = apms.shape[0]
        capacity = max(int(capacity or 0), n)
        if capacity > n:
            pad = np.zeros((capacity - n,) + apms.shape[1:], apms.dtype)
            apms = np.concatenate([apms, pad], 0)
        self.apms = (jax.device_put(apms, sharding) if sharding is not None
                     else jnp.asarray(apms))
        self._n = n
        self.transfer_bytes = int(apms.nbytes)

    @classmethod
    def from_host(cls, db: AttentionDB, capacity: Optional[int] = None,
                  sharding=None) -> "DeviceDB":
        """Materialize the serving copy of a host arena (one transfer of
        the live prefix; the host tier stays the source of truth)."""
        return cls(db._arena[: len(db)], capacity=capacity,
                   sharding=sharding)

    def update(self, slots, apms) -> int:
        """Delta sync: scatter ``apms`` into ``slots`` (admissions land in
        the preallocated slack, overwrites recycle rows in place) — the
        ONLY transfer is the changed rows, never the arena. Returns the
        bytes shipped."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return 0
        if int(slots.max()) >= self.capacity:
            raise ValueError("delta update past device capacity; "
                             "caller must full-resync with more slack")
        n_max = int(slots.max())
        slots, values = pad_delta_pow2(slots, np.asarray(apms, self.dtype))
        values = jnp.asarray(values)
        self.apms = self.apms.at[jnp.asarray(slots)].set(values)
        self._n = max(self._n, n_max + 1)
        shipped = int(values.nbytes + slots.size * 4)
        self.transfer_bytes += shipped
        return shipped

    @property
    def capacity(self) -> int:
        return self.apms.shape[0]

    @property
    def dtype(self):
        return self.apms.dtype

    def __len__(self):
        return self._n

    def gather(self, indices):
        """Fused XLA gather (B,) → (B, H, L, L); with a sharded DB, XLA
        inserts the cross-shard collective automatically."""
        return jnp.take(self.apms, indices, axis=0)


def distributed_search(embs, queries, mesh, *, db_axis="data"):
    """Distributed exact top-1 over an entry-sharded embedding table:
    each shard computes its local argmin (one MXU matmul), then a small
    (n_shards, B) all-gather + global argmin — the pod-scale index search
    (DESIGN.md §2). embs: (N, dim) sharded P(db_axis); queries: (B, dim)
    replicated. Returns (sq_dists (B,), global_idx (B,))."""
    from jax.sharding import PartitionSpec as P

    def body(db, q):
        n_loc = db.shape[0]
        d2 = (jnp.sum(q * q, -1, keepdims=True)
              - 2.0 * q @ db.T + jnp.sum(db * db, -1)[None, :])
        loc_arg = jnp.argmin(d2, axis=-1)
        loc_min = jnp.take_along_axis(d2, loc_arg[:, None], -1)[:, 0]
        shard = jax.lax.axis_index(db_axis)
        gidx = loc_arg + shard * n_loc
        mins = jax.lax.all_gather(loc_min, db_axis)      # (shards, B)
        idxs = jax.lax.all_gather(gidx, db_axis)
        best = jnp.argmin(mins, axis=0)                  # (B,)
        cols = jnp.arange(q.shape[0])
        return mins[best, cols], idxs[best, cols]

    specs = dict(in_specs=(P(db_axis, None), P()), out_specs=(P(), P()))
    if hasattr(jax, "shard_map"):
        smap = jax.shard_map(body, mesh=mesh, check_vma=False, **specs)
    else:  # jax<=0.4.x: experimental home, check_vma was check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        smap = _shard_map(body, mesh=mesh, check_rep=False, **specs)
    return smap(embs, queries)

"""Attention database — the big-memory APM store (paper §5.1, §5.3).

Two tiers (DESIGN.md §2):

* ``AttentionDB`` — host-RAM tier. APMs live in one large preallocated
  float16 arena (the pod host's RAM is the "big memory"); fetches are
  zero-copy numpy views into the arena, batched into a single device
  transfer — the engine-level analogue of the paper's mmap gathering.
  Reuse counts are tracked for the Fig-11 analysis.

* ``DeviceDB`` — device-resident tier for the pure-JAX serving path: the DB
  is a jnp array (shardable over the ``data`` mesh axis); lookup is a fused
  gather the memo_attention Pallas kernel can consume directly by index
  (the TPU "zero-copy": the APM tile flows HBM→VMEM exactly once).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AttentionDB:
    def __init__(self, apm_shape: Tuple[int, int, int], capacity: int = 1024,
                 dtype=np.float16):
        """apm_shape: (H, L, L) per entry."""
        self.apm_shape = tuple(apm_shape)
        self.capacity = capacity
        self.dtype = dtype
        self._arena = np.zeros((capacity,) + self.apm_shape, dtype)
        self._n = 0
        self.reuse_counts = np.zeros(capacity, np.int64)

    def __len__(self):
        return self._n

    @property
    def nbytes(self) -> int:
        return self._n * int(np.prod(self.apm_shape)) * self._arena.itemsize

    def add(self, apms: np.ndarray) -> np.ndarray:
        """apms: (B, H, L, L). Returns assigned indices.

        Growth is geometric but tight: the arena doubles (amortized O(1)
        appends) or jumps straight to the requested size, whichever is
        larger — never both, so capacity always equals the allocation."""
        b = apms.shape[0]
        if self._n + b > self.capacity:
            new_cap = max(2 * self.capacity, self._n + b)
            arena = np.zeros((new_cap,) + self.apm_shape, self.dtype)
            arena[: self._n] = self._arena[: self._n]
            self._arena = arena
            counts = np.zeros(new_cap, np.int64)
            counts[: self._n] = self.reuse_counts[: self._n]
            self.reuse_counts = counts
            self.capacity = new_cap
        idx = np.arange(self._n, self._n + b)
        self._arena[idx] = np.asarray(apms, self.dtype)
        self._n += b
        return idx

    def get(self, indices, count_reuse: bool = True) -> np.ndarray:
        """Batched fetch: one fancy-index gather out of the arena (no
        per-entry copies) — compare benchmarks/table6_gather.py."""
        indices = np.asarray(indices).reshape(-1)
        if count_reuse:
            np.add.at(self.reuse_counts, indices, 1)
        return self._arena[indices]

    def get_naive(self, indices) -> np.ndarray:
        """The paper's 'memory copy' strawman: per-entry slice + copy +
        re-stack (what PyTorch-style per-tensor gathering does)."""
        parts = [self._arena[int(i)].copy() for i in np.asarray(indices)]
        return np.stack(parts, 0)

    def reuse_histogram(self):
        used = self.reuse_counts[: self._n]
        return np.bincount(used[used >= 0])


class DeviceDB:
    """Device-resident APM store; shard over the data axis for pods."""

    def __init__(self, apms: jnp.ndarray, sharding=None):
        self.apms = (jax.device_put(apms, sharding) if sharding is not None
                     else jnp.asarray(apms))

    @classmethod
    def from_host(cls, db: AttentionDB, sharding=None) -> "DeviceDB":
        """Materialize the serving copy of a host arena (one transfer of
        the live prefix; the host tier stays the source of truth)."""
        return cls(db._arena[: len(db)], sharding)

    def __len__(self):
        return self.apms.shape[0]

    def gather(self, indices):
        """Fused XLA gather (B,) → (B, H, L, L); with a sharded DB, XLA
        inserts the cross-shard collective automatically."""
        return jnp.take(self.apms, indices, axis=0)


def distributed_search(embs, queries, mesh, *, db_axis="data"):
    """Distributed exact top-1 over an entry-sharded embedding table:
    each shard computes its local argmin (one MXU matmul), then a small
    (n_shards, B) all-gather + global argmin — the pod-scale index search
    (DESIGN.md §2). embs: (N, dim) sharded P(db_axis); queries: (B, dim)
    replicated. Returns (sq_dists (B,), global_idx (B,))."""
    from jax.sharding import PartitionSpec as P

    def body(db, q):
        n_loc = db.shape[0]
        d2 = (jnp.sum(q * q, -1, keepdims=True)
              - 2.0 * q @ db.T + jnp.sum(db * db, -1)[None, :])
        loc_arg = jnp.argmin(d2, axis=-1)
        loc_min = jnp.take_along_axis(d2, loc_arg[:, None], -1)[:, 0]
        shard = jax.lax.axis_index(db_axis)
        gidx = loc_arg + shard * n_loc
        mins = jax.lax.all_gather(loc_min, db_axis)      # (shards, B)
        idxs = jax.lax.all_gather(gidx, db_axis)
        best = jnp.argmin(mins, axis=0)                  # (B,)
        cols = jnp.arange(q.shape[0])
        return mins[best, cols], idxs[best, cols]

    specs = dict(in_specs=(P(db_axis, None), P()), out_specs=(P(), P()))
    if hasattr(jax, "shard_map"):
        smap = jax.shard_map(body, mesh=mesh, check_vma=False, **specs)
    else:  # jax<=0.4.x: experimental home, check_vma was check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        smap = _shard_map(body, mesh=mesh, check_rep=False, **specs)
    return smap(embs, queries)

"""Attention database — the big-memory APM store (paper §5.1, §5.3).

Two tiers (DESIGN.md §2), both codec-aware (§2.6):

* ``AttentionDB`` — host-RAM tier. Entries live in one preallocated
  arena *per codec part* (f16 APMs, or int8 codes + f16 scales, or
  low-rank factors — see ``core/codec.py``); fetches are zero-copy numpy
  views into the arenas, batched into a single device transfer — the
  engine-level analogue of the paper's mmap gathering. Reuse counts are
  tracked for the Fig-11 analysis and feed the MemoStore eviction clock.
  Slots freed by eviction go on a free-list and are recycled in place by
  ``put`` (no compaction, so slot ids stay stable and the device tier
  can be delta-patched). ``entry_nbytes`` reports the codec-true
  (compressed) payload, so byte budgets and sync receipts stay honest.

* ``DeviceDB`` — device-resident tier for the pure-JAX serving path: each
  codec part is a jnp array (shardable over the ``data`` mesh axis); the
  hot path gathers the *compressed* rows by index and dequantizes in the
  fused layer jit (or inside the memo_attention kernel's VMEM for int8)
  — the APM tile flows HBM→VMEM once, at the compressed width. The
  arenas are preallocated with slack so MemoStore's incremental sync can
  land admissions/overwrites with ``.at[slots].set`` deltas instead of a
  full re-transfer; ``transfer_bytes`` accounts every host→device byte,
  at the compressed width.
"""
from __future__ import annotations

import zlib
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import ApmCodec, F16Codec, get_codec


def pad_delta_pow2(slots: np.ndarray, values: Optional[np.ndarray] = None):
    """Pad a scatter delta to the next power-of-2 row count by repeating
    the first (slot, value) pair. A duplicate index writing the identical
    value is a no-op, and the padding bounds the number of distinct
    compiled scatter shapes to log2(N) — otherwise every novel delta size
    pays a fresh XLA compile (~100ms+ on CPU) on the serving boundary."""
    n = slots.size
    p = 1
    while p < n:
        p *= 2
    if p != n:
        slots = np.concatenate([slots, np.repeat(slots[:1], p - n)])
        if values is not None:
            values = np.concatenate(
                [values, np.repeat(values[:1], p - n, axis=0)])
    return slots, values


def pad_delta_parts(slots: np.ndarray, parts: Sequence[np.ndarray]):
    """`pad_delta_pow2` for a multi-part (codec) payload: one padded slot
    vector shared by every part's scatter."""
    padded_slots, _ = pad_delta_pow2(slots)
    pad = padded_slots.size - slots.size
    if pad == 0:
        return padded_slots, tuple(np.asarray(p) for p in parts)
    return padded_slots, tuple(
        np.concatenate([p, np.repeat(p[:1], pad, axis=0)])
        for p in (np.asarray(p) for p in parts))


class AttentionDB:
    def __init__(self, apm_shape: Tuple[int, int, int], capacity: int = 1024,
                 dtype=np.float16, codec="f16", rank: Optional[int] = None):
        """apm_shape: (H, L, L) per entry; ``codec`` picks the storage
        format (``f16`` | ``int8`` | ``lowrank`` or an ApmCodec)."""
        self.apm_shape = tuple(apm_shape)
        self.capacity = capacity
        self.dtype = dtype                    # logical (decode) dtype
        self.codec: ApmCodec = get_codec(codec, self.apm_shape, rank=rank,
                                         dtype=dtype)
        self._arenas: List[np.ndarray] = [
            np.zeros((capacity,) + p.shape, p.dtype)
            for p in self.codec.parts]
        self._n = 0
        self.reuse_counts = np.zeros(capacity, np.int64)
        self._live = np.zeros(capacity, bool)
        self._free: List[int] = []           # released slots, LIFO recycled
        # per-codec-part CRC32 of each entry's arena row, recorded at
        # write time (add/put/overwrite) — the store's integrity layer
        # (DESIGN.md §2.9): ``verify`` recomputes and flags any slot
        # whose bytes drifted since they were encoded
        self.checksums: List[np.ndarray] = [
            np.zeros(capacity, np.uint32) for _ in self.codec.parts]

    def __len__(self):
        return self._n

    @property
    def _arena(self) -> np.ndarray:
        """The primary part's arena (codes for int8, the f16 arena for
        identity) — capacity/shape introspection and debugging; readers
        of *values* must go through ``get``/``parts_at``."""
        return self._arenas[0]

    @property
    def entry_nbytes(self) -> int:
        """Codec-true bytes per entry (the compressed payload, NOT the
        logical f16 shape — budget accounting depends on this)."""
        return self.codec.entry_nbytes

    @property
    def logical_entry_nbytes(self) -> int:
        """Bytes an uncompressed f16 entry would occupy (the baseline
        the compression receipts are quoted against)."""
        return int(np.prod(self.apm_shape)) * 2

    @property
    def live_count(self) -> int:
        return self._n - len(self._free)

    @property
    def live_mask(self) -> np.ndarray:
        return self._live[: self._n]

    @property
    def nbytes(self) -> int:
        """Bytes of live entries (budget accounting); the allocation is
        ``capacity * entry_nbytes``."""
        return self.live_count * self.entry_nbytes

    def parts_at(self, indices) -> Tuple[np.ndarray, ...]:
        """Raw compressed rows, one gather per codec part."""
        indices = np.asarray(indices).reshape(-1)
        return tuple(a[indices] for a in self._arenas)

    def parts_prefix(self, n: int) -> Tuple[np.ndarray, ...]:
        """Zero-copy views of the first ``n`` rows of every part."""
        return tuple(a[:n] for a in self._arenas)

    def _grow_to(self, need: int) -> None:
        if need <= self.capacity:
            return
        new_cap = max(2 * self.capacity, need)
        arenas = []
        for a in self._arenas:
            fresh = np.zeros((new_cap,) + a.shape[1:], a.dtype)
            fresh[: self._n] = a[: self._n]
            arenas.append(fresh)
        self._arenas = arenas
        counts = np.zeros(new_cap, np.int64)
        counts[: self._n] = self.reuse_counts[: self._n]
        self.reuse_counts = counts
        live = np.zeros(new_cap, bool)
        live[: self._n] = self._live[: self._n]
        self._live = live
        csums = []
        for c in self.checksums:
            fresh = np.zeros(new_cap, np.uint32)
            fresh[: self._n] = c[: self._n]
            csums.append(fresh)
        self.checksums = csums
        self.capacity = new_cap

    # ------------------------------------------------------------ integrity
    @staticmethod
    def _crc_rows(part_rows: np.ndarray) -> np.ndarray:
        """(B, ...) encoded part rows → (B,) CRC32 per row."""
        b = part_rows.shape[0]
        out = np.empty(b, np.uint32)
        rows = np.ascontiguousarray(part_rows)
        for i in range(b):
            out[i] = zlib.crc32(rows[i].tobytes())
        return out

    def _record_checksums(self, slots: np.ndarray,
                          parts: Sequence[np.ndarray]) -> None:
        for csum, p in zip(self.checksums, parts):
            csum[slots] = self._crc_rows(np.asarray(p))

    def verify(self, slots=None) -> np.ndarray:
        """Recompute per-part checksums for ``slots`` (default: every
        live slot) and return the slot ids whose stored bytes no longer
        match — corruption candidates for the store's
        quarantine-and-tombstone path. Dead slots are skipped (their
        rows are garbage by design until ``put`` recycles them)."""
        if slots is None:
            slots = np.flatnonzero(self._live[: self._n])
        else:
            slots = np.asarray(slots).reshape(-1)
            slots = slots[(slots >= 0) & (slots < self._n)]
            slots = slots[self._live[slots]]
        if slots.size == 0:
            return np.zeros(0, np.int64)
        bad = np.zeros(slots.shape[0], bool)
        for csum, arena in zip(self.checksums, self._arenas):
            bad |= self._crc_rows(arena[slots]) != csum[slots]
        return slots[bad].astype(np.int64)

    def add(self, apms: np.ndarray, aux=None) -> np.ndarray:
        """apms: (B, H, L, L). Appends at the arena tail; returns indices.
        ``aux`` is the codec's side-channel payload (KV planes for the
        prefill codec; plain APM codecs ignore it).

        Growth is geometric but tight: the arena doubles (amortized O(1)
        appends) or jumps straight to the requested size, whichever is
        larger — never both, so capacity always equals the allocation."""
        b = apms.shape[0]
        self._grow_to(self._n + b)
        idx = np.arange(self._n, self._n + b)
        parts = self.codec.encode(np.asarray(apms, self.dtype), aux)
        for a, p in zip(self._arenas, parts):
            a[idx] = p
        self._record_checksums(idx, parts)
        self._live[idx] = True
        self._n += b
        return idx

    def put(self, apms: np.ndarray, aux=None) -> np.ndarray:
        """Admit entries, recycling released slots first (LIFO) and
        appending the remainder — the arena never compacts, so live slot
        ids are stable across admissions/evictions."""
        apms = np.asarray(apms, self.dtype)
        b = apms.shape[0]
        if aux is not None:
            aux = np.asarray(aux)
        n_reuse = min(b, len(self._free))
        slots = np.asarray([self._free.pop() for _ in range(n_reuse)],
                           np.int64)
        if n_reuse:
            parts = self.codec.encode(
                apms[:n_reuse], None if aux is None else aux[:n_reuse])
            for a, p in zip(self._arenas, parts):
                a[slots] = p
            self._record_checksums(slots, parts)
            self.reuse_counts[slots] = 0
            self._live[slots] = True
        if b > n_reuse:
            slots = np.concatenate([slots, self.add(
                apms[n_reuse:], None if aux is None else aux[n_reuse:])])
        return slots

    def put_parts(self, parts: Sequence[np.ndarray],
                  checksums: Optional[Sequence[np.ndarray]] = None
                  ) -> np.ndarray:
        """``put`` for rows ALREADY in the codec's encoded form — the
        capacity tier's promotion path (DESIGN.md §2.11): the stored
        bytes land in the arenas verbatim, so a demote → promote round
        trip is bit-identical for every codec. ``checksums`` (per part,
        as recorded at first admission) are adopted when given and
        recomputed otherwise."""
        parts = tuple(np.ascontiguousarray(np.asarray(p, a.dtype))
                      for p, a in zip(parts, self._arenas))
        b = int(parts[0].shape[0])
        if b == 0:
            return np.zeros(0, np.int64)
        if checksums is None:
            checksums = [self._crc_rows(p) for p in parts]
        n_reuse = min(b, len(self._free))
        slots = np.asarray([self._free.pop() for _ in range(n_reuse)],
                           np.int64)
        if b > n_reuse:
            tail = b - n_reuse
            self._grow_to(self._n + tail)
            slots = np.concatenate(
                [slots, np.arange(self._n, self._n + tail)])
            self._n += tail
        for a, p in zip(self._arenas, parts):
            a[slots] = p
        for csum, c in zip(self.checksums, checksums):
            csum[slots] = np.asarray(c, np.uint32)
        self.reuse_counts[slots] = 0
        self._live[slots] = True
        return slots

    def overwrite(self, slots: Sequence[int], apms: np.ndarray,
                  aux=None) -> None:
        """In-place update of existing slots (no allocation, no id churn)."""
        slots = np.asarray(slots).reshape(-1)
        parts = self.codec.encode(np.asarray(apms, self.dtype), aux)
        for a, p in zip(self._arenas, parts):
            a[slots] = p
        self._record_checksums(slots, parts)

    def release(self, slots: Sequence[int]) -> None:
        """Evict entries: mark slots dead and queue them for recycling.
        Idempotent per slot; released slots keep their arena rows until
        ``put`` overwrites them (readers must go through the index, which
        tombstones the slot first)."""
        for s in np.asarray(slots).reshape(-1):
            s = int(s)
            if 0 <= s < self._n and self._live[s]:
                self._live[s] = False
                self.reuse_counts[s] = 0
                self._free.append(s)

    def get(self, indices, count_reuse: bool = True) -> np.ndarray:
        """Batched decoded fetch: one fancy-index gather per codec part
        (no per-entry copies) — compare benchmarks/table6_gather.py."""
        indices = np.asarray(indices).reshape(-1)
        if count_reuse:
            np.add.at(self.reuse_counts, indices, 1)
        return self.codec.decode(tuple(a[indices] for a in self._arenas))

    def get_naive(self, indices) -> np.ndarray:
        """The paper's 'memory copy' strawman: per-entry slice + copy +
        re-stack (what PyTorch-style per-tensor gathering does)."""
        parts = [self.codec.decode(
            tuple(a[int(i): int(i) + 1].copy() for a in self._arenas))[0]
            for i in np.asarray(indices)]
        return np.stack(parts, 0)

    def reuse_histogram(self):
        used = self.reuse_counts[: self._n]
        return np.bincount(used[used >= 0])


class DeviceDB:
    """Device-resident APM store; shard over the data axis for pods.

    ``capacity`` rows are preallocated (``capacity >= n``): the slack lets
    MemoStore land admissions as ``.at[slots].set`` deltas without changing
    the array shape (stable shapes = no fused-jit recompiles), and a
    generation counter upstream decides when a delta suffices. Every
    host→device byte is tallied in ``transfer_bytes`` — at the codec's
    compressed width; the hot path consumes ``parts`` and dequantizes in
    its own jit, so the f16 APMs never exist in HBM."""

    def __init__(self, apms, capacity: Optional[int] = None, sharding=None,
                 codec: Optional[ApmCodec] = None):
        if codec is None:                 # identity construction from array
            apms = np.asarray(apms)
            codec = F16Codec(apms.shape[1:], dtype=apms.dtype)
            host_parts = (apms,)
        else:
            host_parts = tuple(np.asarray(p) for p in apms)
        self.codec = codec
        n = host_parts[0].shape[0]
        capacity = max(int(capacity or 0), n)
        parts = []
        for p in host_parts:
            if capacity > n:
                pad = np.zeros((capacity - n,) + p.shape[1:], p.dtype)
                p = np.concatenate([p, pad], 0)
            parts.append(jax.device_put(p, sharding) if sharding is not None
                         else jnp.asarray(p))
        self.parts: Tuple[jnp.ndarray, ...] = tuple(parts)
        self._n = n
        self.transfer_bytes = sum(int(p.nbytes) for p in self.parts)

    @classmethod
    def from_host(cls, db: AttentionDB, capacity: Optional[int] = None,
                  sharding=None) -> "DeviceDB":
        """Materialize the serving copy of a host arena (one transfer of
        the live prefix — compressed parts, codec carried over; the host
        tier stays the source of truth)."""
        return cls(db.parts_prefix(len(db)), capacity=capacity,
                   sharding=sharding, codec=db.codec)

    @property
    def apms(self) -> jnp.ndarray:
        """The full arena, decoded. For the identity codec this is the
        raw array (zero cost); for compressed codecs it MATERIALIZES the
        decoded arena — tests/debugging only, never the hot path (which
        gathers ``parts`` and dequantizes per batch)."""
        if isinstance(self.codec, F16Codec):
            return self.parts[0]
        return self.codec.decode_rows(self.parts)

    def update(self, slots, values) -> int:
        """Delta sync: scatter compressed rows into ``slots`` (admissions
        land in the preallocated slack, overwrites recycle rows in place)
        — the ONLY transfer is the changed rows, never the arena.
        ``values``: a parts tuple (or a bare decoded array, identity
        codec only). Returns the bytes shipped."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return 0
        if int(slots.max()) >= self.capacity:
            raise ValueError("delta update past device capacity; "
                             "caller must full-resync with more slack")
        if not isinstance(values, (tuple, list)):
            values = self.codec.encode(np.asarray(values))
        n_max = int(slots.max())
        slots, parts = pad_delta_parts(slots, values)
        slots_dev = jnp.asarray(slots)
        shipped = int(slots.size * 4)
        new_parts = []
        for arr, p in zip(self.parts, parts):
            p = jnp.asarray(np.asarray(p, arr.dtype))
            new_parts.append(arr.at[slots_dev].set(p))
            shipped += int(p.nbytes)
        self.parts = tuple(new_parts)
        self._n = max(self._n, n_max + 1)
        self.transfer_bytes += shipped
        return shipped

    @property
    def capacity(self) -> int:
        return self.parts[0].shape[0]

    @property
    def dtype(self):
        return self.parts[0].dtype

    @property
    def entry_nbytes(self) -> int:
        """Compressed bytes per entry actually resident in HBM."""
        return self.codec.entry_nbytes

    @property
    def nbytes(self) -> int:
        """Total HBM bytes of the allocation (all parts, incl. slack)."""
        return sum(int(p.nbytes) for p in self.parts)

    def __len__(self):
        return self._n

    def gather_parts(self, indices) -> Tuple[jnp.ndarray, ...]:
        """Compressed gather (B,) → per-part rows; traceable. The fused
        consumer dequantizes via ``codec.decode_rows`` (or inside the
        memo_attention kernel for int8)."""
        return tuple(jnp.take(p, indices, axis=0) for p in self.parts)

    def gather(self, indices):
        """Decoded gather (B,) → (B, H, L, L); with a sharded DB, XLA
        inserts the cross-shard collective automatically."""
        return self.codec.decode_rows(self.gather_parts(indices))

"""AttMemo core — the paper's contribution as composable JAX modules."""
from repro.core.similarity import (  # noqa: F401
    memo_rate, pairwise_similarity, similarity_score)
from repro.core.embedding import Embedder, train_embedder  # noqa: F401
from repro.core.index import ExactIndex, IVFIndex, recall_at_1  # noqa: F401
from repro.core.database import AttentionDB, DeviceDB  # noqa: F401
from repro.core.selective import LayerProfile, PerfModel  # noqa: F401
from repro.core.store import MemoStore, StoreStats  # noqa: F401
from repro.core.shard import (  # noqa: F401
    ShardedDeviceIndex, ShardedMemoStore, make_store_mesh, mesh_search)
from repro.core.faults import (  # noqa: F401
    CHAOS_PRESETS, FAULT_POINTS, FaultInjector, MemoStoreError)
from repro.core.registry import (  # noqa: F401
    register_codec, register_eviction, register_index)
from repro.core.engine import (  # noqa: F401
    LEVELS, MemoConfig, MemoEngine, MemoStats, SimReservoir)

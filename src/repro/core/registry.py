"""String-keyed extension registries (repro.memo public API v1).

The memo subsystem resolves its pluggable pieces — APM storage codecs,
host/device index layouts, eviction policies — through these registries
instead of ``if/elif`` chains on config strings. Adding a variant is one
``register_*`` call next to its implementation; the engine, store and
specs never change. Unknown keys fail fast with the registered choices
listed, at spec construction (``repro.memo.specs``) and again at
resolution (belt and braces for direct ``MemoStore`` construction).

Registries live in ``repro.core`` (a leaf module, importable by every
core module without cycles) and are re-exported as the public surface by
``repro.memo``. Default implementations register themselves when their
defining module imports; ``autoload`` closes the loop for callers that
touch a registry before importing those modules (e.g. validating a
``CodecSpec`` before ever building a store).

Factory contracts (keyword-only context; factories must tolerate extra
context via ``**_``):

* codec:        ``factory(apm_shape, *, rank=None, dtype=np.float16)``
                → ``ApmCodec``
* host index:   ``factory(embed_dim, *, n_lists=None, interpret=None,
                mesh=None)`` → object with the ``search/assign/remove``
                host-index API (see ``core/index.py``)
* device index: ``factory(embed_dim, *, capacity=0, nprobe=16,
                n_clusters=None, interpret=None, mesh=None)``
                → ``DeviceIndex``-API object
* eviction:     ``policy(store, n)`` → sequence of arena slots to evict;
                called under the store lock, selection only (the store
                does the release/tombstone/dirty bookkeeping)
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Optional, Tuple


class Registry:
    """A named string → factory map with fail-fast resolution."""

    def __init__(self, kind: str, autoload: Tuple[str, ...] = ()):
        self.kind = kind
        self._autoload = tuple(autoload)
        self._loaded = False
        self._entries: Dict[str, Callable] = {}

    def _ensure(self) -> None:
        """Import the modules whose defaults self-register (idempotent).
        ``_loaded`` flips only after every import succeeds: a failed
        autoload must re-raise its real error on the next call, not
        decay into a misleading \"unknown key; registered: []\"."""
        if not self._loaded:
            for mod in self._autoload:
                importlib.import_module(mod)
            self._loaded = True

    def register(self, name: str, obj: Optional[Callable] = None):
        """``register("x", factory)`` or ``@register("x")`` decorator.
        Re-registering a name overwrites it (latest wins) — that is what
        lets a user shadow a built-in implementation."""
        if obj is None:
            return lambda fn: self.register(name, fn)
        if not isinstance(name, str) or not name:
            raise ValueError(f"{self.kind} key must be a non-empty string, "
                             f"got {name!r}")
        self._entries[name] = obj
        return obj

    def choices(self) -> Tuple[str, ...]:
        self._ensure()
        return tuple(sorted(self._entries))

    def __contains__(self, name) -> bool:
        self._ensure()
        return name in self._entries

    def resolve(self, name: str) -> Callable:
        self._ensure()
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{list(self.choices())}") from None


CODECS = Registry("APM codec", autoload=("repro.core.codec",))
HOST_INDEXES = Registry("host index", autoload=("repro.core.index",))
DEVICE_INDEXES = Registry("device index", autoload=("repro.core.index",))
EVICTIONS = Registry("eviction policy", autoload=("repro.core.store",))


def register_codec(name: str, factory: Optional[Callable] = None):
    """Register an APM storage codec under ``name`` (usable as
    ``CodecSpec(name=...)`` / ``MemoConfig(apm_codec=...)``)."""
    return CODECS.register(name, factory)


def register_index(name: str, factory: Optional[Callable] = None, *,
                   tier: str = "host"):
    """Register an index implementation. ``tier="host"`` keys are valid
    for ``IndexSpec.host`` (the calibration/lookup index);
    ``tier="device"`` keys for ``IndexSpec.device`` (the serving-tier
    search traced inside the fused jit)."""
    if tier not in ("host", "device"):
        raise ValueError(f"tier must be 'host' or 'device', got {tier!r}")
    reg = HOST_INDEXES if tier == "host" else DEVICE_INDEXES
    return reg.register(name, factory)


def register_eviction(name: str, policy: Optional[Callable] = None):
    """Register an eviction policy: ``policy(store, n) -> slots``."""
    return EVICTIONS.register(name, policy)

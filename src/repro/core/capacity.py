"""Crash-consistent big-memory capacity tier (DESIGN.md §2.11).

AttMemo's database is meant to live on a *big memory* system — far
larger than the serving process's RAM budget — and to be gathered by
memory-mapping rather than copies (paper §5.3). This module is that
third tier, plus the durability layer PR 5's all-or-nothing ``.npz``
save lacked:

* **Save format 3** — an uncompressed, page-aligned single-file layout
  (``write_format3`` / ``read_format3``): a CRC-framed JSON header
  followed by raw C-order array segments, each starting on a 4096-byte
  page boundary so ``np.memmap`` can open every array zero-copy
  (``MemoSession.load(..., mmap=True)``). Format 2 (compressed npz)
  cannot be mmapped and stays readable through the legacy path.

* **Journal** — a write-ahead redo log of CRC32-framed records. Every
  frame is ``magic | payload_len | payload_crc | payload`` with the
  payload an uncompressed npz, so replay can stop cleanly at the first
  torn/corrupt frame: a process killed mid-append loses at most the
  un-journaled tail, never an earlier record.

* **CapacityTier** — mmap-backed codec-part arenas in a directory, with
  the WAL + shadow-checkpoint protocol: mutations journal first (fsync),
  then land in the arenas; a checkpoint flushes the maps, shadow-writes
  the bookkeeping manifest (temp file + fsync + ``os.replace``) and
  truncates the journal. Recovery = manifest + in-order journal replay
  (idempotent) + a full per-row CRC32 sweep that retires torn or
  bit-flipped rows — so reopening after SIGKILL at ANY instant yields a
  tier whose every live row verifies.

Fault points (``capacity.*`` in ``core/faults.py``) are threaded through
the same way as the store's: ``disk_write_io`` (append raises — or
stalls, with a ``stall_s`` rider), ``journal_torn`` (a deliberately
short frame hits the disk, then the append fails), ``checkpoint_crash``
(the shadow write dies after the temp file, before the replace) and
``mmap_bitflip`` (an arena byte flips after the row's checksum was
recorded).
"""
from __future__ import annotations

import io
import json
import os
import re
import struct
import time
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultInjector, MemoStoreError, fire

PAGE = 4096                     # segment alignment: mmap-friendly pages
MAGIC3 = b"MEMOSAV3"            # format-3 file prelude
_FRAME_MAGIC = 0x334F4D4D       # journal frame marker ("MMO3")
_FRAME_HDR = struct.Struct("<III")   # magic, payload_len, payload_crc


def _align(n: int) -> int:
    return (n + PAGE - 1) // PAGE * PAGE


def _pid_alive(pid: int) -> bool:
    """Best-effort liveness probe for stale-lock detection. ``kill(pid,
    0)`` raising ``ProcessLookupError`` is the only *certain* answer
    (dead); ``PermissionError`` means the pid exists under another uid —
    treat as alive (refusing is the safe direction for a lock)."""
    if pid <= 0:
        return False
    try:
        os.kill(int(pid), 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    except OSError:
        return True
    return True


def _fsync_dir(path: str) -> None:
    """Durability for renames: fsync the containing directory (best
    effort — not every filesystem supports dir fds)."""
    try:
        fd = os.open(path or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


# --------------------------------------------------------------- format 3
def is_format3(path: str) -> bool:
    """True when ``path`` starts with the format-3 magic."""
    try:
        with open(str(path), "rb") as f:
            return f.read(len(MAGIC3)) == MAGIC3
    except OSError:
        return False


def write_format3(path: str, meta: dict, arrays: Dict[str, np.ndarray], *,
                  fsync: bool = True,
                  faults: Optional[FaultInjector] = None,
                  fault_point: Optional[str] = None,
                  fault_raises: bool = False) -> bool:
    """Atomically write a format-3 file: temp file in the target
    directory, fsync, ``os.replace`` — a crash (or an injected
    ``fault_point``, fired after the temp is complete but before the
    replace) can only ever leave a stray ``*.tmp``; an existing good
    file at ``path`` is never clobbered.

    Returns True when the file was published; False when ``fault_point``
    fired with ``fault_raises=False`` (the simulated-crash path:
    truncated temp left behind, target untouched)."""
    path = str(path)
    # NB: ascontiguousarray PROMOTES 0-d arrays to shape (1,) — keep
    # scalars 0-d so shapes round-trip exactly
    arrays = {k: (a if a.ndim == 0 else np.ascontiguousarray(a))
              for k, a in ((k, np.asarray(v)) for k, v in arrays.items())}
    # the header carries absolute segment offsets, which depend on the
    # header's own (digit-count-sensitive) length — iterate to fixpoint
    entries = {k: {"offset": 0, "shape": list(a.shape),
                   "dtype": np.dtype(a.dtype).str,
                   "crc32": zlib.crc32(a.tobytes())}
               for k, a in arrays.items()}
    header = b""
    for _ in range(8):
        off = _align(len(MAGIC3) + _FRAME_HDR.size + len(header))
        for k in arrays:
            entries[k]["offset"] = off
            off = _align(off + int(arrays[k].nbytes))
        fresh = json.dumps({"format": 3, "meta": meta, "arrays": entries},
                           sort_keys=True).encode()
        if len(fresh) == len(header):
            header = fresh
            break
        header = fresh
    d = os.path.dirname(os.path.abspath(path))
    tmp = os.path.join(d, f".{os.path.basename(path)}.{os.getpid()}.tmp")
    fired = False
    try:
        with open(tmp, "wb") as f:
            f.write(MAGIC3)
            f.write(_FRAME_HDR.pack(_FRAME_MAGIC, len(header),
                                    zlib.crc32(header)))
            f.write(header)
            for k, a in arrays.items():
                f.seek(entries[k]["offset"])
                f.write(a.tobytes())
            f.flush()
            if fsync:
                os.fsync(f.fileno())
        if fault_point and fire(faults, fault_point) is not None:
            # simulated crash between the temp write and the publish:
            # tear the temp (as a dying process would) and stop — the
            # target keeps whatever good bytes it already had
            fired = True
            size = os.path.getsize(tmp)
            with open(tmp, "rb+") as f:
                f.truncate(max(1, int(size * 0.6)))
            if fault_raises:
                raise OSError(f"injected crash before publishing {path!r} "
                              f"(torn temp left at {tmp!r})")
            return False
        os.replace(tmp, path)
    finally:
        if not fired and os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
    if fsync:
        _fsync_dir(d)
    return True


def read_format3(path: str, *, mmap: bool = False, verify: bool = True
                 ) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Read a format-3 file → ``(meta, arrays)``. With ``mmap=True``
    every array is an ``np.memmap`` in copy-on-write mode (``'c'``):
    zero-copy until written, and writes never touch the file. Per-array
    CRC verification (``verify``) is skipped under mmap by callers that
    verify lazily — the header CRC and segment bounds are always
    checked. Failures raise ``MemoStoreError`` naming the problem."""
    path = str(path)
    try:
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            prelude = f.read(len(MAGIC3) + _FRAME_HDR.size)
            if len(prelude) < len(MAGIC3) + _FRAME_HDR.size \
                    or prelude[:len(MAGIC3)] != MAGIC3:
                raise MemoStoreError(
                    f"unreadable memo store file {path!r} (truncated or "
                    f"corrupt): bad format-3 prelude")
            magic, hlen, hcrc = _FRAME_HDR.unpack(prelude[len(MAGIC3):])
            header = f.read(hlen)
        if magic != _FRAME_MAGIC or len(header) != hlen \
                or zlib.crc32(header) != hcrc:
            raise MemoStoreError(
                f"unreadable memo store file {path!r} (truncated or "
                f"corrupt): format-3 header checksum mismatch")
        doc = json.loads(header.decode())
    except MemoStoreError:
        raise
    except Exception as e:
        raise MemoStoreError(
            f"unreadable memo store file {path!r} (truncated or "
            f"corrupt): {type(e).__name__}: {e}") from e
    arrays: Dict[str, np.ndarray] = {}
    bad: List[str] = []
    for k, ent in (doc.get("arrays") or {}).items():
        shape = tuple(int(s) for s in ent["shape"])
        dtype = np.dtype(ent["dtype"])
        off = int(ent["offset"])
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if nbytes and off + nbytes > size:
            raise MemoStoreError(
                f"unreadable memo store file {path!r} (truncated or "
                f"corrupt): array {k!r} runs past end of file")
        if mmap:
            a = (np.memmap(path, dtype=dtype, mode="c", offset=off,
                           shape=shape) if nbytes
                 else np.zeros(shape, dtype))
        else:
            with open(path, "rb") as f:
                f.seek(off)
                buf = f.read(nbytes)
            if len(buf) != nbytes:
                raise MemoStoreError(
                    f"unreadable memo store file {path!r} (truncated or "
                    f"corrupt): short read of array {k!r}")
            a = np.frombuffer(buf, dtype=dtype).reshape(shape).copy()
        if verify and not mmap \
                and zlib.crc32(np.ascontiguousarray(a).tobytes()) \
                != int(ent["crc32"]):
            bad.append(k)
        arrays[k] = a
    if bad:
        raise MemoStoreError(
            f"checksum mismatch in memo store file {path!r} for "
            f"{sorted(bad)} — the file is corrupt (bit flips or a "
            f"partial write); rebuild or restore from a good copy")
    return dict(doc.get("meta") or {}), arrays


# ---------------------------------------------------------------- journal
def _pack_record(kind: str, arrays: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, __kind__=np.asarray(kind), **arrays)
    return buf.getvalue()


def _unpack_record(payload: bytes) -> Tuple[str, Dict[str, np.ndarray]]:
    with np.load(io.BytesIO(payload), allow_pickle=False) as data:
        arrays = {k: data[k] for k in data.files if k != "__kind__"}
        return str(data["__kind__"]), arrays


def replay_journal(path: str
                   ) -> Tuple[List[Tuple[str, Dict[str, np.ndarray]]], bool]:
    """Read a journal file without opening it for append: all intact
    records since the last truncate → ``(records, torn_tail)``. Never
    raises on framing damage — a bad frame ends the replay (everything
    after it is unreachable by design) — and never touches the
    directory, so a read-only opener can replay a LIVE writer's WAL."""
    try:
        with open(str(path), "rb") as f:
            blob = f.read()
    except OSError:
        return [], False
    records, off = [], 0
    while True:
        if off == len(blob):
            return records, False
        hdr = blob[off: off + _FRAME_HDR.size]
        if len(hdr) < _FRAME_HDR.size:
            return records, True
        magic, plen, pcrc = _FRAME_HDR.unpack(hdr)
        payload = blob[off + _FRAME_HDR.size:
                       off + _FRAME_HDR.size + plen]
        if magic != _FRAME_MAGIC or len(payload) != plen \
                or zlib.crc32(payload) != pcrc:
            return records, True
        try:
            records.append(_unpack_record(payload))
        except Exception:
            return records, True
        off += _FRAME_HDR.size + plen


class Journal:
    """Append-only CRC-framed redo log. ``append`` fsyncs before
    returning (the WAL ordering contract: a record is durable before the
    arena bytes it describes are written); ``replay`` yields records in
    order and stops — without raising — at the first torn or corrupt
    frame, reporting the torn tail."""

    def __init__(self, path: str, *, fsync: bool = True,
                 faults: Optional[FaultInjector] = None):
        self.path = str(path)
        self._fsync = fsync
        self._faults = faults
        self._f = open(self.path, "ab")
        self.n_appends = 0

    @property
    def nbytes(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0

    def append(self, kind: str, arrays: Dict[str, np.ndarray]) -> None:
        payload = _pack_record(kind, arrays)
        frame = _FRAME_HDR.pack(_FRAME_MAGIC, len(payload),
                                zlib.crc32(payload)) + payload
        torn = fire(self._faults, "capacity.journal_torn")
        if torn is not None:
            # a crash mid-append: only a prefix of the frame reaches the
            # disk. Write the torn prefix durably, then fail the append —
            # in-process the caller degrades; on reopen, replay stops
            # cleanly at this frame (the un-journaled tail is lost).
            frac = float(torn.get("frac", 0.5))
            cut = max(_FRAME_HDR.size, int(len(frame) * frac))
            self._f.write(frame[:cut])
            self._f.flush()
            os.fsync(self._f.fileno())
            raise OSError("injected torn journal frame "
                          f"({cut}/{len(frame)} bytes hit the disk)")
        self._f.write(frame)
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())
        self.n_appends += 1

    def replay(self) -> Tuple[List[Tuple[str, Dict[str, np.ndarray]]], bool]:
        """All intact records since the last truncate → ``(records,
        torn_tail)``. Never raises on framing damage: a bad frame ends
        the replay (everything after it is unreachable by design)."""
        self._f.flush()
        return replay_journal(self.path)

    def truncate(self) -> None:
        """Drop every record (checkpoint absorbed them)."""
        self._f.truncate(0)
        self._f.seek(0)
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass


# ----------------------------------------------------------- capacity tier
class CapacityTier:
    """The durable disk tier: one mmap arena file per codec part plus an
    embedding arena, bookkeeping in a shadow-checkpointed manifest, and
    the WAL in front of every mutation.

    Layout of ``root``::

        MANIFEST.m3      format-3 bookkeeping (shadow-replaced)
        journal.wal      CRC-framed redo log since the last checkpoint
        LOCK             single-writer pidfile (O_EXCL; stale locks of
                         dead pids are reclaimed, live ones refused)
        part_<name>.dat  raw codec-part arena (mmap, grown by ftruncate)
        embs.dat         f32 embedding arena (mmap)

    Arena files carry an *epoch*: epoch 0 keeps the bare names above,
    epoch ``e`` > 0 uses ``part_<name>.e<e>.dat`` / ``embs.e<e>.dat``.
    ``compact`` rewrites the live rows densely into the next epoch's
    files and publishes the switch through the manifest (the usual
    shadow-checkpoint commit point), returning the retired slots' bytes
    to the filesystem; a crash at any instant leaves either the old
    epoch (plus stray new-epoch files, GC'd on reopen) or the new one.

    Opening a directory that already has a manifest *recovers* it:
    replay the journal (stopping at a torn tail), CRC-sweep every live
    row, retire mismatches, then checkpoint — so the post-recovery tier
    always verifies clean. The recovery report lands in
    ``self.recovery``.

    ``read_only=True`` (or ``CapacityTier.open(..., read_only=True)``)
    is the cross-process read-sharing leg (ROADMAP item 4): it BYPASSES
    the ``LOCK`` pidfile — a live writer may keep journaling — maps the
    arenas ``mode='r'`` (shared pages, zero-copy), and replays the WAL
    into an in-memory overlay instead of the arenas, so un-checkpointed
    appends are visible without writing a byte anywhere: no lock, no
    journal handle, no checkpoint, no arena growth. Every mutator
    raises ``MemoStoreError``.
    """

    MANIFEST = "MANIFEST.m3"
    JOURNAL = "journal.wal"
    LOCKFILE = "LOCK"

    def __init__(self, root: str, *, codec, embed_dim: int,
                 capacity: int = 64,
                 budget_bytes: Optional[int] = None,
                 faults: Optional[FaultInjector] = None,
                 fsync: bool = True, read_only: bool = False):
        self.root = str(root)
        self.codec = codec
        self.embed_dim = int(embed_dim)
        self.budget_bytes = budget_bytes
        self._faults = faults
        self._fsync = fsync
        self.read_only = bool(read_only)
        if not self.read_only:
            os.makedirs(self.root, exist_ok=True)
        self._lock_path = os.path.join(self.root, self.LOCKFILE)
        self._lock_held = False
        if not self.read_only:
            self._acquire_lock()
        self.recovery: Optional[dict] = None
        self.n_appended = 0
        self.n_retired = 0
        self.n_checkpoints = 0
        self.n_compactions = 0
        self._parts: List[np.memmap] = []
        self._embs: Optional[np.memmap] = None
        # read-only WAL overlay: slot → (part rows, emb row); empty (and
        # never consulted past a dict probe) in writer mode
        self._overlay: Dict[int, Tuple[Tuple[np.ndarray, ...],
                                       np.ndarray]] = {}
        self.journal: Optional[Journal] = None
        try:
            manifest = os.path.join(self.root, self.MANIFEST)
            if self.read_only:
                if not os.path.exists(manifest):
                    raise MemoStoreError(
                        f"cannot open capacity tier {self.root!r} "
                        f"read-only: no manifest (the tier was never "
                        f"checkpointed, or the path is wrong)")
                self._open_read_only(manifest)
            elif os.path.exists(manifest):
                self._recover(manifest)
            else:
                self._init_state(max(1, int(capacity)))
                self._map_arenas(self.capacity)
                self.journal = Journal(
                    os.path.join(self.root, self.JOURNAL),
                    fsync=fsync, faults=faults)
                self.checkpoint()
        except BaseException:
            self._release_lock()
            raise

    @classmethod
    def open(cls, root: str, *, codec, embed_dim: int,
             read_only: bool = False, **kw) -> "CapacityTier":
        """Open an existing tier directory. ``read_only=True`` shares it
        with a live writer (see the class docstring); ``False`` is the
        normal single-writer recovery path."""
        return cls(root, codec=codec, embed_dim=embed_dim,
                   read_only=read_only, **kw)

    def _require_writable(self, op: str) -> None:
        if self.read_only:
            raise MemoStoreError(
                f"capacity tier {self.root!r} was opened read_only: "
                f"{op} would mutate it (open a writer instance instead)")

    # ----------------------------------------------------- single-writer
    def _acquire_lock(self) -> None:
        """O_EXCL pidfile: exactly one process may journal this dir.
        A lock naming a dead pid (SIGKILL'd writer) or our own pid (a
        same-process reopen) is reclaimed; a different *live* pid is an
        actionable conflict — two writers interleaving one WAL would
        corrupt it silently."""
        for _ in range(16):
            try:
                fd = os.open(self._lock_path,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
            except FileExistsError:
                try:
                    with open(self._lock_path, "r") as f:
                        owner = int(f.read().strip() or "0")
                except (OSError, ValueError):
                    owner = 0           # unreadable/empty: treat as stale
                if owner != os.getpid() and _pid_alive(owner):
                    raise MemoStoreError(
                        f"capacity tier dir {self.root!r} is locked by "
                        f"live process {owner} ({self._lock_path!r}); a "
                        f"second writer would corrupt the journal — "
                        f"close that process, or delete the lockfile if "
                        f"it is wrong")
                try:                    # stale or our own: reclaim
                    os.unlink(self._lock_path)
                except OSError:
                    pass
                continue
            with os.fdopen(fd, "w") as f:
                f.write(f"{os.getpid()}\n")
            self._lock_held = True
            return
        raise MemoStoreError(
            f"could not acquire capacity-tier lock {self._lock_path!r} "
            f"(another process kept re-creating it)")

    def _release_lock(self) -> None:
        if not self._lock_held:
            return
        self._lock_held = False
        try:
            os.unlink(self._lock_path)
        except OSError:
            pass

    # ------------------------------------------------------------- state
    def _init_state(self, capacity: int) -> None:
        self.capacity = capacity
        self.epoch = 0
        self._n = 0
        self._live = np.zeros(capacity, bool)
        self._lens = np.full(capacity, -1, np.int32)
        self._reuse = np.zeros(capacity, np.int64)
        self._free: List[int] = []
        self._csums = [np.zeros(capacity, np.uint32)
                       for _ in self.codec.parts]
        self.extra_meta: dict = {}

    @property
    def entry_nbytes(self) -> int:
        return self.codec.entry_nbytes + self.embed_dim * 4

    @property
    def live_count(self) -> int:
        return int(np.count_nonzero(self._live[: self._n]))

    @property
    def nbytes(self) -> int:
        return self.live_count * self.entry_nbytes

    @property
    def live_slots(self) -> np.ndarray:
        return np.flatnonzero(self._live[: self._n])

    @property
    def retired_fraction(self) -> float:
        """Fraction of the allocated slot prefix that is a retired hole
        (reclaimable by ``compact``)."""
        return len(self._free) / max(1, int(self._n))

    # ------------------------------------------------------------- mmaps
    def _epoch_suffix(self, epoch: Optional[int] = None) -> str:
        e = self.epoch if epoch is None else int(epoch)
        return ".dat" if e == 0 else f".e{e}.dat"

    def _part_path(self, spec, epoch: Optional[int] = None) -> str:
        return os.path.join(
            self.root, f"part_{spec.name}{self._epoch_suffix(epoch)}")

    def _embs_path(self, epoch: Optional[int] = None) -> str:
        return os.path.join(self.root, f"embs{self._epoch_suffix(epoch)}")

    def _arena_paths(self, epoch: Optional[int] = None) -> List[str]:
        return [self._part_path(p, epoch) for p in self.codec.parts] \
            + [self._embs_path(epoch)]

    def _map_file(self, path: str, shape: Tuple[int, ...], dtype) -> np.memmap:
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        nbytes = max(1, nbytes)
        if not os.path.exists(path):
            open(path, "ab").close()
        if os.path.getsize(path) < nbytes:
            os.truncate(path, nbytes)
        return np.memmap(path, dtype=dtype, mode="r+", shape=shape)

    def _map_arenas(self, capacity: int) -> None:
        self._parts = [
            self._map_file(self._part_path(p), (capacity,) + p.shape,
                           p.dtype)
            for p in self.codec.parts]
        self._embs = self._map_file(self._embs_path(),
                                    (capacity, self.embed_dim), np.float32)

    def _map_file_ro(self, path: str, shape: Tuple[int, ...], dtype
                     ) -> np.memmap:
        """Read-only arena map: never creates or grows the file — a
        short/missing arena is the writer's bug (or the wrong dir), not
        something a reader may repair."""
        nbytes = max(1, int(np.prod(shape, dtype=np.int64))
                     * np.dtype(dtype).itemsize)
        try:
            size = os.path.getsize(path)
        except OSError:
            size = -1
        if size < nbytes:
            raise MemoStoreError(
                f"capacity arena {path!r} is missing or shorter than its "
                f"manifest says ({size} < {nbytes} bytes)")
        return np.memmap(path, dtype=dtype, mode="r", shape=shape)

    def _map_arenas_ro(self, capacity: int) -> None:
        self._parts = [
            self._map_file_ro(self._part_path(p), (capacity,) + p.shape,
                              p.dtype)
            for p in self.codec.parts]
        self._embs = self._map_file_ro(
            self._embs_path(), (capacity, self.embed_dim), np.float32)

    def _flush_arenas(self) -> None:
        if self.read_only:      # nothing dirty; 'r'-mode flush may raise
            return
        for m in self._parts:
            m.flush()
        if self._embs is not None:
            self._embs.flush()

    def _grow_to(self, need: int) -> None:
        if need <= self.capacity:
            return
        new_cap = max(2 * self.capacity, int(need))
        self._flush_arenas()
        self._parts, self._embs = [], None
        self._map_arenas(new_cap)
        for name in ("_live", "_lens", "_reuse"):
            old = getattr(self, name)
            fresh = np.full(new_cap, (-1 if name == "_lens" else 0),
                            old.dtype)
            fresh[: self._n] = old[: self._n]
            setattr(self, name, fresh)
        self._live = self._live.astype(bool)
        csums = []
        for c in self._csums:
            fresh = np.zeros(new_cap, np.uint32)
            fresh[: self._n] = c[: self._n]
            csums.append(fresh)
        self._csums = csums
        self.capacity = new_cap

    # ---------------------------------------------------------- mutation
    @staticmethod
    def _crc_rows(rows: np.ndarray) -> np.ndarray:
        rows = np.ascontiguousarray(rows)
        return np.asarray([zlib.crc32(rows[i].tobytes())
                           for i in range(rows.shape[0])], np.uint32)

    def _alloc(self, b: int) -> np.ndarray:
        n_reuse = min(b, len(self._free))
        slots = [self._free.pop() for _ in range(n_reuse)]
        if b > n_reuse:
            tail = b - n_reuse
            self._grow_to(self._n + tail)
            slots.extend(range(self._n, self._n + tail))
            self._n += tail
        return np.asarray(slots, np.int64)

    def append(self, parts: Sequence[np.ndarray], embs: np.ndarray,
               lens: np.ndarray,
               csums: Optional[Sequence[np.ndarray]] = None) -> np.ndarray:
        """Durably admit ``B`` encoded rows (WAL first, arenas second).
        Returns the assigned disk slots. The ``capacity.disk_write_io``
        fault fires here: with a ``stall_s`` rider it sleeps (the
        promotion-stall failure mode), without one it raises OSError
        before any state mutates."""
        self._require_writable("append")
        hit = fire(self._faults, "capacity.disk_write_io")
        if hit is not None:
            if "stall_s" in hit:
                time.sleep(float(hit["stall_s"]))
            else:
                raise OSError("injected capacity-tier disk write failure")
        parts = tuple(np.ascontiguousarray(p) for p in parts)
        embs = np.ascontiguousarray(np.asarray(embs, np.float32))
        lens = np.asarray(lens, np.int32).reshape(-1)
        b = int(embs.shape[0])
        if b == 0:
            return np.zeros(0, np.int64)
        if csums is None:
            csums = [self._crc_rows(p) for p in parts]
        csums = [np.asarray(c, np.uint32) for c in csums]
        slots = self._alloc(b)
        rec = {"slots": slots, "embs": embs, "lens": lens}
        for spec, p, c in zip(self.codec.parts, parts, csums):
            rec[f"part_{spec.name}"] = p
            rec[f"csum_{spec.name}"] = c
        self.journal.append("append", rec)
        for arena, p in zip(self._parts, parts):
            arena[slots] = p
        self._embs[slots] = embs
        if fire(self._faults, "capacity.mmap_bitflip") is not None:
            # flip one byte of the newest row's primary part WITHOUT
            # refreshing its checksum: verify()/promotion must catch it
            row = np.asarray(self._parts[0][int(slots[-1])])
            flipped = row.copy()
            flipped.view(np.uint8).reshape(-1)[0] ^= 0xFF
            self._parts[0][int(slots[-1])] = flipped
        self._lens[slots] = lens
        self._live[slots] = True
        self._reuse[slots] = 0
        for c, fresh in zip(self._csums, csums):
            c[slots] = fresh
        self.n_appended += b
        self._enforce_budget(exclude=slots)
        return slots

    def retire(self, slots: Sequence[int]) -> None:
        """Durably drop rows (quarantine or disk-budget eviction)."""
        self._require_writable("retire")
        slots = np.asarray(slots, np.int64).reshape(-1)
        slots = slots[(slots >= 0) & (slots < self._n)]
        slots = slots[self._live[slots]]
        if slots.size == 0:
            return
        self.journal.append("retire", {"slots": slots})
        self._apply_retire(slots)
        self.n_retired += int(slots.size)
        cb = getattr(self, "on_retire", None)
        if cb is not None:      # owner unlinks its slot maps before the
            cb(slots)           # freed disk slots can be recycled


    def _apply_retire(self, slots: np.ndarray) -> None:
        for s in slots:
            s = int(s)
            if 0 <= s < self._n and self._live[s]:
                self._live[s] = False
                self._lens[s] = -1
                self._reuse[s] = 0
                self._free.append(s)

    def _enforce_budget(self, exclude: Optional[np.ndarray] = None) -> None:
        if self.budget_bytes is None:
            return
        cap = max(1, int(self.budget_bytes) // self.entry_nbytes)
        over = self.live_count - cap
        if over <= 0:
            return
        live = self.live_slots
        if exclude is not None and live.size > over:
            keep_new = live[~np.isin(live, exclude)]
            if keep_new.size >= over:
                live = keep_new
        order = live[np.argsort(self._reuse[live], kind="stable")]
        self.retire(order[:over])

    def note_reuse(self, slots: Sequence[int]) -> None:
        slots = np.asarray(slots, np.int64).reshape(-1)
        if slots.size:
            np.add.at(self._reuse, slots, 1)

    # -------------------------------------------------------- compaction
    def compact(self) -> dict:
        """Rewrite the live rows densely into the next epoch's arena
        files and return the retired holes' bytes to the filesystem.

        Commit protocol: stage the new epoch's files (dense copies,
        flushed), then publish the switch by checkpointing a manifest
        that names the new epoch — the same shadow-replace that commits
        every other mutation. ``capacity.compact_crash`` fires after the
        staging, before the publish: recovery then reopens the OLD epoch
        (manifest + journal untouched) and GC's the stray new-epoch
        files. Old slot ``live_slots[i]`` becomes new slot ``i``; the
        ``on_compact(old_slots, new_slots)`` callback (fired after the
        publish) lets the owner remap its host↔disk slot tables."""
        self._require_writable("compact")
        old_epoch = self.epoch
        old_paths = self._arena_paths(old_epoch)
        old_bytes = sum(os.path.getsize(p) for p in old_paths
                        if os.path.exists(p))
        live = self.live_slots
        nl = int(live.size)
        new_cap = max(1, nl)
        self._flush_arenas()
        self.epoch = old_epoch + 1
        try:
            new_parts = [
                self._map_file(self._part_path(p), (new_cap,) + p.shape,
                               p.dtype)
                for p in self.codec.parts]
            new_embs = self._map_file(self._embs_path(),
                                      (new_cap, self.embed_dim),
                                      np.float32)
            for dst, src in zip(new_parts, self._parts):
                dst[:nl] = src[live]
            new_embs[:nl] = self._embs[live]
            for m in new_parts:
                m.flush()
            new_embs.flush()
            if fire(self._faults, "capacity.compact_crash") is not None:
                raise OSError(
                    f"injected crash mid-compaction (epoch "
                    f"{self.epoch} staged, manifest still at epoch "
                    f"{old_epoch})")
        except BaseException:
            # nothing published: the manifest still names the old epoch
            # and its arenas were never written — roll the in-memory
            # epoch back (stray new-epoch files are GC'd on reopen)
            self.epoch = old_epoch
            raise
        self._parts, self._embs = new_parts, new_embs
        reclaimed = int(self._n) - nl
        for name, fill in (("_live", True), ("_lens", -1), ("_reuse", 0)):
            old = getattr(self, name)
            fresh = np.full(new_cap, fill, old.dtype)
            fresh[:nl] = old[live]
            setattr(self, name, fresh)
        self._live[nl:] = False
        self._csums = [np.concatenate(
            [c[live], np.zeros(new_cap - nl, np.uint32)]).astype(np.uint32)
            for c in self._csums]
        self._n = nl
        self.capacity = new_cap
        self._free = []
        self.checkpoint()               # the commit point (new epoch)
        cb = getattr(self, "on_compact", None)
        if cb is not None:
            cb(live, np.arange(nl, dtype=np.int64))
        for p in old_paths:             # best-effort: reopen GC's strays
            try:
                os.remove(p)
            except OSError:
                pass
        self.n_compactions += 1
        new_bytes = sum(os.path.getsize(p)
                        for p in self._arena_paths(self.epoch)
                        if os.path.exists(p))
        return {"epoch": int(self.epoch), "live": nl,
                "slots_reclaimed": reclaimed,
                "bytes_returned": max(0, old_bytes - new_bytes)}

    def _gc_stray_epochs(self) -> None:
        """Remove arena files from any epoch other than the current one
        — the debris of a compaction that crashed before (stray new
        epoch) or after (undeleted old epoch) its manifest publish."""
        keep = {os.path.basename(p) for p in self._arena_paths()}
        pat = re.compile(r"^(?:part_.+?|embs)(?:\.e\d+)?\.dat$")
        for f in os.listdir(self.root):
            if f not in keep and pat.match(f):
                try:
                    os.remove(os.path.join(self.root, f))
                except OSError:
                    pass

    # ------------------------------------------------------------- reads
    def rows_at(self, slots: Sequence[int]) -> Tuple[
            Tuple[np.ndarray, ...], np.ndarray, np.ndarray,
            Tuple[np.ndarray, ...]]:
        """Raw encoded rows → ``(parts, embs, lens, csums)`` (copies —
        the caller re-verifies the CRCs before promoting). Read-only
        instances serve WAL-overlay rows over the mapped arena bytes
        (a live writer's un-checkpointed appends; possibly past the
        arena's mapped capacity)."""
        slots = np.asarray(slots, np.int64).reshape(-1)
        # overlay slots may exceed the mapped capacity — clamp the arena
        # gather (those rows are overwritten from the overlay below)
        safe = np.clip(slots, 0, self.capacity - 1)
        parts = [np.asarray(a[safe]).copy() for a in self._parts]
        embs = np.asarray(self._embs[safe]).copy()
        if self._overlay:
            for j, s in enumerate(slots):
                row = self._overlay.get(int(s))
                if row is not None:
                    for p, pr in zip(parts, row[0]):
                        p[j] = pr
                    embs[j] = row[1]
        return (tuple(parts), embs, self._lens[slots].copy(),
                tuple(c[slots].copy() for c in self._csums))

    def verify(self, slots: Optional[Sequence[int]] = None) -> np.ndarray:
        """Recompute per-part row CRCs (default: every live row) →
        slot ids whose bytes drifted since they were journaled."""
        if slots is None:
            slots = self.live_slots
        else:
            slots = np.asarray(slots, np.int64).reshape(-1)
            slots = slots[(slots >= 0) & (slots < self._n)]
            slots = slots[self._live[slots]]
        if slots.size == 0:
            return np.zeros(0, np.int64)
        # rows_at (not a raw arena gather) so overlay rows verify against
        # their journaled bytes rather than the writer's arena state
        parts, _, _, csums = self.rows_at(slots)
        bad = np.zeros(slots.shape[0], bool)
        for rows, csum in zip(parts, csums):
            bad |= self._crc_rows(rows) != csum
        return slots[bad].astype(np.int64)

    def search(self, queries: np.ndarray, k: int = 1
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact L2 over the live embedding rows → ``(sq_dists (B,k),
        slots (B,k))``; dead rows can never win. The disk tier is
        searched only at promotion time (maintenance cadence), so a
        plain numpy matmul over the mmap is the right cost model — the
        OS page cache is the 'big memory' here."""
        q = np.asarray(queries, np.float32)
        live = self.live_slots
        if live.size == 0:
            return (np.full((q.shape[0], k), np.inf, np.float32),
                    np.full((q.shape[0], k), -1, np.int64))
        embs = np.asarray(self._embs[np.clip(live, 0,
                                             self.capacity - 1)]).copy()
        if self._overlay:
            for j, s in enumerate(live):
                row = self._overlay.get(int(s))
                if row is not None:
                    embs[j] = row[1]
        d2 = (np.sum(q * q, -1, keepdims=True)
              - 2.0 * q @ embs.T + np.sum(embs * embs, -1)[None, :])
        k = min(k, live.size)
        idx = np.argsort(d2, axis=-1, kind="stable")[:, :k]
        rows = np.take_along_axis(d2, idx, -1)
        pad = np.full((q.shape[0], max(0, k - idx.shape[1])), np.inf)
        return (np.concatenate([rows, pad], -1).astype(np.float32),
                np.concatenate(
                    [live[idx],
                     np.full((q.shape[0], pad.shape[1]), -1, np.int64)],
                    -1))

    # -------------------------------------------------------- durability
    def checkpoint(self, extra_meta: Optional[dict] = None) -> None:
        """Flush the arenas, shadow-replace the manifest, truncate the
        journal — the WAL absorb point. ``capacity.checkpoint_crash``
        fires between the manifest temp write and its publish, leaving
        the OLD manifest + the intact journal (still recoverable)."""
        self._require_writable("checkpoint")
        if extra_meta is not None:
            self.extra_meta = dict(extra_meta)
        self._flush_arenas()
        n = self._n
        arrays = {
            "n": np.asarray(n, np.int64),
            "live": self._live[:n].copy(),
            "lens": self._lens[:n].copy(),
            "reuse": self._reuse[:n].copy(),
            "free": np.asarray(self._free, np.int64),
        }
        for spec, c in zip(self.codec.parts, self._csums):
            arrays[f"csum_{spec.name}"] = c[:n].copy()
        meta = {"capacity": int(self.capacity),
                "embed_dim": self.embed_dim,
                "codec": self.codec.name,
                "epoch": int(self.epoch),
                "extra": self.extra_meta}
        write_format3(os.path.join(self.root, self.MANIFEST), meta, arrays,
                      fsync=self._fsync, faults=self._faults,
                      fault_point="capacity.checkpoint_crash",
                      fault_raises=True)
        self.journal.truncate()
        self.n_checkpoints += 1

    def _recover(self, manifest: str) -> None:
        meta, arrays = read_format3(manifest, verify=True)
        n = int(arrays["n"])
        cap = max(1, int(meta.get("capacity", n or 1)), n)
        self._init_state(cap)
        self.epoch = int(meta.get("epoch", 0))
        self._n = n
        self._live[:n] = arrays["live"]
        self._lens[:n] = arrays["lens"]
        self._reuse[:n] = arrays["reuse"]
        self._free = [int(s) for s in arrays["free"]]
        for i, spec in enumerate(self.codec.parts):
            saved = arrays.get(f"csum_{spec.name}")
            if saved is None:
                raise MemoStoreError(
                    f"capacity manifest {manifest!r} was written for a "
                    f"different codec (missing csum_{spec.name})")
            self._csums[i][:n] = saved
        self.extra_meta = dict(meta.get("extra") or {})
        self._map_arenas(self.capacity)
        # redo the journal in order; a torn tail ends the replay cleanly
        self.journal = Journal(os.path.join(self.root, self.JOURNAL),
                               fsync=self._fsync, faults=self._faults)
        records, torn = self.journal.replay()
        for kind, rec in records:
            slots = np.asarray(rec["slots"], np.int64).reshape(-1)
            if kind == "retire":
                self._apply_retire(slots)
                continue
            self._grow_to(int(slots.max()) + 1 if slots.size else 0)
            self._n = max(self._n, int(slots.max()) + 1 if slots.size else 0)
            taken = set(int(s) for s in slots)
            self._free = [s for s in self._free if s not in taken]
            for arena, spec in zip(self._parts, self.codec.parts):
                arena[slots] = rec[f"part_{spec.name}"]
            for c, spec in zip(self._csums, self.codec.parts):
                c[slots] = np.asarray(rec[f"csum_{spec.name}"], np.uint32)
            self._embs[slots] = np.asarray(rec["embs"], np.float32)
            self._lens[slots] = np.asarray(rec["lens"], np.int32)
            self._live[slots] = True
            self._reuse[slots] = 0
        # every surviving live row must verify — rows torn mid-arena-write
        # (journaled but the mmap bytes never hit the disk) were just
        # rewritten by the replay above; anything still mismatching is
        # real corruption and gets retired (quarantine-through-retire)
        bad = self.verify()
        if bad.size:
            self._apply_retire(bad)
        self.recovery = {"n_replayed": len(records),
                         "torn_tail": bool(torn),
                         "n_quarantined": int(bad.size),
                         "live_after": self.live_count}
        self.checkpoint()
        self._gc_stray_epochs()

    def _grow_state_to(self, need: int) -> None:
        """Read-only bookkeeping growth: a live writer's WAL can name
        slots past the manifest's capacity (it grew its arenas after the
        last checkpoint). Those rows live in the overlay, so only the
        in-memory bookkeeping arrays grow — the mapped arenas (and
        ``self.capacity``, which describes them) stay untouched."""
        if need <= self._live.shape[0]:
            return
        new_cap = max(2 * self._live.shape[0], int(need))
        for name, fill in (("_live", 0), ("_lens", -1), ("_reuse", 0)):
            old = getattr(self, name)
            fresh = np.full(new_cap, fill, old.dtype)
            fresh[: self._n] = old[: self._n]
            setattr(self, name, fresh)
        self._live = self._live.astype(bool)
        self._csums = [
            np.concatenate([c, np.zeros(new_cap - c.shape[0], np.uint32)])
            for c in self._csums]

    def _open_read_only(self, manifest: str) -> None:
        """Recovery's read-only twin: manifest + journal replay, but the
        replayed rows land in ``self._overlay`` (the arenas belong to
        the writer) and nothing is swept, retired or checkpointed — a
        reader reports what it sees, it never repairs."""
        meta, arrays = read_format3(manifest, verify=True)
        n = int(arrays["n"])
        cap = max(1, int(meta.get("capacity", n or 1)), n)
        self._init_state(cap)
        self.epoch = int(meta.get("epoch", 0))
        self._n = n
        self._live[:n] = arrays["live"]
        self._lens[:n] = arrays["lens"]
        self._reuse[:n] = arrays["reuse"]
        self._free = [int(s) for s in arrays["free"]]
        for i, spec in enumerate(self.codec.parts):
            saved = arrays.get(f"csum_{spec.name}")
            if saved is None:
                raise MemoStoreError(
                    f"capacity manifest {manifest!r} was written for a "
                    f"different codec (missing csum_{spec.name})")
            self._csums[i][:n] = saved
        self.extra_meta = dict(meta.get("extra") or {})
        self._map_arenas_ro(self.capacity)
        records, torn = replay_journal(
            os.path.join(self.root, self.JOURNAL))
        for kind, rec in records:
            slots = np.asarray(rec["slots"], np.int64).reshape(-1)
            if kind == "retire":
                self._apply_retire(slots)
                for s in slots:
                    self._overlay.pop(int(s), None)
                continue
            top = int(slots.max()) + 1 if slots.size else 0
            self._grow_state_to(top)
            self._n = max(self._n, top)
            taken = set(int(s) for s in slots)
            self._free = [s for s in self._free if s not in taken]
            for j, s in enumerate(slots):
                self._overlay[int(s)] = (
                    tuple(np.asarray(rec[f"part_{spec.name}"][j])
                          for spec in self.codec.parts),
                    np.asarray(rec["embs"][j], np.float32))
            for c, spec in zip(self._csums, self.codec.parts):
                c[slots] = np.asarray(rec[f"csum_{spec.name}"], np.uint32)
            self._lens[slots] = np.asarray(rec["lens"], np.int32)
            self._live[slots] = True
            self._reuse[slots] = 0
        self.recovery = {"n_replayed": len(records),
                         "torn_tail": bool(torn),
                         "read_only": True,
                         "overlay_rows": len(self._overlay),
                         "live_after": self.live_count}

    def flush(self) -> None:
        self._flush_arenas()

    def close(self) -> None:
        try:
            self._flush_arenas()
        except (OSError, ValueError):
            pass
        try:
            if self.journal is not None:
                self.journal.close()
        finally:
            self._release_lock()

    def stats(self) -> dict:
        return {"live": self.live_count,
                "bytes": self.nbytes,
                "capacity": int(self.capacity),
                "epoch": int(self.epoch),
                "appended": self.n_appended,
                "retired": self.n_retired,
                "retired_fraction": self.retired_fraction,
                "checkpoints": self.n_checkpoints,
                "compactions": self.n_compactions,
                "journal_bytes": self.journal.nbytes,
                "recovery": self.recovery}

"""Selective memoization — per-layer performance model (paper §5.4).

Eq. 3:  PBⁱ = Tⁱ_attn · αⁱ − Tⁱ_overhead.
Memoization is attempted at layer i only when PBⁱ > 0. The offline profiler
measures Tⁱ_attn (the attention compute being replaced), Tⁱ_overhead
(embedding + index search + APM fetch) and αⁱ (the calibration memo rate)
during database construction. At serve time the times scale ~linearly with
the token count, so a single ``scale`` knob adapts the decision to the
request batch (paper: "approximate linear scaling").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class LayerProfile:
    t_attn: float = 0.0          # seconds per calibration batch
    t_overhead: float = 0.0
    alpha: float = 0.0           # memo success rate at this layer


@dataclass
class PerfModel:
    profiles: Dict[int, LayerProfile] = field(default_factory=dict)

    def benefit(self, layer: int, scale: float = 1.0) -> float:
        p = self.profiles.get(layer)
        if p is None:
            return -1.0
        return (p.t_attn * p.alpha - p.t_overhead) * scale

    def active_layers(self, scale: float = 1.0) -> List[int]:
        return [i for i in sorted(self.profiles)
                if self.benefit(i, scale) > 0.0]

    def summary(self) -> str:
        rows = ["layer  t_attn(ms)  t_over(ms)  alpha   PB(ms)  memoize?"]
        for i in sorted(self.profiles):
            p = self.profiles[i]
            pb = self.benefit(i) * 1e3
            rows.append(f"{i:5d}  {p.t_attn*1e3:9.3f}  {p.t_overhead*1e3:9.3f}"
                        f"  {p.alpha:5.2f}  {pb:7.3f}  "
                        f"{'yes' if pb > 0 else 'no'}")
        return "\n".join(rows)


def timeit_median(fn, *args, reps: int = 5) -> float:
    """Median wall time of a (jitted) callable; blocks on the result."""
    import jax
    fn(*args)                                    # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]

"""Similarity metrics from the paper.

Eq. 1 — total-variation similarity between attention probability matrices:
    SC(A, A') = 1 - (1/L) Σ_p ½ ‖A[p,:] − A'[p,:]‖₁   ∈ [0, 1]
Eq. 2 — memoization rate: ms = M / (N·L).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def similarity_score(a, a_prime):
    """TV similarity. a, a_prime: (L, L) → scalar; (H, L, L) → scalar
    (head-averaged); (B, H, L, L) → (B,)."""
    tv = 0.5 * jnp.sum(jnp.abs(a.astype(jnp.float32)
                               - a_prime.astype(jnp.float32)), axis=-1)
    if a.ndim <= 3:
        return 1.0 - jnp.mean(tv)
    return 1.0 - jnp.mean(tv, axis=tuple(range(1, a.ndim - 1)))


def memo_rate(n_memoized: int, n_inputs: int, n_layers: int) -> float:
    """Eq. 2."""
    return n_memoized / float(n_inputs * n_layers)


@jax.jit
def pairwise_similarity(a_batch, b_batch):
    """a_batch: (N, H, L, L) vs b_batch: (M, H, L, L) → (N, M) head-averaged
    similarity matrix (memory-safe lax.map over N)."""
    def one(a):
        tv = 0.5 * jnp.sum(jnp.abs(a[None].astype(jnp.float32)
                                   - b_batch.astype(jnp.float32)), axis=-1)
        return 1.0 - jnp.mean(tv, axis=tuple(range(1, tv.ndim)))
    return jax.lax.map(one, a_batch)

"""Index database — ANN search over hidden-state embeddings (paper §5.3).

The paper uses Faiss HNSW; HNSW's sequential graph walk is hostile to TPUs
and to SPMD, so we provide matmul-shaped indexes (DESIGN.md §2):

* ``ExactIndex``  — exact batched L2 top-k (the oracle; also fast on MXU:
                    ‖q‖² − 2·q·Dᵀ + ‖d‖² is one matmul).
* ``IVFIndex``    — k-means coarse quantizer + exact search in the nprobe
                    nearest lists; sub-linear in N like HNSW, but batched.
* ``DeviceIndex`` — the serving tier: the embedding table is a device
                    array and search is traceable inside a jit (streaming
                    Pallas ``nn_search`` on TPU, one-matmul fallback on
                    CPU/interpret, ``distributed_search`` under a mesh),
                    so the engine's embed→search→threshold→gather pipeline
                    never leaves the accelerator.

All three share the host ``search`` API returning (distances, indices);
the engine converts distance → predicted similarity (the Siamese loss
trains ‖e₁−e₂‖ ≈ 1 − SC).

Index rows are slot-aligned with the `AttentionDB` arena so the MemoStore
lifecycle can admit/evict without compaction: ``assign`` writes embeddings
at explicit slots (growing with sentinel padding) and ``remove``
tombstones slots by overwriting them with ``TOMBSTONE`` — a far-away
finite value, so dead slots can never win a nearest-neighbor search yet
the distance math stays NaN-free (±inf would poison the matmul form
``‖q‖² − 2qDᵀ + ‖d‖²``).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# sentinel coordinate for dead/slack index rows: large enough that a dead
# row's distance dwarfs any live one (dim·1e12 vs O(1) embeddings), small
# enough that its square stays comfortably inside float32
TOMBSTONE = 1.0e6


def _grown(arr: Optional[np.ndarray], need: int, dim: int) -> np.ndarray:
    """Geometric numpy growth with TOMBSTONE-filled slack."""
    cap = 0 if arr is None else arr.shape[0]
    if need <= cap:
        return arr
    new_cap = max(need, 2 * cap, 8)
    out = np.full((new_cap, dim), TOMBSTONE, np.float32)
    if arr is not None and cap:
        out[:cap] = arr
    return out


class ExactIndex:
    def __init__(self, dim: int):
        self.dim = dim
        self._embs: Optional[np.ndarray] = None

    def __len__(self):
        return 0 if self._embs is None else self._embs.shape[0]

    def add(self, embs: np.ndarray):
        embs = np.asarray(embs, np.float32)
        self._embs = (embs if self._embs is None
                      else np.concatenate([self._embs, embs], 0))

    def assign(self, slots: Sequence[int], embs: np.ndarray):
        """Slot-aligned write (admission into recycled or fresh slots)."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        self._embs = _grown(self._embs, int(slots.max()) + 1, self.dim)
        self._embs[slots] = np.asarray(embs, np.float32)

    def remove(self, slots: Sequence[int]):
        """Tombstone slots: they keep their row (slot ids stay stable) but
        can never be returned by a search against live entries."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size and self._embs is not None:
            self._embs[slots] = TOMBSTONE

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """q: (B, dim) → (dists (B,k) L2, idx (B,k))."""
        d2 = _sq_dists(jnp.asarray(q, jnp.float32),
                       jnp.asarray(self._embs))
        if k == 1:
            idx = jnp.argmin(d2, -1)
            dist = jnp.take_along_axis(d2, idx[:, None], -1)
            out = (np.sqrt(np.maximum(np.asarray(dist), 0.0)),
                   np.asarray(idx)[:, None])
        else:
            neg, idx = jax.lax.top_k(-d2, k)
            out = (np.sqrt(np.maximum(-np.asarray(neg), 0.0)),
                   np.asarray(idx))
        return out


@jax.jit
def _sq_dists(q, d):
    qn = jnp.sum(q * q, -1, keepdims=True)
    dn = jnp.sum(d * d, -1)
    return qn - 2.0 * (q @ d.T) + dn[None, :]


class IVFIndex:
    """k-means coarse quantizer; lists stored as a padded dense array so the
    probe search stays one gather + one matmul."""

    def __init__(self, dim: int, n_lists: int = 16, nprobe: int = 4,
                 kmeans_iters: int = 10, seed: int = 0):
        self.dim = dim
        self.n_lists = n_lists
        self.nprobe = min(nprobe, n_lists)
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self._embs: Optional[np.ndarray] = None
        self._built = False

    def __len__(self):
        return 0 if self._embs is None else self._embs.shape[0]

    def add(self, embs: np.ndarray):
        embs = np.asarray(embs, np.float32)
        self._embs = (embs if self._embs is None
                      else np.concatenate([self._embs, embs], 0))
        self._built = False

    def assign(self, slots: Sequence[int], embs: np.ndarray):
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        self._embs = _grown(self._embs, int(slots.max()) + 1, self.dim)
        self._embs[slots] = np.asarray(embs, np.float32)
        self._built = False

    def remove(self, slots: Sequence[int]):
        """Tombstoned rows land in (or become) a far-away cluster the
        coarse quantizer never probes for live queries."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size and self._embs is not None:
            self._embs[slots] = TOMBSTONE
            self._built = False

    def _build(self):
        x = self._embs
        n = x.shape[0]
        k = min(self.n_lists, n)
        rng = np.random.default_rng(self.seed)
        cent = x[rng.choice(n, k, replace=False)].copy()
        for _ in range(self.kmeans_iters):
            d2 = np.asarray(_sq_dists(jnp.asarray(x), jnp.asarray(cent)))
            assign = d2.argmin(1)
            for c in range(k):
                m = assign == c
                if m.any():
                    cent[c] = x[m].mean(0)
        d2 = np.asarray(_sq_dists(jnp.asarray(x), jnp.asarray(cent)))
        assign = d2.argmin(1)
        cap = max(1, int(np.bincount(assign, minlength=k).max()))
        lists = np.full((k, cap), -1, np.int64)
        fill = np.zeros(k, np.int64)
        for i, c in enumerate(assign):
            lists[c, fill[c]] = i
            fill[c] += 1
        self._cent = cent
        self._lists = lists
        self._padded = np.where(lists[..., None] >= 0, x[lists.clip(0)],
                                np.inf).astype(np.float32)
        self._built = True

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        if not self._built:
            self._build()
        q = np.asarray(q, np.float32)
        B = q.shape[0]
        dc = np.asarray(_sq_dists(jnp.asarray(q), jnp.asarray(self._cent)))
        probes = np.argsort(dc, 1)[:, : self.nprobe]           # (B, nprobe)
        cand_ids = self._lists[probes].reshape(B, -1)          # (B, nprobe*cap)
        cand = self._padded[probes].reshape(B, -1, self.dim)
        diff = cand - q[:, None]
        d2 = np.where(np.isfinite(cand).all(-1),
                      np.einsum("bcd,bcd->bc", diff, diff), np.inf)
        order = np.argsort(d2, 1)[:, :k]
        dist = np.sqrt(np.maximum(np.take_along_axis(d2, order, 1), 0.0))
        idx = np.take_along_axis(cand_ids, order, 1)
        return dist, idx


class DeviceIndex:
    """Device-resident exact top-k index — the serving tier (DESIGN.md §2).

    Unlike the host-tier indexes, the embedding table lives on the
    accelerator and ``search_device`` is pure jnp/Pallas, so the engine can
    trace it *inside* its fused lookup jit: no numpy round-trip, no host
    synchronization on the hot path. Backend selection:

    * TPU           — the streaming ``nn_search`` Pallas kernel (the DB
                      tiles stream HBM→VMEM; running argmin in VMEM).
    * CPU/interpret — the ExactIndex one-matmul formulation (running the
                      kernel under the Pallas interpreter would be strictly
                      slower than XLA's fused matmul).
    * mesh          — ``distributed_search``: per-shard local argmin + a
                      small all-gather (the multi-host pod case).
    """

    def __init__(self, dim: int, *, use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None, block_q: int = 128,
                 block_n: int = 512, mesh=None, db_axis: str = "data",
                 capacity: int = 0):
        self.dim = dim
        self.interpret = (jax.default_backend() == "cpu"
                          if interpret is None else interpret)
        # matmul fallback under interpret/CPU unless the kernel is forced
        self.use_kernel = ((not self.interpret) if use_kernel is None
                           else use_kernel)
        self.block_q = block_q
        self.block_n = block_n
        self.mesh = mesh
        self.db_axis = db_axis
        self._table: Optional[jnp.ndarray] = None
        self._n = 0
        self.transfer_bytes = 0
        if capacity:
            self._ensure_capacity(capacity)

    def __len__(self):
        return self._n

    @property
    def capacity(self) -> int:
        return 0 if self._table is None else self._table.shape[0]

    @property
    def table(self) -> jnp.ndarray:
        """The full preallocated table (slack rows are TOMBSTONE, so they
        lose every distance comparison): constant shape across delta
        updates keeps downstream fused jits from recompiling."""
        return self._table

    # host-tier compat: numpy staging view (ExactIndex/IVFIndex expose this)
    @property
    def _embs(self):
        return None if self._table is None else np.asarray(
            self._table[: self._n])

    def _ensure_capacity(self, need: int):
        cap = self.capacity
        if need <= cap:
            return
        new_cap = max(need, 2 * cap, 8)
        table = jnp.full((new_cap, self.dim), TOMBSTONE, jnp.float32)
        if self._n:
            table = table.at[: self._n].set(self._table[: self._n])
        self._table = table
        self.transfer_bytes += self._n * self.dim * 4   # prefix re-upload

    def add(self, embs):
        embs = jnp.asarray(embs, jnp.float32)
        b = embs.shape[0]
        self._ensure_capacity(self._n + b)
        self._table = self._table.at[self._n: self._n + b].set(embs)
        self._n += b
        self.transfer_bytes += int(embs.nbytes)

    def assign(self, slots: Sequence[int], embs):
        """Slot-aligned delta write (device-side ``.at[slots].set``): the
        MemoStore sync path for admissions/overwrites — only the changed
        rows cross the host→device link (padded to a power-of-2 row count
        so XLA compiles log2(N) scatter shapes, not one per delta size)."""
        from repro.core.database import pad_delta_pow2
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        n_max = int(slots.max())
        self._ensure_capacity(n_max + 1)
        slots, values = pad_delta_pow2(slots, np.asarray(embs, np.float32))
        values = jnp.asarray(values)
        self._table = self._table.at[jnp.asarray(slots)].set(values)
        self._n = max(self._n, n_max + 1)
        self.transfer_bytes += int(values.nbytes + slots.size * 4)

    def remove(self, slots: Sequence[int]):
        from repro.core.database import pad_delta_pow2
        slots = np.asarray(slots).reshape(-1)
        if slots.size and self._table is not None:
            slots, _ = pad_delta_pow2(slots)
            self._table = self._table.at[jnp.asarray(slots)].set(TOMBSTONE)
            self.transfer_bytes += int(slots.size * 4)

    def search_device(self, q, k: int = 1, *, table: Optional[jnp.ndarray]
                      = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Traceable search. q: (B, dim) device array →
        (sq_dists (B, k), idx (B, k)) device arrays — SQUARED L2, unlike the
        host API (sqrt belongs to the caller's fused sim calculation).
        ``table`` lets a jitted caller pass the table as a traced argument
        so index growth re-specializes instead of staleness."""
        t = self._table if table is None else table
        q = jnp.asarray(q, jnp.float32)
        if k == 1:
            if self.mesh is not None:
                from repro.core.database import distributed_search
                d2, idx = distributed_search(t, q, self.mesh,
                                             db_axis=self.db_axis)
            elif self.use_kernel:
                from repro.kernels.nn_search.ops import nn_search
                d2, idx = nn_search(q, t, block_q=self.block_q,
                                    block_n=self.block_n,
                                    interpret=self.interpret)
            else:
                d2 = _sq_dists(q, t)
                idx = jnp.argmin(d2, -1).astype(jnp.int32)
                d2 = jnp.take_along_axis(d2, idx[:, None], -1)[:, 0]
            return d2[:, None], idx[:, None]
        neg, idx = jax.lax.top_k(-_sq_dists(q, t), k)
        return -neg, idx.astype(jnp.int32)

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """Host-compat API, same contract as ExactIndex.search: L2 (not
        squared) distances as numpy."""
        d2, idx = self.search_device(jnp.asarray(q, jnp.float32), k)
        return (np.sqrt(np.maximum(np.asarray(d2), 0.0)), np.asarray(idx))


def recall_at_1(index, oracle: ExactIndex, queries) -> float:
    """Fraction of queries where the index returns the oracle's top-1."""
    _, ia = index.search(queries, 1)
    _, ib = oracle.search(queries, 1)
    return float((ia[:, 0] == ib[:, 0]).mean())

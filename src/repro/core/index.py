"""Index database — ANN search over hidden-state embeddings (paper §5.3).

The paper uses Faiss HNSW; HNSW's sequential graph walk is hostile to TPUs
and to SPMD, so we provide matmul-shaped indexes (DESIGN.md §2):

* ``ExactIndex``  — exact batched L2 top-k (the oracle; also fast on MXU:
                    ‖q‖² − 2·q·Dᵀ + ‖d‖² is one matmul).
* ``IVFIndex``    — k-means coarse quantizer + exact search in the nprobe
                    nearest lists; sub-linear in N like HNSW, but batched.
* ``DeviceIndex`` — the serving tier: the embedding table is a device
                    array and search is traceable inside a jit (streaming
                    Pallas ``nn_search`` on TPU, one-matmul fallback on
                    CPU/interpret, ``shard.mesh_search`` under a mesh),
                    so the engine's embed→search→threshold→gather pipeline
                    never leaves the accelerator.
* ``ClusteredDeviceIndex`` — the scale tier (DESIGN.md §2.6): an IVF
                    layout of the device table. k-means centroids route
                    each query to its ``nprobe`` nearest clusters; the
                    candidate set (member ids + a small exact-searched
                    overflow buffer of post-build admissions) is gathered
                    from an int8-quantized table (per-entry f16 scales)
                    and scored exactly. Search cost drops from O(N·D) to
                    O((C + nprobe·m + o)·D) while staying matmul/gather
                    shaped and traceable inside the engine's fused jit.

All three share the host ``search`` API returning (distances, indices);
the engine converts distance → predicted similarity (the Siamese loss
trains ‖e₁−e₂‖ ≈ 1 − SC).

Index rows are slot-aligned with the `AttentionDB` arena so the MemoStore
lifecycle can admit/evict without compaction: ``assign`` writes embeddings
at explicit slots (growing with sentinel padding) and ``remove``
tombstones slots by overwriting them with ``TOMBSTONE`` — a far-away
finite value, so dead slots can never win a nearest-neighbor search yet
the distance math stays NaN-free (±inf would poison the matmul form
``‖q‖² − 2qDᵀ + ‖d‖²``).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# sentinel coordinate for dead/slack index rows: large enough that a dead
# row's distance dwarfs any live one (dim·1e12 vs O(1) embeddings), small
# enough that its square stays comfortably inside float32
TOMBSTONE = 1.0e6


def _grown(arr: Optional[np.ndarray], need: int, dim: int) -> np.ndarray:
    """Geometric numpy growth with TOMBSTONE-filled slack."""
    cap = 0 if arr is None else arr.shape[0]
    if need <= cap:
        return arr
    new_cap = max(need, 2 * cap, 8)
    out = np.full((new_cap, dim), TOMBSTONE, np.float32)
    if arr is not None and cap:
        out[:cap] = arr
    return out


class ExactIndex:
    def __init__(self, dim: int):
        self.dim = dim
        self._embs: Optional[np.ndarray] = None

    def __len__(self):
        return 0 if self._embs is None else self._embs.shape[0]

    def add(self, embs: np.ndarray):
        embs = np.asarray(embs, np.float32)
        self._embs = (embs if self._embs is None
                      else np.concatenate([self._embs, embs], 0))

    def assign(self, slots: Sequence[int], embs: np.ndarray):
        """Slot-aligned write (admission into recycled or fresh slots)."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        self._embs = _grown(self._embs, int(slots.max()) + 1, self.dim)
        self._embs[slots] = np.asarray(embs, np.float32)

    def remove(self, slots: Sequence[int]):
        """Tombstone slots: they keep their row (slot ids stay stable) but
        can never be returned by a search against live entries."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size and self._embs is not None:
            self._embs[slots] = TOMBSTONE

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """q: (B, dim) → (dists (B,k) L2, idx (B,k))."""
        d2 = _sq_dists(jnp.asarray(q, jnp.float32),
                       jnp.asarray(self._embs))
        if k == 1:
            idx = jnp.argmin(d2, -1)
            dist = jnp.take_along_axis(d2, idx[:, None], -1)
            out = (np.sqrt(np.maximum(np.asarray(dist), 0.0)),
                   np.asarray(idx)[:, None])
        else:
            neg, idx = jax.lax.top_k(-d2, k)
            out = (np.sqrt(np.maximum(-np.asarray(neg), 0.0)),
                   np.asarray(idx))
        return out


@jax.jit
def _sq_dists(q, d):
    qn = jnp.sum(q * q, -1, keepdims=True)
    dn = jnp.sum(d * d, -1)
    return qn - 2.0 * (q @ d.T) + dn[None, :]


@jax.jit
def _sq_dists_cached(q, d, dn):
    """The matmul form with precomputed per-row ‖d‖² — the hot-path
    variant: DeviceIndex caches the norms per mutation generation, so a
    search is ONE matmul plus broadcasts instead of re-reducing the
    whole table."""
    qn = jnp.sum(q * q, -1, keepdims=True)
    return qn - 2.0 * (q @ d.T) + dn[None, :]


@jax.jit
def _row_norms(t):
    return jnp.sum(t * t, axis=-1)


def _kmeans(x: np.ndarray, k: int, iters: int, seed: int):
    """Plain Lloyd k-means (matmul-shaped assignment steps); returns
    (centroids (k, dim) f32, assignment (n,) int64). Shared by the host
    IVFIndex and the device ClusteredDeviceIndex build."""
    n = x.shape[0]
    k = max(1, min(k, n))
    rng = np.random.default_rng(seed)
    cent = x[rng.choice(n, k, replace=False)].copy()
    for _ in range(iters):
        d2 = np.asarray(_sq_dists(jnp.asarray(x), jnp.asarray(cent)))
        assign = d2.argmin(1)
        for c in range(k):
            m = assign == c
            if m.any():
                cent[c] = x[m].mean(0)
    d2 = np.asarray(_sq_dists(jnp.asarray(x), jnp.asarray(cent)))
    return cent, d2.argmin(1)


class IVFIndex:
    """k-means coarse quantizer; lists stored as a padded dense array so the
    probe search stays one gather + one matmul."""

    def __init__(self, dim: int, n_lists: int = 16, nprobe: int = 4,
                 kmeans_iters: int = 10, seed: int = 0):
        self.dim = dim
        self.n_lists = n_lists
        self.nprobe = min(nprobe, n_lists)
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self._embs: Optional[np.ndarray] = None
        self._built = False

    def __len__(self):
        return 0 if self._embs is None else self._embs.shape[0]

    def add(self, embs: np.ndarray):
        embs = np.asarray(embs, np.float32)
        self._embs = (embs if self._embs is None
                      else np.concatenate([self._embs, embs], 0))
        self._built = False

    def assign(self, slots: Sequence[int], embs: np.ndarray):
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        self._embs = _grown(self._embs, int(slots.max()) + 1, self.dim)
        self._embs[slots] = np.asarray(embs, np.float32)
        self._built = False

    def remove(self, slots: Sequence[int]):
        """Tombstoned rows land in (or become) a far-away cluster the
        coarse quantizer never probes for live queries."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size and self._embs is not None:
            self._embs[slots] = TOMBSTONE
            self._built = False

    def _build(self):
        x = self._embs
        n = x.shape[0]
        k = min(self.n_lists, n)
        cent, assign = _kmeans(x, k, self.kmeans_iters, self.seed)
        k = cent.shape[0]
        cap = max(1, int(np.bincount(assign, minlength=k).max()))
        lists = np.full((k, cap), -1, np.int64)
        fill = np.zeros(k, np.int64)
        for i, c in enumerate(assign):
            lists[c, fill[c]] = i
            fill[c] += 1
        self._cent = cent
        self._lists = lists
        self._padded = np.where(lists[..., None] >= 0, x[lists.clip(0)],
                                np.inf).astype(np.float32)
        self._built = True

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        if not self._built:
            self._build()
        q = np.asarray(q, np.float32)
        B = q.shape[0]
        dc = np.asarray(_sq_dists(jnp.asarray(q), jnp.asarray(self._cent)))
        probes = np.argsort(dc, 1)[:, : self.nprobe]           # (B, nprobe)
        cand_ids = self._lists[probes].reshape(B, -1)          # (B, nprobe*cap)
        cand = self._padded[probes].reshape(B, -1, self.dim)
        diff = cand - q[:, None]
        d2 = np.where(np.isfinite(cand).all(-1),
                      np.einsum("bcd,bcd->bc", diff, diff), np.inf)
        order = np.argsort(d2, 1)[:, :k]
        dist = np.sqrt(np.maximum(np.take_along_axis(d2, order, 1), 0.0))
        idx = np.take_along_axis(cand_ids, order, 1)
        return dist, idx


class DeviceIndex:
    """Device-resident exact top-k index — the serving tier (DESIGN.md §2).

    Unlike the host-tier indexes, the embedding table lives on the
    accelerator and ``search_device`` is pure jnp/Pallas, so the engine can
    trace it *inside* its fused lookup jit: no numpy round-trip, no host
    synchronization on the hot path. Backend selection:

    * TPU           — the streaming ``nn_search`` Pallas kernel (the DB
                      tiles stream HBM→VMEM; running argmin in VMEM).
    * CPU/interpret — the ExactIndex one-matmul formulation (running the
                      kernel under the Pallas interpreter would be strictly
                      slower than XLA's fused matmul).
    * mesh          — ``shard.mesh_search``: per-shard local argmin + a
                      small all-gather (the multi-host pod case).
    """

    def __init__(self, dim: int, *, use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None, block_q: int = 128,
                 block_n: int = 512, mesh=None, db_axis: str = "data",
                 capacity: int = 0):
        self.dim = dim
        self.interpret = (jax.default_backend() == "cpu"
                          if interpret is None else interpret)
        # matmul fallback under interpret/CPU unless the kernel is forced
        self.use_kernel = ((not self.interpret) if use_kernel is None
                           else use_kernel)
        self.block_q = block_q
        self.block_n = block_n
        self.mesh = mesh
        self.db_axis = db_axis
        self._table: Optional[jnp.ndarray] = None
        self._norms: Optional[jnp.ndarray] = None   # cached per generation
        self._n = 0
        self.transfer_bytes = 0
        if capacity:
            self._ensure_capacity(capacity)

    def __len__(self):
        return self._n

    @property
    def capacity(self) -> int:
        return 0 if self._table is None else self._table.shape[0]

    @property
    def table(self) -> jnp.ndarray:
        """The full preallocated table (slack rows are TOMBSTONE, so they
        lose every distance comparison): constant shape across delta
        updates keeps downstream fused jits from recompiling."""
        return self._table

    # host-tier compat: numpy staging view (ExactIndex/IVFIndex expose this)
    @property
    def _embs(self):
        return None if self._table is None else np.asarray(
            self._table[: self._n])

    def _ensure_capacity(self, need: int):
        cap = self.capacity
        if need <= cap:
            return
        new_cap = max(need, 2 * cap, 8)
        table = jnp.full((new_cap, self.dim), TOMBSTONE, jnp.float32)
        if self._n:
            table = table.at[: self._n].set(self._table[: self._n])
        self._table = table
        self._norms = None
        self.transfer_bytes += self._n * self.dim * 4   # prefix re-upload

    def add(self, embs):
        embs = jnp.asarray(embs, jnp.float32)
        b = embs.shape[0]
        self._ensure_capacity(self._n + b)
        self._table = self._table.at[self._n: self._n + b].set(embs)
        self._norms = None
        self._n += b
        self.transfer_bytes += int(embs.nbytes)

    def assign(self, slots: Sequence[int], embs):
        """Slot-aligned delta write (device-side ``.at[slots].set``): the
        MemoStore sync path for admissions/overwrites — only the changed
        rows cross the host→device link (padded to a power-of-2 row count
        so XLA compiles log2(N) scatter shapes, not one per delta size)."""
        from repro.core.database import pad_delta_pow2
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        n_max = int(slots.max())
        self._ensure_capacity(n_max + 1)
        slots, values = pad_delta_pow2(slots, np.asarray(embs, np.float32))
        values = jnp.asarray(values)
        self._table = self._table.at[jnp.asarray(slots)].set(values)
        self._norms = None
        self._n = max(self._n, n_max + 1)
        self.transfer_bytes += int(values.nbytes + slots.size * 4)

    def remove(self, slots: Sequence[int]):
        from repro.core.database import pad_delta_pow2
        slots = np.asarray(slots).reshape(-1)
        if slots.size and self._table is not None:
            slots, _ = pad_delta_pow2(slots)
            self._table = self._table.at[jnp.asarray(slots)].set(TOMBSTONE)
            self._norms = None
            self.transfer_bytes += int(slots.size * 4)

    @property
    def norms(self) -> Optional[jnp.ndarray]:
        """Cached per-row squared norms ‖d‖² of the FULL table (slack and
        TOMBSTONE rows included — their huge norms keep losing every
        comparison). Computed lazily ONCE per mutation generation
        (add/assign/remove/growth invalidate) and shipped inside
        ``search_args``, so every search this generation — the fused
        serving jit, the nn_search kernel, the host-compat API — reuses
        one O(N·D) reduction instead of recomputing ‖d‖² per query tile."""
        if self._norms is None and self._table is not None:
            self._norms = _row_norms(self._table)
        return self._norms

    @property
    def search_args(self):
        """The pytree of device arrays ``search_device`` consumes —
        jitted callers pass this as a traced argument so index growth or
        a rebuild re-specializes (shape change → retrace) instead of
        serving stale closures. Flat index: ``(table, row_norms)`` — the
        norms are the per-generation ‖d‖² cache (see ``norms``), so a
        StoreSnapshot publish freezes them alongside the table."""
        return (self._table, self.norms)

    def search_device(self, q, k: int = 1, *, table: Optional[jnp.ndarray]
                      = None, args=None, fused: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Traceable search. q: (B, dim) device array →
        (sq_dists (B, k), idx (B, k)) device arrays — SQUARED L2, unlike the
        host API (sqrt belongs to the caller's fused sim calculation).
        ``table``/``args`` let a jitted caller pass the index state as a
        traced argument so index growth re-specializes instead of
        staleness; ``args`` is the ``search_args`` tuple (a bare table is
        accepted for back-compat). ``fused=True`` is the scalar-prefetch
        prologue contract of the fused memo-attention dispatch: it forces
        the one-matmul XLA formulation even when the nn_search kernel is
        enabled, so a memoized layer issues exactly ONE Pallas kernel
        (memo_attention) — nn_search would be a second dispatch with an
        HBM round-trip between them."""
        norms = None
        if args is not None:
            if isinstance(args, tuple):
                table, norms = args
            else:
                table = args
        t = self._table if table is None else table
        if norms is None and table is None:
            norms = self.norms
        q = jnp.asarray(q, jnp.float32)
        if k == 1:
            if self.mesh is not None:
                from repro.core.shard import mesh_search
                d2, idx = mesh_search(t, q, self.mesh,
                                      db_axis=self.db_axis)
            elif self.use_kernel and not fused:
                from repro.kernels.nn_search.ops import nn_search
                d2, idx = nn_search(q, t, db_norms=norms,
                                    block_q=self.block_q,
                                    block_n=self.block_n,
                                    interpret=self.interpret)
            else:
                d2 = (_sq_dists(q, t) if norms is None
                      else _sq_dists_cached(q, t, norms))
                idx = jnp.argmin(d2, -1).astype(jnp.int32)
                d2 = jnp.take_along_axis(d2, idx[:, None], -1)[:, 0]
            return d2[:, None], idx[:, None]
        d2_all = (_sq_dists(q, t) if norms is None
                  else _sq_dists_cached(q, t, norms))
        neg, idx = jax.lax.top_k(-d2_all, k)
        return -neg, idx.astype(jnp.int32)

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """Host-compat API, same contract as ExactIndex.search: L2 (not
        squared) distances as numpy."""
        d2, idx = self.search_device(jnp.asarray(q, jnp.float32), k)
        return (np.sqrt(np.maximum(np.asarray(d2), 0.0)), np.asarray(idx))


class ClusteredDeviceIndex(DeviceIndex):
    """Two-stage clustered (IVF) device index — the serving tier once N
    outgrows the exhaustive-search crossover (DESIGN.md §2.6).

    The flat ``DeviceIndex`` is one (B, N) matmul: unbeatable small, but
    O(N·D) FLOPs *and* O(N·D) streamed bytes per layer per batch. This
    index routes first and scores second, with a layout chosen so every
    step is a dense BLAS/MXU op — no per-query gathers (a per-query
    (B, K, dim) candidate gather materializes more bytes than the
    exhaustive matmul reads, and is exactly the trap that makes naive
    IVF slower than brute force on wide batches):

    * **packed clusters** — int8-quantized member vectors stored
      contiguously per cluster: ``pvecs (C, m_pad, dim) int8`` +
      per-entry ``pscales (C, m_pad) f16`` + slot ids ``pids (C, m_pad)
      i32`` (−1 pads masked at score time). Cluster assignment is
      k-means with a **balance cap** (≤ ~1.5× the mean size; spillovers
      go to their next-nearest cluster with room), so ``m_pad`` — which
      every probe pays for — stays near N/C.
    * **batch-shared, vote-priority probes** — stage 1 scores centroids
      (one (B, C) matmul) and probes a single deduplicated set for the
      whole batch: every cluster that is some query's top-1 ranks ahead
      of every cluster that is no one's (votes form the integer part of
      the priority; normalized batch-min distance fills the remainder).
      Stage 1 is therefore exact per query whenever the batch's
      distinct top-1 clusters fit in ``nprobe`` — the serving regime,
      where batches are homogeneous (that is why memoization hits at
      all) — and degrades gracefully toward most-voted clusters on
      adversarially scattered batches. The probed blocks are whole
      contiguous rows (nprobe block copies, not B·K element gathers)
      and stage 2 is ONE dense (B, nprobe·m_pad) matmul against the
      dequantized candidates. Recall is measured, not assumed
      (tests/test_codec.py property test; benchmarks/serve_compress.py).
    * **overflow buffer** — entries admitted/overwritten since the last
      rebuild live in a small dense side table (``ovecs/oscales/oids``,
      power-of-2 padded) that is scored alongside every probe, so fresh
      admissions are findable immediately. Overwritten slots also patch
      their packed row in place (the value must be current even if the
      cluster is now wrong — a stale pointer is at worst a redundant
      candidate scored at its true distance). When the buffer exceeds
      ``rebuild_frac``·N, a host k-means rebuild folds everything back
      in (ships centroids + packed arrays — int8, NOT the f32 table).

    Quantization is symmetric per entry; candidates are scored as the
    true distance to the *quantized* point, whose error 2(d−q)·Δ
    vanishes as q → d: exactly the memo-hit regime, where the argmin
    must not flip. (The asymmetric exact-norm form was tried and
    rejected: its −2q·Δ error scales with ‖q‖.)

    Under a mesh, search falls back to ``shard.mesh_search`` over a
    lazily-cached dequantized f32 replica (the clustered stages are a
    single-replica optimization; the pod path keeps its O(shards·B)
    collective).

    search/search_device may return duplicate ids for k>1 (an entry can
    appear in both its packed row and the overflow buffer); top-1 — the
    serving path — is unaffected.
    """

    def __init__(self, dim: int, *, n_clusters: Optional[int] = None,
                 nprobe: int = 16, kmeans_iters: int = 8,
                 rebuild_frac: float = 0.25, balance_cap: float = 1.5,
                 seed: int = 0, interpret: Optional[bool] = None, mesh=None,
                 db_axis: str = "data", capacity: int = 0):
        self.dim = dim
        self.interpret = (jax.default_backend() == "cpu"
                          if interpret is None else interpret)
        self.use_kernel = False      # candidate scoring is one dense matmul
        self.block_q, self.block_n = 128, 512     # parent-API compat
        self.mesh = mesh
        self.db_axis = db_axis
        self.n_clusters = n_clusters
        self.nprobe = nprobe
        self.kmeans_iters = kmeans_iters
        self.rebuild_frac = rebuild_frac
        self.balance_cap = balance_cap
        self.seed = seed
        self._host: Optional[np.ndarray] = None      # f32 mirror (rebuilds)
        self._slot_loc: Optional[np.ndarray] = None  # (cap, 2) packed (c,pos)
        self._centroids: Optional[jnp.ndarray] = None
        self._pvecs: Optional[jnp.ndarray] = None    # (C, m_pad, dim) int8
        self._pscales: Optional[jnp.ndarray] = None  # (C, m_pad) f16
        self._pids: Optional[jnp.ndarray] = None     # (C, m_pad) i32
        self._overflow: List[int] = []               # slot ids, insert order
        self._opos: dict = {}                        # slot -> overflow pos
        self._overflow_base = 0                      # size seeded by rebuild
        self._ovecs: Optional[jnp.ndarray] = None
        self._oscales: Optional[jnp.ndarray] = None
        self._oids: Optional[jnp.ndarray] = None
        self._mesh_table: Optional[jnp.ndarray] = None
        # the atomically-published search pytree (see search_args): every
        # mutation path finishes by rebuilding this ONE tuple and assigning
        # it in a single reference write, so a reader on another thread
        # (the MemoServer serving loop, via a StoreSnapshot) either sees
        # the whole previous state or the whole new one — never a torn
        # mix of new centroids with old packed rows
        self._packed: Optional[tuple] = None
        self._built = False
        self._n = 0
        self.n_rebuilds = 0
        self.transfer_bytes = 0
        if capacity:
            self._ensure_capacity(capacity)

    # -------------------------------------------------------------- storage
    @staticmethod
    def _quant(rows: np.ndarray):
        from repro.core.codec import _quantize_rows
        return _quantize_rows(rows)

    @property
    def capacity(self) -> int:
        return 0 if self._host is None else self._host.shape[0]

    @property
    def table(self) -> Optional[jnp.ndarray]:
        """f32 replica of the live prefix (mesh fallback / debug only —
        lazily materialized from the host mirror, NOT the hot path)."""
        if self._host is None:
            return None
        if self._mesh_table is None:
            self._mesh_table = jnp.asarray(self._host)
            self.transfer_bytes += int(self._host.nbytes)
        return self._mesh_table

    @property
    def _embs(self):
        return None if self._host is None else self._host[: self._n]

    def _ensure_capacity(self, need: int):
        cap = self.capacity
        if need <= cap:
            return
        new_cap = max(need, 2 * cap, 8)
        host = np.full((new_cap, self.dim), TOMBSTONE, np.float32)
        loc = np.full((new_cap, 2), -1, np.int32)
        if self._host is not None and self._n:
            host[: self._n] = self._host[: self._n]
            loc[: self._n] = self._slot_loc[: self._n]
        self._host = host
        self._slot_loc = loc

    # ------------------------------------------------------------ mutation
    def add(self, embs):
        embs = np.asarray(embs, np.float32)
        b = embs.shape[0]
        if b == 0:
            return
        self._ensure_capacity(self._n + b)
        slots = np.arange(self._n, self._n + b)
        self._host[slots] = embs
        self._n += b
        self._on_rows_changed(slots)

    def assign(self, slots: Sequence[int], embs):
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0:
            return
        self._ensure_capacity(int(slots.max()) + 1)
        self._host[slots] = np.asarray(embs, np.float32)
        self._n = max(self._n, int(slots.max()) + 1)
        self._on_rows_changed(slots)

    def remove(self, slots: Sequence[int]):
        slots = np.asarray(slots).reshape(-1)
        if slots.size == 0 or self._host is None:
            return
        self._host[slots] = TOMBSTONE
        self._on_rows_changed(slots, removing=True)

    def _on_rows_changed(self, slots: np.ndarray, removing: bool = False):
        """Propagate mirror changes to the device copies. Pre-build this
        is a no-op (the first build covers everything); post-build it
        patches packed rows in place and routes new/overwritten slots
        through the overflow buffer."""
        self._mesh_table = None
        if not self._built:
            return
        slots = np.asarray(slots).reshape(-1)
        packed = slots[self._slot_loc[slots, 0] >= 0]
        if packed.size:
            self._patch_packed(packed)
        changed = [int(s) for s in slots if int(s) in self._opos]
        if not removing:
            for s in slots:
                s = int(s)
                if s not in self._opos:
                    self._opos[s] = len(self._overflow)
                    self._overflow.append(s)
                    changed.append(s)
        if changed:
            self._sync_overflow(changed=changed)
        # trigger on post-rebuild GROWTH only: the rebuild itself seeds
        # the buffer with balance-cap spills, which must not re-trigger
        grown = len(self._overflow) - getattr(self, "_overflow_base", 0)
        if grown > max(8, int(self.rebuild_frac * max(1, self._n))):
            self.rebuild()
        else:
            self._republish()

    def _republish(self):
        """Publish the current packed + overflow arrays as one tuple in a
        single (atomic under the GIL) reference assignment — the
        generation-publish protocol's index leg (DESIGN.md §2.7)."""
        self._packed = (self._centroids, self._pvecs, self._pscales,
                        self._pids, self._ovecs, self._oscales, self._oids)

    def _patch_packed(self, slots: np.ndarray):
        """Scatter current (possibly tombstoned) rows into their packed
        positions: values stay truthful even when the cluster is stale."""
        from repro.core.database import pad_delta_pow2
        locs = self._slot_loc[slots]                       # (k, 2)
        m_pad = self._pvecs.shape[1]
        flat = (locs[:, 0].astype(np.int64) * m_pad + locs[:, 1])
        codes, scales = self._quant(self._host[slots])
        flat, codes = pad_delta_pow2(flat, codes)
        _, scales = pad_delta_pow2(self._slot_loc[slots][:, 0], scales)
        fl = jnp.asarray(flat)
        C = self._pvecs.shape[0]
        self._pvecs = self._pvecs.reshape(C * m_pad, self.dim).at[fl].set(
            jnp.asarray(codes)).reshape(C, m_pad, self.dim)
        self._pscales = self._pscales.reshape(C * m_pad).at[fl].set(
            jnp.asarray(scales)).reshape(C, m_pad)
        self.transfer_bytes += int(codes.nbytes + scales.nbytes
                                   + flat.size * 4)

    def _sync_overflow(self, changed=None):
        """Ship the overflow side table (pow2-padded). A full re-upload
        happens only when the padded capacity changes (or on rebuild,
        ``changed=None``); otherwise exactly the changed positions move
        as a padded scatter — the same delta discipline as every other
        device array in the sync path."""
        from repro.core.database import pad_delta_pow2
        ids = np.asarray(self._overflow, np.int64)
        p = 1
        while p < max(1, ids.size):
            p *= 2
        if changed is None or self._oids is None or self._oids.shape[0] != p:
            vecs = np.zeros((p, self.dim), np.float32)
            if ids.size:
                vecs[: ids.size] = self._host[ids]
            codes, scales = self._quant(vecs)
            oids = np.full(p, -1, np.int32)
            oids[: ids.size] = ids
            self._ovecs = jnp.asarray(codes)
            self._oscales = jnp.asarray(scales)
            self._oids = jnp.asarray(oids)
            self.transfer_bytes += int(codes.nbytes + scales.nbytes
                                       + oids.nbytes)
            return
        pos = sorted({self._opos[int(s)] for s in changed
                      if int(s) in self._opos})
        if not pos:
            return
        pos = np.asarray(pos, np.int64)
        slot_ids = ids[pos]
        codes, scales = self._quant(self._host[slot_ids])
        pos_p, codes = pad_delta_pow2(pos, codes)
        _, scales = pad_delta_pow2(pos, scales)
        _, oid_vals = pad_delta_pow2(pos, slot_ids.astype(np.int32))
        pl = jnp.asarray(pos_p)
        self._ovecs = self._ovecs.at[pl].set(jnp.asarray(codes))
        self._oscales = self._oscales.at[pl].set(jnp.asarray(scales))
        self._oids = self._oids.at[pl].set(jnp.asarray(oid_vals))
        self.transfer_bytes += int(codes.nbytes + scales.nbytes
                                   + oid_vals.nbytes + pos_p.size * 4)

    # ------------------------------------------------------------- build
    def _live_slots(self) -> np.ndarray:
        if self._host is None or self._n == 0:
            return np.zeros(0, np.int64)
        rows = self._host[: self._n]
        return np.flatnonzero(np.abs(rows[:, 0]) < TOMBSTONE / 2)

    def rebuild(self):
        """Host k-means over the live mirror with balance-capped
        assignment; ships centroids + packed int8 arrays."""
        live = self._live_slots()
        if live.size == 0:
            # degenerate-but-searchable: one tombstone centroid, an empty
            # packed row, an empty overflow buffer — every candidate is
            # id −1, so searches return BIG distances (a guaranteed miss)
            # instead of crashing; the flat index handles the same state
            # via its TOMBSTONE rows
            self._centroids = jnp.full((1, self.dim), TOMBSTONE, jnp.float32)
            self._pvecs = jnp.zeros((1, 1, self.dim), jnp.int8)
            self._pscales = jnp.zeros((1, 1), jnp.float16)
            self._pids = jnp.full((1, 1), -1, jnp.int32)
            if self._slot_loc is not None:
                self._slot_loc[:, :] = -1
            self._overflow = []
            self._opos = {}
            self._overflow_base = 0
            self._sync_overflow()
            self._built = True
            self._republish()
            return
        x = self._host[live]
        k = self.n_clusters or max(1, int(np.sqrt(live.size)))
        cent, assign = _kmeans(x, k, self.kmeans_iters, self.seed)
        # balance: every probe pays for m_pad, so one fat cluster taxes
        # them all. Over-cap clusters are recursively 2-means SPLIT (the
        # centroid count adapts to the data's true granularity); the few
        # entries still over cap afterwards are NOT exiled to a far
        # cluster (a spilled entry becomes unfindable exactly when its
        # query probes the right cluster — measured as a hard recall
        # cliff) — they go to the always-scored overflow buffer.
        cap = max(1, int(np.ceil(self.balance_cap * live.size / k)))
        for _ in range(4):
            sizes = np.bincount(assign, minlength=cent.shape[0])
            fat = np.flatnonzero(sizes > cap)
            if fat.size == 0:
                break
            for c in fat:
                m = np.flatnonzero(assign == c)
                sub_c, sub_a = _kmeans(x[m], 2, 4, self.seed + int(c) + 1)
                if sub_c.shape[0] < 2:
                    continue
                new_id = cent.shape[0]
                cent = np.concatenate([cent, sub_c[1:]], 0)
                cent[c] = sub_c[0]
                assign[m[sub_a == 1]] = new_id
        k = cent.shape[0]
        top1 = assign
        fill = np.zeros(k, np.int64)
        assign = np.full(live.size, -1, np.int64)
        spills: List[int] = []
        for i in range(live.size):
            c = top1[i]
            if fill[c] < cap:
                assign[i] = c
                fill[c] += 1
            else:
                spills.append(i)
        m_pad = max(1, int(fill.max()))
        pvecs = np.zeros((k, m_pad, self.dim), np.float32)
        pids = np.full((k, m_pad), -1, np.int32)
        pos = np.zeros(k, np.int64)
        self._slot_loc[:, :] = -1
        for i, (slot, c) in enumerate(zip(live, assign)):
            if c < 0:
                continue
            p = pos[c]
            pvecs[c, p] = x[i]
            pids[c, p] = slot
            self._slot_loc[slot] = (c, p)
            pos[c] += 1
        codes, scales = self._quant(pvecs.reshape(k * m_pad, self.dim))
        self._pvecs = jnp.asarray(codes.reshape(k, m_pad, self.dim))
        self._pscales = jnp.asarray(scales.reshape(k, m_pad))
        self._pids = jnp.asarray(pids)
        self._centroids = jnp.asarray(cent)
        self._overflow = [int(live[i]) for i in spills]
        self._opos = {s: j for j, s in enumerate(self._overflow)}
        self._overflow_base = len(self._overflow)
        self._sync_overflow()
        self.transfer_bytes += int(cent.nbytes + codes.nbytes
                                   + scales.nbytes + pids.nbytes)
        self._built = True
        self._republish()
        self.n_rebuilds += 1

    @property
    def search_args(self):
        """(centroids, pvecs, pscales, pids, ovecs, oscales, oids) — the
        traced pytree; rebuilds/growth change shapes and retrace the
        consumer jit automatically. Under a mesh the args ARE the f32
        table (the mesh branch of ``search_device`` consumes it as a
        traced value — closing over ``self.table`` at trace time would
        bake a stale constant into the caller's jit)."""
        if self.mesh is not None:
            return self.table
        if not self._built:
            self.rebuild()
        return self._packed

    # ------------------------------------------------------------- search
    def search_device(self, q, k: int = 1, *, table=None, args=None,
                      fused: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        # ``fused`` is accepted for API parity with DeviceIndex: the
        # clustered search is already pure XLA (no Pallas dispatch), so
        # it IS the fused-prologue form
        q = jnp.asarray(q, jnp.float32)
        if self.mesh is not None:
            # args is the traced f32 table here (see search_args); the
            # clustered stages are a single-replica optimization
            t = (args if args is not None and not isinstance(args, tuple)
                 else (table if table is not None else self.table))
            if k == 1:
                from repro.core.shard import mesh_search
                d2, idx = mesh_search(t, q, self.mesh,
                                      db_axis=self.db_axis)
                return d2[:, None], idx[:, None]
            neg, idx = jax.lax.top_k(-_sq_dists(q, t), k)
            return -neg, idx.astype(jnp.int32)
        if args is None:
            args = self.search_args
        centroids, pvecs, pscales, pids, ovecs, oscales, oids = args
        C, m_pad, dim = pvecs.shape
        # stage 1: one (B, C) matmul → vote-priority probes. Every
        # cluster that is SOME query's top-1 outranks every cluster that
        # is no one's (votes are the integer part of the priority, the
        # normalized batch-min distance breaks ties below 1.0) — so as
        # long as the batch's distinct top-1 clusters fit in nprobe,
        # stage 1 is exact for every query; leftover probes go to the
        # next-nearest clusters batch-wide.
        d2c = _sq_dists(q, centroids)
        nprobe = min(self.nprobe, C)
        votes = jnp.zeros((C,), jnp.float32).at[jnp.argmin(d2c, 1)].add(1.0)
        dmin = jnp.min(d2c, axis=0)
        priority = votes - dmin / (jnp.max(dmin) + 1e-9)
        _, probes = jax.lax.top_k(priority, nprobe)                # (P,)
        # stage 2: P contiguous block copies + the overflow side table,
        # dequantized once, scored with ONE dense (B, K) matmul
        vec_blocks = jnp.take(pvecs, probes, axis=0).reshape(-1, dim)
        sc_blocks = jnp.take(pscales, probes, axis=0).reshape(-1)
        id_blocks = jnp.take(pids, probes, axis=0).reshape(-1)
        cand_vecs = jnp.concatenate([vec_blocks, ovecs], 0)
        cand_sc = jnp.concatenate([sc_blocks, oscales], 0)
        cand_ids = jnp.concatenate([id_blocks, oids], 0)           # (K,)
        vecs = cand_vecs.astype(jnp.float32) * cand_sc.astype(
            jnp.float32)[:, None]
        d2 = _sq_dists(q, vecs)                                    # (B, K)
        # BIG (not inf): downstream sqrt/calibration must stay NaN-free
        d2 = jnp.where((cand_ids >= 0)[None, :], d2, 1e30)
        if k == 1:
            best = jnp.argmin(d2, axis=-1)
            idx = jnp.take(cand_ids, best).astype(jnp.int32)
            return jnp.take_along_axis(d2, best[:, None], -1), idx[:, None]
        neg, pos = jax.lax.top_k(-d2, k)
        return -neg, jnp.take(cand_ids, pos.reshape(-1)).reshape(
            pos.shape).astype(jnp.int32)

    def search(self, q, k: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        d2, idx = self.search_device(jnp.asarray(q, jnp.float32), k)
        return (np.sqrt(np.maximum(np.asarray(d2), 0.0)), np.asarray(idx))


def recall_at_1(index, oracle: ExactIndex, queries) -> float:
    """Fraction of queries where the index returns the oracle's top-1."""
    _, ia = index.search(queries, 1)
    _, ib = oracle.search(queries, 1)
    return float((ia[:, 0] == ib[:, 0]).mean())


# --- registry wiring (repro.memo public API v1) -------------------------
# Host-tier (calibration/lookup) and device-tier (fused-jit serving)
# index layouts resolve through string-keyed registries; the MemoStore
# never names a concrete class. Extensions: ``repro.memo.register_index``.
from repro.core.registry import DEVICE_INDEXES, HOST_INDEXES  # noqa: E402

HOST_INDEXES.register(
    "exact", lambda dim, **_: ExactIndex(dim))
HOST_INDEXES.register(
    "ivf", lambda dim, *, n_lists=None, **_: IVFIndex(dim,
                                                      n_lists=n_lists or 8))
HOST_INDEXES.register(
    "device", lambda dim, *, interpret=None, mesh=None, **_:
    DeviceIndex(dim, interpret=interpret, mesh=mesh))

DEVICE_INDEXES.register(
    "flat", lambda dim, *, capacity=0, interpret=None, mesh=None, **_:
    DeviceIndex(dim, interpret=interpret, capacity=capacity, mesh=mesh))
DEVICE_INDEXES.register(
    "clustered", lambda dim, *, capacity=0, nprobe=16, n_clusters=None,
    interpret=None, mesh=None, **_:
    ClusteredDeviceIndex(dim, nprobe=nprobe, n_clusters=n_clusters,
                         interpret=interpret, capacity=capacity, mesh=mesh))

"""Sharded memo store — the multi-device tier (DESIGN.md §2.12).

One host's memo store stops scaling at one accelerator's HBM: PR 1–8
made the single-host store fast, compressed, crash-consistent and
disk-backed, but its device tier is a single replicated allocation.
This module partitions the device tier over a mesh axis so capacity and
search throughput scale with device count:

* ``ShardedDeviceDB`` / ``ShardedDeviceIndex`` — every row-indexed leaf
  (embedding table, slot map, codec-part arenas) is laid out as a flat
  ``(S*M, ...)`` array row-sharded over the ``store`` axis: shard ``s``
  owns positions ``[s*M, (s+1)*M)``. Routing state (k-means centroids +
  their owning shard) and a small hot-entry set replicate everywhere.

* Centroid-routed search: a query computes its ``route_nprobe`` nearest
  centroids; only shards owning one of them compete (the others submit
  +inf), so the per-shard work stays one local matmul. Every shard also
  scores the replicated hot set (top reuse-count rows, refreshed each
  maintenance sync) so skewed traffic against a single hot shard never
  serializes the batch. Shard winners — distance, GLOBAL slot id, and
  the candidate's codec-part rows — combine through exactly ONE
  ``all_gather`` + argmin under ``shard_map``: the one-barrier-per-batch
  invariant holds in meshed mode (trace-counted in tests/test_shard.py).

* ``ShardedMemoStore`` — admission and CLOCK eviction become per-shard
  under the same global byte budget: a dirty slot routes to the shard
  owning its nearest centroid; a full shard runs a shard-local CLOCK
  sweep before spilling to the emptiest shard. Delta sync ships only
  shard-local dirty positions and bumps only the touched shards'
  generations (``shard_snapshots``); the global ``StoreSnapshot``
  publish protocol is unchanged.

``mesh_search`` is the plain entry-sharded exact search (the retired
``database.distributed_search``), still used by the flat/clustered
indexes when constructed with a mesh.
"""
from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.database import pad_delta_parts, pad_delta_pow2
from repro.core.faults import MemoStoreError
from repro.core.index import TOMBSTONE, _kmeans
from repro.core.registry import DEVICE_INDEXES
from repro.core.store import MemoStore
from repro.sharding.rules import memo_row_spec

# module-level indirection so the trace-time collective count is
# observable: tests monkeypatch ``shard._ALL_GATHER`` and assert the
# whole sharded search traces exactly ONE cross-shard collective
_ALL_GATHER = jax.lax.all_gather


def _shard_map(body, mesh, in_specs, out_specs):
    """Version-compat shard_map (jax>=0.5 top-level vs experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_store_mesh(n_shards: Optional[int] = None,
                    axis: str = "store") -> Mesh:
    """A 1-D mesh over the local devices for the sharded store. Requests
    past ``jax.device_count()`` clamp (an 8-shard spec on a 1-device dev
    box degrades to S=1 rather than failing); the 8-way CPU runs set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
    import."""
    devs = np.asarray(jax.devices())
    n = devs.size if n_shards is None else max(1, min(int(n_shards),
                                                      int(devs.size)))
    return Mesh(devs[:n], (axis,))


def mesh_search(embs, queries, mesh, *, db_axis: str = "data"):
    """Distributed exact top-1 over an entry-sharded embedding table:
    each shard computes its local argmin (one MXU matmul), then a small
    (n_shards, B) all-gather + global argmin. embs: (N, dim) sharded
    P(db_axis); queries: (B, dim) replicated. Returns (sq_dists (B,),
    global_idx (B,)). The flat/clustered device indexes fall back to
    this under a mesh; the full sharded store uses
    ``ShardedDeviceIndex.search_fetch`` (centroid routing + hot set +
    fetch in the same single collective)."""
    def body(db, q):
        n_loc = db.shape[0]
        d2 = (jnp.sum(q * q, -1, keepdims=True)
              - 2.0 * q @ db.T + jnp.sum(db * db, -1)[None, :])
        loc_arg = jnp.argmin(d2, axis=-1)
        loc_min = jnp.take_along_axis(d2, loc_arg[:, None], -1)[:, 0]
        shard = jax.lax.axis_index(db_axis)
        gidx = loc_arg + shard * n_loc
        mins, idxs = _ALL_GATHER((loc_min, gidx), db_axis)  # (shards, B)
        best = jnp.argmin(mins, axis=0)                     # (B,)
        cols = jnp.arange(q.shape[0])
        return mins[best, cols], idxs[best, cols]

    smap = _shard_map(body, mesh, in_specs=(P(db_axis, None), P()),
                      out_specs=(P(), P()))
    return smap(embs, queries)


class ShardSnapshot(NamedTuple):
    """Per-shard publish record: generation bumps only when THAT shard's
    rows changed, so a reader (delta replication, the benchmarks' balance
    probe) can tell which shards a sync actually touched."""
    shard: int
    generation: int
    live: int          # occupied positions
    free: int          # free positions remaining


class ShardedDeviceDB:
    """Position-indexed device arenas, row-sharded over the mesh axis.

    Same surface as ``DeviceDB`` (``parts`` tuple consumed by the fused
    jit, ``update`` scatter deltas, ``transfer_bytes``), but rows are
    device POSITIONS (shard*M + row), not host slot ids — the sharded
    index returns each winner's codec rows from the combine, so the
    engine never indexes these arenas by slot."""

    def __init__(self, host_parts: Sequence[np.ndarray], mesh: Mesh,
                 axis: str, codec=None):
        self.codec = codec
        self.mesh = mesh
        self.axis = axis
        parts = []
        for p in host_parts:
            sh = NamedSharding(mesh, memo_row_spec(mesh, p.ndim, axis=axis,
                                                   shape=p.shape))
            parts.append(jax.device_put(p, sh))
        self.parts: Tuple[jnp.ndarray, ...] = tuple(parts)
        self.transfer_bytes = sum(int(p.nbytes) for p in self.parts)

    @property
    def capacity(self) -> int:
        return int(self.parts[0].shape[0])

    @property
    def nbytes(self) -> int:
        return sum(int(p.nbytes) for p in self.parts)

    def __len__(self):
        return self.capacity

    def update(self, positions: np.ndarray,
               host_parts: Sequence[np.ndarray]) -> int:
        """Scatter compressed rows into device positions (pow2-padded so
        compiled scatter shapes stay log2-bounded). Returns bytes."""
        positions = np.asarray(positions).reshape(-1)
        if positions.size == 0:
            return 0
        if int(positions.max()) >= self.capacity:
            raise ValueError("sharded delta past device position capacity")
        pos, parts = pad_delta_parts(positions, host_parts)
        pos_dev = jnp.asarray(pos)
        shipped = int(pos.size * 8)
        new_parts = []
        for arr, p in zip(self.parts, parts):
            p = jnp.asarray(np.asarray(p, arr.dtype))
            new_parts.append(arr.at[pos_dev].set(p))
            shipped += int(p.nbytes)
        self.parts = tuple(new_parts)
        self.transfer_bytes += shipped
        return shipped


class ShardedDeviceIndex:
    """Centroid-routed sharded top-1 index (DESIGN.md §2.12).

    Row-sharded state: ``table`` (S*M, dim) embeddings at device
    positions, ``slot_at`` (S*M,) the GLOBAL host slot each position
    holds (−1 free). Replicated state: k-means ``centroids`` (C, dim) +
    ``owner`` (C,) shard id per centroid, and the hot set (``hot_table``
    / ``hot_slots`` / ``hot_parts`` — top reuse-count rows).

    ``search_fetch`` runs the whole search under ``shard_map`` with ONE
    ``all_gather`` combine and returns (d2, slot, codec rows) — global
    slot ids, so the engine's length gate and reuse drain are unchanged
    from the single-host path."""

    is_sharded = True

    def __init__(self, dim: int, *, mesh: Mesh, axis: str = "store",
                 capacity: int = 0, nprobe: int = 4, hot_k: int = 32,
                 interpret: Optional[bool] = None, **_):
        self.dim = dim
        self.mesh = mesh
        self.axis = axis
        self.n_shards = int(mesh.shape[axis])
        self.nprobe = max(1, int(nprobe))
        self.hot_k = max(0, int(hot_k))
        self.interpret = interpret
        self.transfer_bytes = 0
        self._table: Optional[jnp.ndarray] = None
        self._slot_at: Optional[jnp.ndarray] = None
        self._centroids: Optional[jnp.ndarray] = None
        self._owner: Optional[jnp.ndarray] = None
        H = max(1, self.hot_k)
        self._hot_table = jnp.full((H, dim), TOMBSTONE, jnp.float32)
        self._hot_slots = jnp.full((H,), -1, jnp.int32)
        self._hot_parts: Tuple[jnp.ndarray, ...] = ()
        self._norms: Optional[jnp.ndarray] = None
        if capacity:
            self.load(np.full((capacity, dim), TOMBSTONE, np.float32),
                      np.full((capacity,), -1, np.int64))
            self.set_centroids(
                np.full((1, dim), TOMBSTONE, np.float32),
                np.zeros((1,), np.int32))

    # ------------------------------------------------------------- state
    def _row_sharding(self, ndim: int, shape) -> NamedSharding:
        return NamedSharding(self.mesh, memo_row_spec(
            self.mesh, ndim, axis=self.axis, shape=tuple(shape)))

    @property
    def capacity(self) -> int:
        return 0 if self._table is None else int(self._table.shape[0])

    def __len__(self):
        return self.capacity

    def load(self, table: np.ndarray, slot_at: np.ndarray) -> None:
        """Full rebuild: upload position-indexed table + slot map."""
        table = np.asarray(table, np.float32)
        slot_at = np.asarray(slot_at, np.int64)
        self._table = jax.device_put(
            table, self._row_sharding(2, table.shape))
        self._slot_at = jax.device_put(
            slot_at, self._row_sharding(1, slot_at.shape))
        self._norms = None
        self.transfer_bytes += int(table.nbytes + slot_at.nbytes)

    def set_centroids(self, centroids: np.ndarray,
                      owner: np.ndarray) -> None:
        self._centroids = jnp.asarray(np.asarray(centroids, np.float32))
        self._owner = jnp.asarray(np.asarray(owner, np.int32))
        self.transfer_bytes += int(self._centroids.nbytes
                                   + self._owner.nbytes)

    def set_hot(self, table: np.ndarray, slots: np.ndarray,
                parts: Tuple[np.ndarray, ...]) -> int:
        """Refresh the replicated hot set (fixed H rows — shapes never
        change across refreshes, so no consumer retrace). Returns the
        bytes shipped."""
        self._hot_table = jnp.asarray(np.asarray(table, np.float32))
        self._hot_slots = jnp.asarray(np.asarray(slots, np.int32))
        self._hot_parts = tuple(jnp.asarray(p) for p in parts)
        shipped = int(self._hot_table.nbytes + self._hot_slots.nbytes
                      + sum(int(p.nbytes) for p in self._hot_parts))
        self.transfer_bytes += shipped
        return shipped

    def update(self, positions: np.ndarray, embs: np.ndarray,
               slots: np.ndarray) -> int:
        """Delta: write embedding rows + their global slot ids at device
        positions (pow2-padded scatters)."""
        positions = np.asarray(positions).reshape(-1)
        if positions.size == 0:
            return 0
        pos, vals = pad_delta_pow2(positions,
                                   np.asarray(embs, np.float32))
        _, sl = pad_delta_pow2(positions, np.asarray(slots, np.int64))
        pos_dev = jnp.asarray(pos)
        self._table = self._table.at[pos_dev].set(jnp.asarray(vals))
        self._slot_at = self._slot_at.at[pos_dev].set(jnp.asarray(sl))
        self._norms = None
        shipped = int(vals.nbytes + sl.nbytes + pos.size * 8)
        self.transfer_bytes += shipped
        return shipped

    def kill(self, positions: np.ndarray) -> int:
        """Tombstone freed device positions (slot −1, TOMBSTONE row)."""
        positions = np.asarray(positions).reshape(-1)
        if positions.size == 0:
            return 0
        pos, _ = pad_delta_pow2(positions)
        pos_dev = jnp.asarray(pos)
        self._table = self._table.at[pos_dev].set(TOMBSTONE)
        self._slot_at = self._slot_at.at[pos_dev].set(-1)
        self._norms = None
        shipped = int(pos.size * 8)
        self.transfer_bytes += shipped
        return shipped

    # ------------------------------------------------------------ search
    @property
    def search_args(self):
        """The traced pytree the fused jit consumes — per-row ‖d‖² for
        the sharded table, centroid norms and the hot set are cached per
        mutation generation at publish, exactly like the flat index."""
        if self._norms is None:
            self._norms = jnp.sum(self._table * self._table, axis=-1)
        cnorms = jnp.sum(self._centroids * self._centroids, axis=-1)
        hnorms = jnp.sum(self._hot_table * self._hot_table, axis=-1)
        return (self._table, self._norms, self._slot_at, self._centroids,
                cnorms, self._owner, self._hot_table, hnorms,
                self._hot_slots, self._hot_parts)

    def _combine(self, args, q, parts, with_rows: bool):
        """The one-collective sharded search. Local per shard: one
        (B, M) matmul + centroid-routing mask + the replicated hot-set
        scores; global: ONE pytree ``all_gather`` of each shard's winner
        (distance, slot id, codec rows) followed by a replicated argmin.
        Masked shards (no probed centroid owned) submit +inf."""
        (table, norms, slot_at, cents, cnorms, owner, hot_t, hnorms,
         hot_s, hot_parts) = args
        axis = self.axis
        nprobe = min(self.nprobe, int(cents.shape[0]))

        def body(table, norms, slot_at, cents, cnorms, owner, hot_t,
                 hnorms, hot_s, q, hot_parts, parts):
            me = jax.lax.axis_index(axis)
            qq = jnp.sum(q * q, axis=-1, keepdims=True)        # (B, 1)
            d2 = qq + norms[None, :] - 2.0 * (q @ table.T)     # (B, M)
            loc = jnp.argmin(d2, axis=1)                       # (B,)
            dloc = jnp.take_along_axis(d2, loc[:, None], 1)[:, 0]
            # centroid routing: only shards owning one of the query's
            # nprobe nearest centroids compete for it
            cd = cnorms[None, :] - 2.0 * (q @ cents.T)         # (B, C)
            _, probes = jax.lax.top_k(-cd, nprobe)             # (B, P)
            mine = jnp.any(owner[probes] == me, axis=1)        # (B,)
            dloc = jnp.where(mine, dloc, jnp.float32(np.inf))
            sloc = slot_at[loc]
            # replicated hot set: every shard scores it (H is tiny), so
            # a skew-hot entry is served without routing to its shard
            dh = qq + hnorms[None, :] - 2.0 * (q @ hot_t.T)    # (B, H)
            hloc = jnp.argmin(dh, axis=1)
            dhot = jnp.take_along_axis(dh, hloc[:, None], 1)[:, 0]
            use_hot = dhot < dloc
            dbest = jnp.where(use_hot, dhot, dloc)
            sbest = jnp.where(use_hot, hot_s[hloc].astype(sloc.dtype),
                              sloc)
            payload = [dbest, sbest]
            if with_rows:
                for p, hp in zip(parts, hot_parts):
                    lr = jnp.take(p, loc, axis=0)              # (B, ...)
                    hr = jnp.take(hp, hloc, axis=0)
                    sel = use_hot.reshape(
                        (-1,) + (1,) * (lr.ndim - 1))
                    payload.append(jnp.where(sel, hr, lr))
            g = _ALL_GATHER(tuple(payload), axis)   # ONE collective
            win = jnp.argmin(g[0], axis=0)                     # (B,)
            cols = jnp.arange(g[0].shape[1])
            out = [g[0][win, cols], g[1][win, cols]]
            if with_rows:
                out.append(tuple(r[win, cols] for r in g[2:]))
            return tuple(out)

        row = P(self.axis)
        n_in = 10  # table..q specs below
        in_specs = (P(self.axis, None), row, row, P(), P(), P(), P(),
                    P(), P(), P(), P(), P(self.axis))
        assert len(in_specs) == n_in + 2
        out_specs = ((P(), P(), P()) if with_rows else (P(), P()))
        smap = _shard_map(body, self.mesh, in_specs, out_specs)
        return smap(table, norms, slot_at, cents, cnorms, owner, hot_t,
                    hnorms, hot_s, jnp.asarray(q, jnp.float32),
                    hot_parts, tuple(parts or ()))

    def search_device(self, q, k: int = 1, *, table=None, args=None,
                      fused: bool = False):
        """DeviceIndex-compat search: (sq_dists (B, k), slot ids (B, k)).
        Top-1 only (the sharded combine carries one winner per shard);
        ``fused`` is accepted for API parity — the search is already the
        one-matmul-per-shard form."""
        if k != 1:
            raise NotImplementedError("sharded index serves top-1 only")
        if args is None:
            args = self.search_args
        d2, slot = self._combine(args, q, None, with_rows=False)
        return d2[:, None], slot.astype(jnp.int32)[:, None]

    def search_fetch(self, q, *, args, parts):
        """Search + fetch in the SAME collective: returns (sq_dists
        (B, 1), slot ids (B, 1), codec-part rows tuple (B, ...)). The
        winning shard's arena rows ride the all_gather payload, so the
        engine never gathers from the sharded arenas by index — which
        would be a second cross-shard collective."""
        d2, slot, rows = self._combine(args, q, parts, with_rows=True)
        return d2[:, None], slot.astype(jnp.int32)[:, None], rows

    def search(self, q, k: int = 1):
        """Host-compat API (L2, not squared — same as ExactIndex)."""
        d2, idx = self.search_device(jnp.asarray(q, jnp.float32), k)
        return (np.sqrt(np.maximum(np.asarray(d2), 0.0)),
                np.asarray(idx))


class ShardedMemoStore(MemoStore):
    """MemoStore whose device tier is partitioned over a mesh axis.

    The host tier (arena, host index, capacity tier, budgets) is exactly
    the base store — global admission still enforces the ONE byte budget.
    What changes is device placement: every live slot is assigned a
    device POSITION on the shard owning its nearest centroid; a full
    shard runs a shard-local CLOCK sweep (per-shard eviction) before
    spilling to the emptiest shard. Delta sync ships only the touched
    shards' positions and bumps only their ``shard_snapshots``
    generations; full sync re-runs k-means and rebalances ownership."""

    def __init__(self, apm_shape, embed_dim, *, n_shards: int = 0,
                 shard_axis: str = "store", hot_k: int = 32,
                 route_nprobe: Optional[int] = None,
                 refresh_spills: int = 0, mesh=None, **kw):
        if kw.get("index_kind") == "device":
            raise MemoStoreError(
                "ShardedMemoStore needs a host-tier index separate from "
                "the device table (index_kind='device' is single-host "
                "only); use index_kind='exact' or 'ivf'")
        if mesh is None:
            mesh = make_store_mesh(n_shards or None, shard_axis)
        kw.pop("device_index_kind", None)   # the sharded layout is fixed
        kw.pop("mesh", None)
        super().__init__(apm_shape, embed_dim,
                         device_index_kind="sharded", mesh=None, **kw)
        self.shard_mesh = mesh
        self.shard_axis = shard_axis
        self.n_shards = int(mesh.shape[shard_axis])
        self.hot_k = max(0, int(hot_k))
        self.route_nprobe = (max(1, int(route_nprobe))
                             if route_nprobe is not None
                             else max(1, int(self.nprobe)))
        # position bookkeeping (all rebuilt by each full sync)
        self._pos_per_shard = 0
        self._slot_pos: Dict[int, int] = {}
        self._pos_slot = np.full((0,), -1, np.int64)
        self._shard_free: List[List[int]] = [[] for _ in
                                             range(self.n_shards)]
        self._shard_hands = [0] * self.n_shards
        self._centroids_host = np.full((1, embed_dim), TOMBSTONE,
                                       np.float32)
        self._owner_host = np.zeros((1,), np.int32)
        self._shard_gens = np.zeros(self.n_shards, np.int64)
        self.shard_snapshots: Tuple[ShardSnapshot, ...] = ()
        self.n_shard_evictions = 0
        self.n_spills = 0
        # routing-drift repair (ROADMAP item 1): after this many delta-
        # sync spills since the last centroid fit, recompute centroids
        # from the current embedding table (0 disables)
        self.refresh_spills = max(0, int(refresh_spills))
        self._spills_since_refresh = 0
        self.n_centroid_refreshes = 0

    # -------------------------------------------------------- accounting
    def shard_occupancy(self) -> np.ndarray:
        """(S,) live positions per shard — the balance probe."""
        occ = np.zeros(self.n_shards, np.int64)
        if self._pos_per_shard:
            held = np.flatnonzero(self._pos_slot >= 0)
            np.add.at(occ, held // self._pos_per_shard, 1)
        return occ

    def shard_stats(self) -> Dict[str, object]:
        occ = self.shard_occupancy()
        mean = float(occ.mean()) if occ.size else 0.0
        return {
            "n_shards": self.n_shards,
            "positions_per_shard": self._pos_per_shard,
            "occupancy": [int(c) for c in occ],
            "imbalance": (float(occ.max()) / mean if mean > 0 else 1.0),
            "hot_k": self.hot_k,
            "n_shard_evictions": self.n_shard_evictions,
            "n_spills": self.n_spills,
            "n_centroid_refreshes": self.n_centroid_refreshes,
        }

    @property
    def per_shard_budget_bytes(self) -> Optional[int]:
        """The byte budget one shard's positions can hold — what 'a
        database too big for one shard' is measured against."""
        if self._pos_per_shard == 0:
            return None
        return self._pos_per_shard * self.entry_nbytes

    # ---------------------------------------------------------- routing
    def _route_shards(self, embs: np.ndarray) -> np.ndarray:
        """Host-side nearest-centroid → owning shard per row."""
        c = self._centroids_host
        d2 = ((c * c).sum(1)[None, :] - 2.0 * embs @ c.T)
        return self._owner_host[np.argmin(d2, axis=1)]

    def _free_position_locked(self, slot: int,
                              killed: List[int]) -> None:
        pos = self._slot_pos.pop(int(slot), None)
        if pos is not None:
            self._pos_slot[pos] = -1
            self._shard_free[pos // self._pos_per_shard].append(pos)
            killed.append(pos)

    def _evict_shard_locked(self, shard: int, n: int) -> List[int]:
        """Shard-local CLOCK: sweep only this shard's positions with the
        same decaying-second-chance rule as the global clock; falls back
        to coldest-resident when everything is hot. Victims retire
        through the shared path (demotion, tombstones, dirty marking)."""
        M = self._pos_per_shard
        lo = shard * M
        counts = self.db.reuse_counts
        hand = self._shard_hands[shard]
        victims: List[int] = []
        scanned = 0
        while len(victims) < n and scanned < 2 * M:
            pos = lo + (hand % M)
            hand += 1
            scanned += 1
            slot = int(self._pos_slot[pos])
            if slot < 0 or not self.db._live[slot]:
                continue
            if counts[slot] > 0:
                counts[slot] //= 2
            else:
                victims.append(slot)
        self._shard_hands[shard] = hand % M
        if len(victims) < n:      # all hot: coldest resident on the shard
            res = [int(s) for s in self._pos_slot[lo: lo + M]
                   if s >= 0 and self.db._live[s] and s not in victims]
            res.sort(key=lambda s: int(counts[s]))
            victims.extend(res[: n - len(victims)])
        if victims:
            self._retire_slots_locked(victims)
            self.stats.n_evicted += len(victims)
            self.n_shard_evictions += len(victims)
        return victims

    # ------------------------------------------------------------- sync
    def _need_full_sync_locked(self, n: int, force_full: bool) -> bool:
        if (force_full or self.device_db is None
                or self.device_index is None or self._dev_lens is None
                or n > int(self._dev_lens.shape[0])):
            return True
        pending = sum(1 for s in self._dirty
                      if s < n and self.db._live[s]
                      and s not in self._slot_pos)
        total_free = sum(len(f) for f in self._shard_free)
        return pending > total_free

    def _full_sync_device_locked(self, n: int) -> int:
        S = self.n_shards
        live = (np.flatnonzero(self.db.live_mask[:n]) if n
                else np.zeros(0, np.int64))
        nl = int(live.size)
        # per-shard position capacity: the whole live set + device slack,
        # rounded up so every shard can absorb deltas before a re-pack
        budgeted = nl + max(8, int(nl * self.device_slack))
        M = max(4, -(-budgeted // S))
        total = S * M
        # centroids: at least one per shard (ownership must cover the
        # mesh) — k-means clamps k <= live rows itself
        C = int(self.n_clusters or round(math.sqrt(max(1, nl))))
        C = max(S, min(max(1, C), max(1, nl)))
        if nl:
            cents, assign = _kmeans(self._embs_host[live], C, iters=5,
                                    seed=0)
        else:
            cents = np.full((1, self.embed_dim), TOMBSTONE, np.float32)
            assign = np.zeros(0, np.int64)
        # balanced ownership: biggest clusters first, each to the
        # least-loaded shard — per-shard occupancy stays within the
        # largest single cluster of even
        sizes = np.bincount(assign, minlength=cents.shape[0])
        owner = np.zeros(cents.shape[0], np.int32)
        load = np.zeros(S, np.int64)
        for c in np.argsort(-sizes, kind="stable"):
            s = int(np.argmin(load))
            owner[int(c)] = s
            load[s] += int(sizes[int(c)])
        self._centroids_host = np.asarray(cents, np.float32)
        self._owner_host = owner
        # assign every live slot a position on its owning shard;
        # overfull shards spill to the globally emptiest
        self._pos_per_shard = M
        self._pos_slot = np.full((total,), -1, np.int64)
        self._slot_pos = {}
        nxt = [s * M for s in range(S)]
        pref = (owner[assign] if nl else np.zeros(0, np.int32))
        for slot, p in zip(live, pref):
            p = int(p)
            if nxt[p] >= (p + 1) * M:
                p = int(np.argmin([nxt[s] - s * M for s in range(S)]))
                self.n_spills += 1
            pos = nxt[p]
            nxt[p] += 1
            self._slot_pos[int(slot)] = pos
            self._pos_slot[pos] = int(slot)
        self._shard_free = [
            list(range((s + 1) * M - 1, nxt[s] - 1, -1))
            for s in range(S)]
        self._shard_hands = [0] * S
        # host staging at positions → sharded device arrays
        table = np.full((total, self.embed_dim), TOMBSTONE, np.float32)
        held = np.flatnonzero(self._pos_slot >= 0)
        slots_held = self._pos_slot[held]
        table[held] = self._embs_host[slots_held]
        host_parts = [np.zeros((total,) + p.shape, p.dtype)
                      for p in self.codec.parts]
        if held.size:
            rows = self.db.parts_at(slots_held)
            for dst, src in zip(host_parts, rows):
                dst[held] = src
        self.device_db = ShardedDeviceDB(host_parts, self.shard_mesh,
                                         self.shard_axis,
                                         codec=self.codec)
        di = ShardedDeviceIndex(
            self.embed_dim, mesh=self.shard_mesh, axis=self.shard_axis,
            nprobe=self.route_nprobe, hot_k=self.hot_k,
            interpret=self._interpret)
        di._registry_kind = "sharded"
        di.load(table, self._pos_slot)
        di.set_centroids(self._centroids_host, self._owner_host)
        self.device_index = di
        # slot-indexed device lengths (replicated — tiny, and the length
        # gate indexes it by the GLOBAL slot id the combine returns)
        cap_slots = n + max(8, int(n * self.device_slack))
        lens = np.full((cap_slots,), -1, np.int32)
        lens[:n] = self._lens_host[:n]
        self._dev_lens = jnp.asarray(lens)
        shipped = (self.device_db.transfer_bytes
                   + di.transfer_bytes + int(lens.nbytes))
        shipped += self._refresh_hot_locked()
        self._shard_gens += 1
        self._spills_since_refresh = 0    # fresh fit: drift clock restarts
        return shipped

    def _delta_sync_device_locked(self, n: int,
                                  slots: np.ndarray) -> int:
        M = self._pos_per_shard
        killed: List[int] = []
        touched = set(int(s) for s in slots)
        # every dirty slot's old position frees first: dead slots stay
        # free, live ones re-route by their CURRENT embedding (an evicted
        # slot recycled by admission may belong to a different shard now)
        for s in slots:
            self._free_position_locked(int(s), killed)
        live = [int(s) for s in slots if self.db._live[s]]
        write_pos: List[int] = []
        write_slots: List[int] = []
        if live:
            pref = self._route_shards(self._embs_host[np.asarray(live)])
            for slot, p in zip(live, pref):
                if not self.db._live[slot]:
                    continue    # evicted below by an earlier shard sweep
                p = int(p)
                if not self._shard_free[p]:
                    # placement pressure: the routed shard is full while
                    # the sync proceeds — whether resolved by eviction or
                    # by spilling, it is the drift signal the centroid
                    # refresh triggers on
                    self._spills_since_refresh += 1
                    for v in self._evict_shard_locked(p, 1):
                        touched.add(int(v))
                        self._free_position_locked(int(v), killed)
                    if not self._shard_free[p]:
                        p = int(max(range(self.n_shards),
                                    key=lambda s: len(
                                        self._shard_free[s])))
                        self.n_spills += 1
                        if not self._shard_free[p]:
                            raise MemoStoreError(
                                "sharded device tier out of positions "
                                "(needs a full resync)")
                pos = self._shard_free[p].pop()
                self._slot_pos[slot] = pos
                self._pos_slot[pos] = slot
                write_pos.append(pos)
                write_slots.append(slot)
        shipped = 0
        if write_pos:
            posa = np.asarray(write_pos, np.int64)
            sla = np.asarray(write_slots, np.int64)
            shipped += self.device_db.update(posa, self.db.parts_at(sla))
            shipped += self.device_index.update(
                posa, self._embs_host[sla], sla)
        kill = sorted(set(killed) - set(write_pos))
        if kill:
            shipped += self.device_index.kill(np.asarray(kill, np.int64))
        # slot-indexed device lengths for every slot this sync touched
        # (dirty + shard-eviction victims)
        ta = np.asarray(sorted(touched), np.int64)
        ta = ta[ta < int(self._dev_lens.shape[0])]
        if ta.size:
            sl, vals = pad_delta_pow2(ta, self._lens_host[ta])
            self._dev_lens = self._dev_lens.at[jnp.asarray(sl)].set(
                jnp.asarray(vals))
            shipped += int(vals.nbytes + sl.size * 4)
        for sh in {pos // M for pos in write_pos + killed}:
            self._shard_gens[sh] += 1
        if self.refresh_spills \
                and self._spills_since_refresh >= self.refresh_spills:
            shipped += self._refresh_centroids_locked()
        shipped += self._refresh_hot_locked()
        return shipped

    def _refresh_centroids_locked(self) -> int:
        """Lightweight routing-drift repair between full syncs (ROADMAP
        item 1): when enough delta-sync admissions spilled off their
        preferred shard, the centroid fit no longer describes the
        embedding distribution. Re-run k-means over the RESIDENT rows'
        current embeddings and re-derive each centroid's owner by
        majority vote of its assigned rows' resident shard — no row
        moves, no arena traffic; only the tiny replicated routing state
        ships. Future admissions then route to where the data actually
        lives, so the spill rate decays instead of compounding. Runs
        under the store lock on the maintenance cadence (off-thread
        under the MemoServer)."""
        self._spills_since_refresh = 0
        M = self._pos_per_shard
        if M == 0 or not self._slot_pos or self.device_index is None:
            return 0
        n = len(self.db)
        if n == 0:
            return 0
        resident = np.asarray(sorted(self._slot_pos), np.int64)
        resident = resident[resident < n]
        resident = resident[self.db.live_mask[resident]]
        if resident.size == 0:
            return 0
        # keep the centroid count (and therefore the search_args shapes)
        # fixed: k-means may clamp k below C on tiny stores — pad back
        # with TOMBSTONE rows, which are never the nearest probe
        C = int(self._centroids_host.shape[0])
        cents, assign = _kmeans(self._embs_host[resident], C, iters=5,
                                seed=1 + self.n_centroid_refreshes)
        row_shard = np.asarray(
            [self._slot_pos[int(s)] // M for s in resident], np.int64)
        c_eff = int(cents.shape[0])
        owner = np.zeros(C, np.int32)
        for c in range(c_eff):
            m = assign == c
            if np.any(m):
                owner[c] = np.int32(np.bincount(
                    row_shard[m], minlength=self.n_shards).argmax())
            elif c < self._owner_host.shape[0]:
                owner[c] = self._owner_host[c]
        if c_eff < C:
            pad = np.full((C - c_eff, self.embed_dim), TOMBSTONE,
                          np.float32)
            cents = np.concatenate([np.asarray(cents, np.float32), pad])
        self._centroids_host = np.asarray(cents, np.float32)
        self._owner_host = owner
        self.device_index.set_centroids(self._centroids_host,
                                        self._owner_host)
        self.n_centroid_refreshes += 1
        return int(self._centroids_host.nbytes + owner.nbytes)

    def _refresh_hot_locked(self) -> int:
        """Rebuild the replicated hot set: the top ``hot_k`` live slots
        by reuse count, shipped as fixed-H padded arrays (embedding,
        slot id, codec rows). Runs on every sync — which the MemoServer
        moves to the maintenance worker — so the skew absorber tracks
        the live reuse signal."""
        if self.device_index is None:
            return 0
        H = max(1, self.hot_k)
        n = len(self.db)
        live = np.flatnonzero(self.db.live_mask[:n]) if n else \
            np.zeros(0, np.int64)
        take = np.zeros(0, np.int64)
        if self.hot_k and live.size:
            order = np.argsort(-self.db.reuse_counts[live],
                               kind="stable")
            take = live[order[: self.hot_k]]
        table = np.full((H, self.embed_dim), TOMBSTONE, np.float32)
        slots = np.full((H,), -1, np.int32)
        parts = [np.zeros((H,) + p.shape, p.dtype)
                 for p in self.codec.parts]
        if take.size:
            table[: take.size] = self._embs_host[take]
            slots[: take.size] = take
            for dst, src in zip(parts, self.db.parts_at(take)):
                dst[: take.size] = src
        return self.device_index.set_hot(table, slots, tuple(parts))

    # ----------------------------------------------------------- publish
    def _publish_locked(self):
        snap = super()._publish_locked()
        occ = self.shard_occupancy()
        self.shard_snapshots = tuple(
            ShardSnapshot(shard=s, generation=int(self._shard_gens[s]),
                          live=int(occ[s]),
                          free=len(self._shard_free[s]))
            for s in range(self.n_shards))
        return snap


DEVICE_INDEXES.register(
    "sharded", lambda dim, *, capacity=0, nprobe=16, n_clusters=None,
    interpret=None, mesh=None, axis="store", hot_k=32, **_:
    ShardedDeviceIndex(dim, mesh=(mesh if mesh is not None
                                  else make_store_mesh(None, axis)),
                       axis=axis, capacity=capacity, nprobe=nprobe,
                       hot_k=hot_k, interpret=interpret))

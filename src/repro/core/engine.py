"""AttMemo online inference engine (paper §5.1 Fig. 5).

Orchestrates, per memoizable layer:
    hidden state → MLP embedding → index search → threshold check →
    APM fetch from the attention database → memoized attention.

Execution modes (DESIGN.md §2, the TPU adaptation of "dynamic fallback"):

* ``select``  — both paths are computed and combined with ``jnp.where``
                (reference semantics; used for accuracy studies).
* ``bucket``  — the batch is split into hit/miss sub-batches
                (continuous-batching style): hits run the memo-only
                attention (no Q/K projection, no QKᵀ, no softmax), misses
                run normal attention. This is where the latency win is real.

The engine also builds the database: run the model with APM capture on a
calibration corpus, train the Siamese embedder, index the embeddings.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.database import AttentionDB, DeviceDB
from repro.core.embedding import Embedder, train_embedder
from repro.core.faults import FaultInjector
from repro.core.index import DeviceIndex
from repro.core.prefill import PrefillCodec, stack_kv, unstack_kv_rows
from repro.core.selective import LayerProfile, PerfModel, timeit_median
from repro.core.similarity import similarity_score
from repro.core.store import MemoStore, StoreSnapshot
# MemoConfig/MemoSpec live in repro.memo.specs (the public API v1 config
# surface); re-exported here so ``from repro.core.engine import
# MemoConfig`` keeps working for one release
from repro.memo.specs import MemoConfig, MemoSpec  # noqa: F401
from repro.models import attention as attn_mod
from repro.models import backbone as bb

# paper Table 2 — per-model threshold levels
LEVELS = {"conservative": 0.98, "moderate": 0.97, "aggressive": 0.96}


class SimReservoir:
    """Bounded reservoir sample (Algorithm R) of predicted similarities.

    `MemoStats.sims` used to be an unbounded list — a serving loop that
    threads one MemoStats through the whole run leaked forever. The
    reservoir keeps a uniform sample, so percentile summaries (the
    `suggest_levels`-style reporting) stay accurate while memory is O(cap).

    Mutation and summary are lock-guarded: under the MemoServer runtime
    the serving thread and the maintenance worker both merge per-batch
    stats into one shared reservoir (DESIGN.md §2.7) — without the lock,
    interleaved Algorithm-R updates lose or duplicate samples and the
    ``seen`` counter drifts from reality.
    """

    def __init__(self, cap: int = 4096, seed: int = 0):
        self.cap = cap
        self.seen = 0                 # total values offered
        self._vals: List[float] = []
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()

    def _append_locked(self, v: float) -> None:
        self.seen += 1
        if len(self._vals) < self.cap:
            self._vals.append(float(v))
        else:
            j = int(self._rng.integers(0, self.seen))
            if j < self.cap:
                self._vals[j] = float(v)

    def append(self, v: float) -> None:
        with self._lock:
            self._append_locked(v)

    def extend(self, values) -> None:
        values = list(values)
        with self._lock:
            if len(self._vals) + len(values) <= self.cap:
                self.seen += len(values)
                self._vals.extend(float(v) for v in values)
                return
            for v in values:
                self._append_locked(v)

    def percentile(self, q) -> float:
        with self._lock:
            if not self._vals:
                return float("nan")
            return float(np.percentile(self._vals, q))

    def __len__(self):
        return len(self._vals)        # retained (bounded); .seen = total

    def __iter__(self):
        return iter(list(self._vals))


@dataclass
class MemoStats:
    n_inputs: int = 0
    n_layer_attempts: int = 0
    n_hits: int = 0
    sims: SimReservoir = field(default_factory=SimReservoir)
    t_embed: float = 0.0
    t_search: float = 0.0
    t_fetch: float = 0.0
    t_attn: float = 0.0
    t_other: float = 0.0
    t_total: float = 0.0            # whole-batch wall time (fast path)
    per_layer_hits: Dict[int, int] = field(default_factory=dict)
    n_admitted: int = 0             # entries admitted via miss capture
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    @property
    def memo_rate(self) -> float:
        return self.n_hits / max(1, self.n_layer_attempts)

    def merge(self, other: "MemoStats") -> "MemoStats":
        """Fold another stats object into this one under the lock — the
        MemoServer accumulates per-batch stats this way so the serving
        thread and the off-thread maintenance worker never race on the
        counters (they used to be bare ``+=`` on shared fields)."""
        with self._lock:
            self.n_inputs += other.n_inputs
            self.n_layer_attempts += other.n_layer_attempts
            self.n_hits += other.n_hits
            self.t_embed += other.t_embed
            self.t_search += other.t_search
            self.t_fetch += other.t_fetch
            self.t_attn += other.t_attn
            self.t_other += other.t_other
            self.t_total += other.t_total
            self.n_admitted += other.n_admitted
            for li, nh in other.per_layer_hits.items():
                self.per_layer_hits[li] = self.per_layer_hits.get(li, 0) + nh
        self.sims.extend(other.sims)          # reservoir has its own lock
        return self

    def add_admitted(self, n: int) -> None:
        """Maintenance-side counter bump (worker thread under the async
        runtime), guarded like ``merge``."""
        with self._lock:
            self.n_admitted += int(n)


@dataclass
class PreparedBatch:
    """Everything ``run_layers``/``finalize`` need for one device-resident
    batch — produced by ``prepare_batch``, which is where the runtime's
    batching policy hands over to the engine (DESIGN.md §2.7)."""
    tokens: jnp.ndarray
    h: jnp.ndarray
    positions: jnp.ndarray
    kpad: Optional[jnp.ndarray]          # (B, S) bool key-validity mask
    lengths_dev: Optional[jnp.ndarray]   # (B,) int32 true lengths (device)
    lengths: Optional[np.ndarray]        # host copy (drain/admission)
    n_valid: int                         # real rows; the rest are padding
    thr: float
    active: set
    capture: bool
    view: StoreSnapshot                  # the store generation this batch
    #                                      serves against, end to end
    t0: float = 0.0
    pend: list = field(default_factory=list)
    # prefill serving (DESIGN.md §2.13): per-layer decode-cache templates
    # split from model.init_caches, and the caches each layer produced
    prefill: bool = False
    cache_len: int = 0
    cache_tpls: Optional[dict] = None
    caches_by_li: dict = field(default_factory=dict)


@dataclass
class MaintenancePayload:
    """Host-tier store work drained from one finished batch. Applying it
    (``MemoEngine.apply_maintenance``) is the ONLY thing that mutates the
    MemoStore — the runtime either does it inline (sync mode) or hands it
    to the background worker (async mode, overlapped with batch t+1's
    device compute)."""
    reuse_slots: Optional[np.ndarray] = None        # device-tier hits
    admissions: List[Tuple] = field(default_factory=list)
    #   (apms, embs, lens, kv) — kv is the stacked (B, 2, S, D) K/V plane
    #   under prefill capture, None for APM-only admissions
    generation: int = -1        # the store generation the batch served
    #                             against (failure-report context)

    @property
    def empty(self) -> bool:
        return not self.admissions and (
            self.reuse_slots is None or self.reuse_slots.size == 0)


class MemoEngine:
    def __init__(self, model, params,
                 memo_cfg: Optional[MemoSpec] = None):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        # None → a fresh default spec PER ENGINE (a shared default
        # instance would leak one engine's mc mutations — threshold
        # autotune, mode flips — into every other default-configured one)
        self.mc = MemoSpec() if memo_cfg is None else memo_cfg
        self.is_encdec = getattr(model, "is_encdec", False)
        if self.is_encdec:
            # enc-dec (whisper): memoize ENCODER self-attention — fixed
            # frame count, bidirectional APMs, reused across requests
            self.layers = list(range(self.cfg.encoder.n_layers))
        else:
            self.layers = list(self.cfg.memoizable_layers())
        if self.mc.max_layers:
            self.layers = self.layers[: self.mc.max_layers]
        # ALL memoization state (both tiers) lives in the MemoStore; the
        # engine only orchestrates (DESIGN.md §2.5). Created by build().
        self.store: Optional[MemoStore] = None
        self.embedder: Optional[Embedder] = None
        self.perf: Optional[PerfModel] = None
        self._jit_cache: Dict = {}
        self._interpret = (self.mc.interpret if self.mc.interpret
                           is not None else jax.default_backend() == "cpu")
        self._layers_cache = None
        self._serve_batches = 0          # admission-sampling counter
        self._pending_admissions: List = []   # host-path capture staging
        self._recal_buf: List = []       # rolling (apms, embs) captures
        self._flush_count = 0
        # fault injection (DESIGN.md §2.9): None unless the spec opts in
        # (RuntimeSpec.faults), so production serving pays one `is None`
        self.faults = FaultInjector.from_spec(self.mc.runtime.faults)

    @property
    def _kernel_impl(self) -> str:
        """Resolved memo_attention implementation for kernel mode
        ("pallas" | "xla"). Explicit ``mc.kernel_impl`` wins; an explicit
        ``mc.interpret`` pins the Pallas path (that is how kernel tests
        keep exercising the kernel); otherwise the backend decides —
        the one-matmul XLA form on CPU (the Pallas interpreter is ~30x
        slower there), the compiled kernel on TPU/GPU. A property, not
        an ``__init__`` capture: callers mutate ``mc`` between builds."""
        ki = self.mc.kernel_impl
        if ki:
            return ki
        if self.mc.interpret is not None:
            return "pallas"
        return "xla" if jax.default_backend() == "cpu" else "pallas"

    # --- store delegation (compat: the pre-store attribute API) ---------
    @property
    def db(self) -> Optional[AttentionDB]:
        return self.store.db if self.store is not None else None

    @property
    def index(self):
        return self.store.index if self.store is not None else None

    @property
    def device_db(self) -> Optional[DeviceDB]:
        return self.store.device_db if self.store is not None else None

    @property
    def device_index(self) -> Optional[DeviceIndex]:
        return self.store.device_index if self.store is not None else None

    @property
    def sim_cal(self):
        return self.store.sim_cal if self.store is not None else (-1.0, 1.0)

    @sim_cal.setter
    def sim_cal(self, value):
        if self.store is None:
            raise AttributeError("sim_cal lives on the MemoStore; "
                                 "build() the engine first")
        self.store.sim_cal = tuple(value)

    def _iter_layers(self):
        """Params are fixed per engine: slice the stacked layer params
        once and reuse — ``bb.iter_layers`` re-slices with eager tree_map
        gathers on every call, which is pure host overhead per batch."""
        if self._layers_cache is None:
            self._layers_cache = list(bb.iter_layers(self.params, self.cfg))
        return self._layers_cache

    def _make_store(self, apm_shape, *, capacity: int,
                    n_lists: Optional[int] = None) -> MemoStore:
        """Construct the MemoStore exactly as the spec describes — the
        single construction path shared by ``build()`` and
        ``MemoSession.load``. A loaded store must be configured
        identically to the saved one for lookups to round-trip:
        ``n_lists`` (derived from the CALIBRATION size at build, which a
        grown store no longer knows) is therefore persisted and passed
        back explicitly on load."""
        mc = self.mc
        budget = (None if mc.budget_mb is None
                  else int(mc.budget_mb * 1e6))
        codec = mc.apm_codec
        if mc.prefill.enabled:
            # prefill memoization (DESIGN.md §2.13): wrap the APM codec so
            # every entry carries per-layer K/V parts — the SAME store,
            # arenas, sync, capacity tier and save format serve both
            from repro.core.codec import get_codec
            base = get_codec(codec, tuple(apm_shape), rank=mc.apm_rank)
            codec = PrefillCodec(
                base, kv_dim=self.cfg.n_kv_heads * self.cfg.head_dim,
                kv_codec=mc.prefill.kv_codec, kv_rank=mc.prefill.kv_rank)
        kw = dict(
            index_kind=mc.index_kind, budget_bytes=budget,
            capacity=capacity, interpret=self._interpret,
            device_slack=mc.device_slack,
            n_lists=(n_lists if n_lists is not None
                     else max(4, int(np.sqrt(max(1, capacity))))),
            codec=codec, apm_rank=mc.apm_rank,
            cluster_crossover=mc.cluster_crossover,
            nprobe=mc.nprobe, n_clusters=mc.n_clusters,
            eviction=mc.eviction.kind, faults=self.faults,
            capacity_dir=mc.capacity.dir,
            capacity_budget_mb=mc.capacity.budget_mb,
            capacity_fsync=mc.capacity.fsync,
            capacity_stall_s=mc.capacity.stall_s)
        if getattr(mc, "shards", 0):
            from repro.core.shard import ShardedMemoStore
            return ShardedMemoStore(
                tuple(apm_shape), mc.embed_dim,
                n_shards=mc.shards, shard_axis=mc.shard_axis,
                hot_k=mc.shard_hot, route_nprobe=mc.shard_route_nprobe,
                refresh_spills=mc.shard_refresh_spills,
                **kw)
        return MemoStore(tuple(apm_shape), mc.embed_dim,
                         device_index_kind=mc.device_index, **kw)

    # ------------------------------------------------------------------ build
    def build(self, key, batches: Sequence[dict], *, train_pairs=512,
              verbose=False):
        """Populate the attention + index databases from a calibration
        corpus and train the embedding model. With prefill memoization
        enabled, every calibration entry also stores the layer's post-RoPE
        K/V (recomputed from the captured attention input — the capture
        dict's ``hidden`` IS the normed x that ``_qkv`` consumes), so the
        first epoch is immediately servable for prefill."""
        prefill = self.mc.prefill.enabled
        if prefill:
            self._check_prefill_supported()
        lps = ({li: lp for li, _, lp in self._iter_layers()}
               if prefill else None)
        hiddens, apms, kvs = [], [], []
        for batch in batches:
            _, caps = self.model.classify(self.params, batch, capture=True) \
                if self.cfg.n_classes else self.model.forward(
                    self.params, batch, capture=True)[:2]
            for li in self.layers:
                if li in caps:
                    hid = np.asarray(caps[li]["hidden"])
                    hiddens.append(hid)
                    apms.append(np.asarray(caps[li]["apm"], np.float16))
                    if prefill:
                        kvs.append(np.asarray(self._kv_probe(
                            lps[li], jnp.asarray(hid))))
        hiddens = np.concatenate(hiddens, 0)      # (N, L, H)
        apms = np.concatenate(apms, 0)            # (N, heads, L, L)
        kv = np.concatenate(kvs, 0) if prefill else None
        n, L, H = hiddens.shape

        self.store = self._make_store(apms.shape[1:], capacity=n)

        k1, k2 = jax.random.split(key)
        emb = Embedder.init(k1, L, H, dim=self.mc.embed_dim,
                            pool=self.mc.embed_pool, act=self.mc.embed_act)
        sub = min(n, max(64, train_pairs))
        self.embedder, hist = train_embedder(
            k2, emb, jnp.asarray(hiddens[:sub]), jnp.asarray(apms[:sub]),
            steps=self.mc.embed_steps)
        if verbose:
            print(f"embedder loss {hist[0]:.4f} -> {hist[-1]:.4f}")

        embs = np.asarray(self._embed(jnp.asarray(hiddens)))
        self.store.admit(apms, embs, kv=kv)   # calibration = first epoch
        self._calibrate(hiddens, apms)
        # materialize the serving tier only when the fast path can reach
        # it (select-mode engines would duplicate the arena for nothing);
        # mode switches after build are covered by the lazy sync in
        # _infer_device/_layer_kernel
        if self.mc.store == "device" and self.mc.mode in ("bucket",
                                                          "kernel"):
            self.store.sync()
        return self

    # -------------------------------------------------------- device tier
    def _sync_device_tier(self):
        """Bring the serving tier (DeviceDB + DeviceIndex) up to date.
        Generation-counted in the store: a clean store is a host-side
        no-op, host-tier changes move as slot deltas into preallocated
        device slack, and only arena growth past the device allocation
        re-materializes (never on the serving hot path)."""
        return self.store.sync()

    def _use_fast_path(self) -> bool:
        if self.is_encdec or self.store is None or self.db is None:
            return False
        if self.mc.mode not in ("bucket", "kernel"):
            return False                 # select stays the host reference
        if self.mc.device_fast_path is not None:
            return self.mc.device_fast_path
        return self.mc.store == "device"

    def _embed(self, hiddens, lengths=None):
        key = ("embed", lengths is not None)
        fn = self._jit_cache.get(key)
        if fn is None:
            pool, act = self.embedder.pool, self.embedder.act
            from repro.core.embedding import embed_apply
            if lengths is None:
                fn = jax.jit(lambda p, h: embed_apply(p, h, pool, act))
            else:
                fl = self.store.apm_shape[-1]   # chunk-scale anchor
                fn = jax.jit(lambda p, h, ln: embed_apply(
                    p, h, pool, act, lengths=ln, full_len=fl))
            self._jit_cache[key] = fn
        if lengths is None:
            return fn(self.embedder.params, hiddens)
        return fn(self.embedder.params, hiddens,
                  jnp.asarray(lengths, jnp.int32))

    def _calibrate(self, hiddens, apms, n_pairs=256):
        """Fit sim ≈ a·dist + b so search distances predict similarity."""
        rng = np.random.default_rng(0)
        n = hiddens.shape[0]
        ia, ib = rng.integers(0, n, n_pairs), rng.integers(0, n, n_pairs)
        ea = np.asarray(self._embed(jnp.asarray(hiddens[ia])))
        eb = np.asarray(self._embed(jnp.asarray(hiddens[ib])))
        dist = np.linalg.norm(ea - eb, axis=-1)
        sim = np.asarray(jax.vmap(similarity_score)(
            jnp.asarray(apms[ia]), jnp.asarray(apms[ib])))
        if np.std(dist) < 1e-9:
            self.sim_cal = (0.0, float(np.mean(sim)))
        else:
            a, b = np.polyfit(dist, sim, 1)
            self.sim_cal = (float(a), float(b))

    def predict_sim(self, dist: np.ndarray) -> np.ndarray:
        a, b = self.sim_cal
        return a * dist + b

    def suggest_levels(self, batches) -> Dict[str, float]:
        """Per-model threshold levels (paper Table 2 tunes these per model;
        §5.4 suggests an autotuner). Percentiles of the top-1 predicted
        similarity on calibration queries: conservative admits only the
        best-matched quartile, aggressive admits three quartiles."""
        sims = []
        for batch in batches:
            h = bb.embed_tokens(self.params, batch["tokens"], self.cfg)
            for li, kind, lp in self._iter_layers():
                if li in self.layers and kind in ("attn", "mla"):
                    x = bb.norm_apply(lp["norm1"], h, self.cfg.norm)
                    emb = self._embed(x)
                    dist, _ = self.index.search(np.asarray(emb), 1)
                    sims.extend(self.predict_sim(dist[:, 0]).tolist())
                h = self._layer_plain(lp, h, kind, li, None,
                                      jnp.broadcast_to(
                                          jnp.arange(h.shape[1],
                                                     dtype=jnp.int32),
                                          h.shape[:2]))
        sims = np.asarray(sims)
        return {"conservative": float(np.percentile(sims, 75)),
                "moderate": float(np.percentile(sims, 50)),
                "aggressive": float(np.percentile(sims, 25))}

    # ------------------------------------------------------------------ infer
    def infer(self, batch, *, threshold: Optional[float] = None,
              active_layers: Optional[Sequence[int]] = None,
              stats: Optional[MemoStats] = None, use_memo: bool = True):
        """Memoized forward. Returns (logits, stats).

        ``batch`` may carry ``lengths`` (B,) for padded variable-length
        inputs (tokens past a sequence's length are padding: masks flow
        through attention, memo lookup and the head) and ``n_valid`` (the
        runtime's batch padding — trailing rows are shape filler and are
        excluded from stats and admission). Variable length is served by
        the device fast path, the select reference and kernel mode (the
        memo_attention ``lengths`` operand); only the host-synchronous
        bucket path stays fixed-length."""
        thr = self.mc.threshold if threshold is None else threshold
        active = set(self.layers if active_layers is None else active_layers)
        st = stats or MemoStats()
        cfg = self.cfg
        if self.is_encdec:
            return self._infer_encdec(batch, thr, active, st, use_memo)
        if use_memo and self._use_fast_path():
            # step-wise executor with inline (synchronous batch-boundary)
            # maintenance — the MemoServer runtime calls the same three
            # steps but moves apply_maintenance onto its worker thread
            prep = self.prepare_batch(batch, threshold=thr,
                                      active_layers=active)
            self.run_layers(prep)
            out, st, payload = self.finalize(prep, stats=st)
            self.apply_maintenance(payload, stats=st)
            return out, st
        capture = self._capture_now(use_memo)
        if use_memo:
            self._serve_batches += 1
        tokens = batch["tokens"]
        lengths = batch.get("lengths")
        if lengths is not None and use_memo and self.mc.mode == "bucket":
            raise ValueError(
                "variable-length batches are served by the device fast "
                "path, the select reference, or kernel mode (the "
                "memo_attention lengths operand); the host-synchronous "
                "bucket path is fixed-length")
        B, S = tokens.shape[0], tokens.shape[1]
        n_valid = int(batch.get("n_valid", B))
        st.n_inputs += n_valid
        h = bb.embed_tokens(self.params, tokens, cfg)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, S))
        kpad = None
        if lengths is not None:
            kpad = (jnp.arange(S, dtype=jnp.int32)[None, :]
                    < jnp.asarray(lengths, jnp.int32)[:, None])

        for li, kind, lp in self._iter_layers():
            memo = None
            if use_memo and li in active and kind in ("attn", "mla") \
                    and self.db is not None:
                memo = self._lookup(lp, h, kind, thr, st, li,
                                    positions=positions, capture=capture,
                                    lengths=lengths, kpad=kpad,
                                    n_valid=n_valid)
            t0 = time.perf_counter()
            if memo is not None and self.mc.mode == "bucket":
                h = self._layer_bucket(lp, h, kind, li, memo, positions)
            elif memo is not None and self.mc.mode == "kernel" \
                    and kind == "attn":
                h = self._layer_kernel(lp, h, li, memo, positions,
                                       lengths=lengths)
            else:
                h = self._layer_plain(lp, h, kind, li, memo, positions,
                                      kpad=kpad)
            jax.block_until_ready(h)
            st.t_attn += time.perf_counter() - t0
        self._flush_admissions(st)        # batch boundary: admit + sync
        if cfg.n_classes:
            return bb.classify_from_hidden(self.params, h, cfg,
                                           kpad=kpad), st
        return bb.logits_from_hidden(self.params, h, cfg), st

    # ------------------------------------- step-wise fast-path executor
    def prepare_batch(self, batch, *, threshold: Optional[float] = None,
                      active_layers: Optional[Sequence[int]] = None,
                      sync_store: bool = True,
                      prefill: bool = False) -> PreparedBatch:
        """Stage one device-resident batch (DESIGN.md §2.7): freeze the
        policy inputs (threshold, active layers, admission sampling), read
        the store snapshot the WHOLE batch will serve against, and run the
        prologue jit (token embed, positions, padding mask). The serving
        runtime owns batching and calls prepare/run/finalize itself;
        ``infer`` composes them with inline maintenance.

        ``sync_store=False`` is the async-maintenance contract: the
        serving thread never mutates the store — it reads the latest
        atomically-published snapshot and leaves sync to the worker.

        ``prefill=True`` stages a memoized causal prefill (DESIGN.md
        §2.13): the batch additionally carries per-layer decode-cache
        templates, memoized layers run ``_layer_fused_prefill`` (a hit
        materializes the decode cache from the stored KV entry), and
        ``finalize`` returns ``(last_logits, caches)``."""
        if not self._use_fast_path():
            raise RuntimeError(
                "prepare_batch drives the device fast path; build() the "
                "engine in bucket/kernel mode (select and host paths go "
                "through infer())")
        cfg = self.cfg
        tokens = jnp.asarray(batch["tokens"])
        lengths = batch.get("lengths")
        thr = self.mc.threshold if threshold is None else float(threshold)
        active = set(self.layers if active_layers is None
                     else active_layers)
        capture = self._capture_now(True, prefill=prefill)
        self._serve_batches += 1
        if sync_store:
            self.store.sync()     # generation-counted: no-op unless stale
        view = self.store.snapshot
        if view is None:          # bootstrap: materialize + publish once
            self.store.sync()
            view = self.store.snapshot
        B, S = tokens.shape[0], tokens.shape[1]
        n_valid = int(batch.get("n_valid", B))
        cache_len, cache_tpls = 0, None
        if prefill:
            if not self.mc.prefill.enabled:
                raise RuntimeError(
                    "prefill serving needs PrefillSpec(enabled=True) at "
                    "build time — the store must carry KV-bearing entries")
            if not isinstance(self.store.codec, PrefillCodec):
                raise RuntimeError(
                    "this store's entries carry no KV parts; rebuild (or "
                    "re-save) it with prefill_enabled=True")
            self._check_prefill_supported()
            cache_len = self._prefill_cache_len(S)
            cache_tpls = self._split_caches(
                self.model.init_caches(B, cache_len))
            for li in sorted(set(self.layers) & active):
                cl = bb.cache_len_from(cache_tpls[li])
                if cl < S:
                    raise ValueError(
                        f"layer {li} decode cache holds {cl} slots < "
                        f"prompt length {S} (sliding windows shorter "
                        f"than the prompt cannot replay a stored "
                        f"prefix)")
        t0 = time.perf_counter()
        key = ("prolog", lengths is not None)
        prolog = self._jit_cache.get(key)
        if prolog is None:
            def prolog(params, tokens, ln):
                h = bb.embed_tokens(params, tokens, cfg)
                S = tokens.shape[1]
                positions = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), tokens.shape[:2])
                kpad = (None if ln is None else
                        jnp.arange(S, dtype=jnp.int32)[None, :]
                        < ln[:, None])
                return h, positions, kpad
            prolog = self._jit_cache[key] = jax.jit(prolog)
        len_dev = (None if lengths is None
                   else jnp.asarray(lengths, jnp.int32))
        if lengths is not None and not isinstance(lengths, np.ndarray):
            lengths = np.asarray(lengths)
        h, positions, kpad = prolog(self.params, tokens, len_dev)
        return PreparedBatch(
            tokens=tokens, h=h, positions=positions, kpad=kpad,
            lengths_dev=len_dev, lengths=lengths,
            n_valid=n_valid, thr=thr, active=active, capture=capture,
            view=view, t0=t0, prefill=prefill, cache_len=cache_len,
            cache_tpls=cache_tpls)

    def run_layers(self, prep: PreparedBatch) -> PreparedBatch:
        """The device-resident serving loop (DESIGN.md §2): every layer is
        a chained jitted dispatch — fused lookup (embed → nn_search →
        threshold → length gate → gather) feeding the layer body — with
        ZERO per-layer host synchronization (the one barrier lives in
        ``finalize``). Stats are event-based: hit masks, predicted sims
        and matched slots accumulate as device arrays in ``prep.pend``.
        With ``prep.capture`` (online admission), miss embeddings + APMs
        are STAGED ON DEVICE the same way — the loop never blocks."""
        thr_dev = jnp.float32(prep.thr)
        h = prep.h
        if prep.prefill:
            # memoized causal prefill: memoized layers hand back the
            # layer's decode cache alongside h (hits from the stored KV
            # entry, misses from the freshly computed K/V); every other
            # layer runs the backbone's exact prefill step
            for li, kind, lp in self._iter_layers():
                if li in prep.active and kind == "attn":
                    h, ck, cv, *rest = self._layer_fused_prefill(
                        lp, h, li, thr_dev, prep.positions,
                        view=prep.view, cache_tpl=prep.cache_tpls[li],
                        kpad=prep.kpad, qlen=prep.lengths_dev,
                        capture=prep.capture)
                    prep.caches_by_li[li] = {"k": ck, "v": cv}
                    prep.pend.append((li, *rest))
                else:
                    h, c = self._layer_plain_prefill(
                        lp, h, kind, li, prep.positions,
                        prep.cache_tpls[li], kpad=prep.kpad)
                    prep.caches_by_li[li] = c
            prep.h = h
            return prep
        for li, kind, lp in self._iter_layers():
            if li in prep.active and kind in ("attn", "mla"):
                h, *rest = self._layer_fused(
                    lp, h, kind, li, thr_dev, prep.positions,
                    view=prep.view, kpad=prep.kpad,
                    qlen=prep.lengths_dev, capture=prep.capture)
                prep.pend.append((li, *rest))
            else:
                h = self._layer_plain(lp, h, kind, li, None, prep.positions,
                                      kpad=prep.kpad)
        prep.h = h
        return prep

    def finalize(self, prep: PreparedBatch,
                 stats: Optional[MemoStats] = None):
        """Head jit + the ONE trailing barrier, then the event-based stats
        drain. Returns ``(outputs, stats, payload)`` — the payload carries
        every piece of host-tier store work from this batch; the caller
        decides WHERE it runs (inline vs the maintenance worker)."""
        st = stats or MemoStats()
        cfg = self.cfg
        if prep.prefill:
            # the prefill head byte-mirrors Model.prefill (last-position
            # logits), so exact-vs-memoized parity compares like for like
            headpf = self._jit_cache.get("headpf")
            if headpf is None:
                def headpf(params, h):
                    return bb.logits_from_hidden(
                        params, h[:, -1:], cfg)[:, 0]
                headpf = self._jit_cache["headpf"] = jax.jit(headpf)
            logits = jax.block_until_ready(
                headpf(self.params, prep.h))                # ONE barrier
            out = (logits, self._merge_caches(prep.caches_by_li))
        else:
            key = ("head", prep.kpad is not None)
            head = self._jit_cache.get(key)
            if head is None:
                def head(params, h, kpad):
                    return (bb.classify_from_hidden(params, h, cfg,
                                                    kpad=kpad)
                            if cfg.n_classes
                            else bb.logits_from_hidden(params, h, cfg))
                head = self._jit_cache[key] = jax.jit(head)
            out = jax.block_until_ready(
                head(self.params, prep.h, prep.kpad))       # ONE barrier
        dt = time.perf_counter() - prep.t0
        st.n_inputs += prep.n_valid
        st.t_total += dt
        st.t_attn += dt
        payload = self._drain_stats(prep, st)
        return out, st, payload

    def _layer_fused(self, lp, h, kind, li, thr_dev, positions, view,
                     kpad=None, qlen=None, capture: bool = False):
        """The fused serving layer: embed → nn_search → threshold → gather
        → attention → channel mixer, ONE jitted dispatch per layer, device
        arrays in and out (no np.asarray, no block_until_ready). Returns
        (h', sims, hits, slots) — plus (embs, apms_f16) under ``capture``,
        staged on device for the batch-boundary admission drain; the hit
        decision itself is consumed on-device.

        * ``bucket`` — rows are sorted hit-first ON DEVICE (stable argsort
          of the hit mask) and processed in fixed ``bucket_quantum``-sized
          quanta; each quantum picks its path with an XLA conditional on a
          device scalar. After the sort at most ONE quantum is mixed, so
          hit quanta genuinely skip Q/K projection + QKᵀ + softmax and
          miss quanta skip the memo combine — the same compute savings as
          host-side bucketing, but the batch composition never leaves the
          accelerator and shapes stay static (no recompiles across hit
          counts, unlike the host path's per-bucket-size cache entries).
        * ``kernel`` — ONE fused dispatch end to end: the search runs
          with ``fused=True`` (the one-matmul prologue, reusing the
          snapshot's cached DB norms) so the only Pallas kernel a
          memoized layer issues is memo_attention itself. The APM gather
          is elided entirely: the kernel gathers its own tiles from the
          device DB via the scalar-prefetched hit index, and the hit
          flag drives the BlockSpec index maps — hit programs alias the
          Q/K fetch to one resident tile and stream only APM tiles, miss
          programs alias the APM (and int8 scale-sliver) fetch and run
          pure flash attention, never touching the DB or the host arena.
          Under the int8 codec the kernel gathers codes + scale slivers
          and dequantizes in VMEM (the fused-dequant gather, DESIGN.md
          §2.6). On CPU the same math runs as the one-matmul XLA form
          (``_kernel_impl``); variable length rides the ``lengths``
          operand instead of erroring.

        Compression plumbing: the device DB rides in as its codec
        ``parts`` tuple and the index as its ``search_args`` pytree —
        read from the ``view`` (a StoreSnapshot), so one batch serves one
        atomically-published store generation end to end; an index
        rebuild or codec-shape change retraces automatically because the
        traced pytree changes.

        Variable length (``qlen``/``kpad`` both set): the embedding pools
        mask-aware over the true length, the hit decision additionally
        requires the matched entry's stored length to EQUAL the query's
        (a padded APM row is only valid at its own length), the gathered
        arena rows are sliced to the bucket length, and every attention
        branch masks pad keys.
        """
        cfg = self.cfg
        kernel_path = self.mc.mode == "kernel" and kind == "attn"
        varlen = qlen is not None
        impl = self._kernel_impl if kernel_path else None
        key = ("fused", kernel_path, kind, li if cfg.moe else 0, h.shape,
               self.mc.device_quanta, capture, view.codec_key,
               view.index_key, varlen, impl)
        fn = self._jit_cache.get(key)
        if fn is None:
            pool, act = self.embedder.pool, self.embedder.act
            from repro.core.embedding import embed_apply
            interpret = self._interpret
            codec = self.store.codec
            codec_name = codec.name
            # search_device is pure given ``args``; the instance only
            # contributes static config (nprobe/backend), which is fixed
            # per store — so closing over this view's index is safe even
            # after a rebuild swaps in a new instance of the same class
            # (the class itself is part of the jit key via index_key)
            index = view.index
            # sharded store (DESIGN.md §2.12): the index returns the
            # winner's codec rows FROM its single-collective combine —
            # the device arenas are position-indexed per shard, so a
            # slot-id gather against them would be wrong (and a second
            # cross-shard collective)
            sharded = getattr(index, "is_sharded", False)
            f_memo = (attn_mod.gqa_apply_memo if kind == "attn"
                      else attn_mod.mla_apply_memo)
            f_attn = (attn_mod.gqa_apply if kind == "attn"
                      else attn_mod.mla_apply)
            mask_kind = "causal" if cfg.causal else "bidir"
            B = h.shape[0]
            # quanta must tile the batch; otherwise one whole-batch quantum
            nq = (self.mc.device_quanta
                  if (1 < self.mc.device_quanta <= B
                      and B % self.mc.device_quanta == 0) else 1)

            def bucketed(lp, xs, apm, hit, pos, kp, size):
                def all_hit(ops):
                    xs, apm, hit, pos, kp = ops
                    return f_memo(lp["mix"], xs, cfg,
                                  apm.astype(jnp.float32))

                def all_miss(ops):
                    xs, apm, hit, pos, kp = ops
                    y, _ = f_attn(lp["mix"], xs, cfg, positions=pos,
                                  mask_kind=mask_kind,
                                  window=cfg.sliding_window, kpad=kp)
                    return y

                def mixed(ops):
                    xs, apm, hit, pos, kp = ops
                    y, _ = f_attn(lp["mix"], xs, cfg, positions=pos,
                                  mask_kind=mask_kind,
                                  window=cfg.sliding_window, kpad=kp,
                                  memo=attn_mod.Memo(apm=apm, hit=hit))
                    return y

                n_hit = jnp.sum(hit.astype(jnp.int32))
                return jax.lax.cond(
                    n_hit == size, all_hit,
                    lambda ops: jax.lax.cond(n_hit == 0, all_miss, mixed,
                                             ops),
                    (xs, apm, hit, pos, kp))

            arena_len = self.store.apm_shape[-1]

            def run(lp, emb_p, sargs, db_parts, ent_lens, h, thr, a, b,
                    positions, qlen, kpad):
                x = bb.norm_apply(lp["norm1"], h, cfg.norm)
                emb = embed_apply(emb_p, x, pool, act, lengths=qlen,
                                  full_len=arena_len)
                # fused=True on the kernel path forces the one-matmul
                # search prologue so memo_attention is the layer's ONLY
                # Pallas dispatch (the norms cached in sargs keep it cheap)
                if sharded:
                    d2, idx, drows = index.search_fetch(
                        emb, args=sargs, parts=db_parts)
                else:
                    drows = None
                    d2, idx = index.search_device(emb, args=sargs,
                                                  fused=kernel_path)
                dist = jnp.sqrt(jnp.maximum(d2[:, 0], 0.0))
                sim = a * dist + b
                hit = sim > thr
                idx0 = idx[:, 0].astype(jnp.int32)
                S = x.shape[1]
                # the length gate — ALWAYS on: a hit may only reuse an
                # APM captured at the query's own true length (a
                # fixed-length batch's true length is S); without it a
                # fixed-length query could replay a shorter entry whose
                # rows past its length are hard zeros
                hit = hit & (jnp.take(ent_lens, idx0)
                             == (qlen if varlen else S))

                def gather_apm():
                    """Compressed gather + on-device dequant — the only
                    place the decoded APM batch exists. Decoded THROUGH
                    f16 (host-decode parity) but returned as f32: the
                    cast fuses the rounding into the dequant pipeline,
                    whereas an f16 result would materialize as a cond
                    operand — software-emulated f16 stores are ~4× the
                    whole dequant cost on CPU. Arena rows are stored at
                    the calibration length; padded-row gathers slice to
                    this bucket's length (parity with the select path's
                    host-side slice)."""
                    rows = (drows if sharded
                            else tuple(jnp.take(p, idx0, axis=0)
                                       for p in db_parts))
                    apm = codec.decode_rows(rows).astype(jnp.float32)
                    if apm.shape[-1] != S:
                        apm = apm[..., :S, :S]
                    return apm

                if kernel_path:
                    from repro.kernels.memo_attention.ops import \
                        memo_attention
                    qq, kk, vv = attn_mod._qkv(lp["mix"], x, cfg, positions)
                    blk = max(8, min(128, S))
                    kw = dict(causal=cfg.causal, window=cfg.sliding_window,
                              block_q=blk, block_k=blk, impl=impl,
                              interpret=(interpret if impl == "pallas"
                                         else None))
                    if varlen:      # padded key positions mask per sequence
                        kw["lengths"] = qlen
                    if codec_name == "int8" and not sharded:
                        # fused-dequant gather: int8 tiles + scale slivers,
                        # dequantized in the kernel's VMEM
                        out = memo_attention(
                            qq, kk, vv, db_parts[0], idx0,
                            hit.astype(jnp.int32), db_scales=db_parts[1],
                            **kw)
                    elif codec_name == "f16" and not sharded:
                        out = memo_attention(
                            qq, kk, vv, db_parts[0], idx0,
                            hit.astype(jnp.int32), **kw)
                    else:
                        # factorized codecs — and ANY codec on the
                        # sharded path, whose arenas are position-
                        # indexed: decode the B gathered rows (not the
                        # DB) and feed them as a B-row database
                        out = memo_attention(
                            qq, kk, vv, gather_apm(),
                            jnp.arange(B, dtype=jnp.int32),
                            hit.astype(jnp.int32), **kw)
                    y = jnp.einsum("bshe,hed->bsd", out, lp["mix"]["wo"])
                elif nq == 1:
                    apm = gather_apm()
                    y = bucketed(lp, x, apm, hit, positions, kpad, B)
                else:
                    apm = gather_apm()
                    order = jnp.argsort(jnp.logical_not(hit))  # hits first
                    qs = B // nq
                    x_s = jnp.take(x, order, 0)
                    apm_s = jnp.take(apm, order, 0)
                    hit_s = jnp.take(hit, order, 0)
                    pos_s = jnp.take(positions, order, 0)
                    kp_s = (None if kpad is None
                            else jnp.take(kpad, order, 0))
                    parts = [bucketed(lp, x_s[g * qs:(g + 1) * qs],
                                      apm_s[g * qs:(g + 1) * qs],
                                      hit_s[g * qs:(g + 1) * qs],
                                      pos_s[g * qs:(g + 1) * qs],
                                      None if kp_s is None
                                      else kp_s[g * qs:(g + 1) * qs], qs)
                             for g in range(nq)]
                    y = jnp.take(jnp.concatenate(parts, 0),
                                 jnp.argsort(order), 0)
                out = (self._chan_tail(lp, h + y, li), sim, hit, idx0)
                if capture:
                    # miss capture for online admission: the TRUE APM of
                    # this input, computed exactly like the miss path (so
                    # an admitted entry replays bit-for-bit). Only the apm
                    # output is consumed, so XLA dead-code-eliminates the
                    # probe's APM·V and output projection; staged in the
                    # arena dtype to halve the drain transfer.
                    _, apm_cap = f_attn(lp["mix"], x, cfg,
                                        positions=positions,
                                        mask_kind=mask_kind,
                                        window=cfg.sliding_window,
                                        kpad=kpad, return_apm=True)
                    out = out + (emb, apm_cap.astype(jnp.float16))
                return out
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, self.embedder.params, view.search_args,
                  view.db_parts, view.lengths, h, thr_dev,
                  jnp.float32(view.sim_a), jnp.float32(view.sim_b),
                  positions, qlen, kpad)

    def _layer_fused_prefill(self, lp, h, li, thr_dev, positions, view,
                             cache_tpl, kpad=None, qlen=None,
                             capture: bool = False):
        """The fused memoized-prefill layer (DESIGN.md §2.13): ONE jitted
        dispatch extending ``_layer_fused`` with the KV leg. The gather
        decodes the entry's KV suffix next to its APM; hit quanta skip
        Q/K projection + QKᵀ + softmax via the memo-only attention AND
        take their decode cache straight from the stored KV; miss quanta
        run exact attention and cache their freshly computed K/V. Both
        legs zero-pad the cache to ``cache_len`` — the same convention
        as ``gqa_prefill_cache`` — so a hit's cache and an exact prefill
        cache differ only by the KV codec's quantization. Returns
        (h', k_cache, v_cache, sims, hits, slots[, embs, apms, kvs]).

        Kernel-mode engines also land here for prefill batches:
        memo_attention produces attention outputs only (it cannot hand
        K/V back), so prefill always uses the bucketed-quanta
        formulation."""
        cfg = self.cfg
        varlen = qlen is not None
        Sc = bb.cache_len_from(cache_tpl)
        cdt = jax.tree.leaves(cache_tpl)[0].dtype
        key = ("fusedpf", li if cfg.moe else 0, h.shape,
               self.mc.device_quanta, capture, view.codec_key,
               view.index_key, varlen, Sc, cdt)
        fn = self._jit_cache.get(key)
        if fn is None:
            pool, act = self.embedder.pool, self.embedder.act
            from repro.core.embedding import embed_apply
            codec = self.store.codec
            index = view.index
            sharded = getattr(index, "is_sharded", False)
            B = h.shape[0]
            nq = (self.mc.device_quanta
                  if (1 < self.mc.device_quanta <= B
                      and B % self.mc.device_quanta == 0) else 1)
            n_kv, dh = cfg.n_kv_heads, cfg.head_dim
            arena_len = self.store.apm_shape[-1]

            def true_kv(lp, xs, pos, kp):
                """Exact post-RoPE K/V of a (sub-)batch, padded rows
                zeroed so a served miss cache and an admitted entry both
                follow the stored-KV convention (zeros past the true
                length)."""
                _, k, v = attn_mod._qkv(lp["mix"], xs, cfg, pos)
                if kp is not None:
                    m = kp[:, :, None, None].astype(k.dtype)
                    k, v = k * m, v * m
                return k.astype(jnp.float32), v.astype(jnp.float32)

            def bucketed(lp, xs, apm, mk, mv, hit, pos, kp, size):
                def all_hit(ops):
                    xs, apm, mk, mv, hit, pos, kp = ops
                    y = attn_mod.gqa_apply_memo(
                        lp["mix"], xs, cfg, apm.astype(jnp.float32))
                    return y, mk, mv

                def all_miss(ops):
                    xs, apm, mk, mv, hit, pos, kp = ops
                    y, _ = attn_mod.gqa_apply(
                        lp["mix"], xs, cfg, positions=pos,
                        mask_kind="causal", window=cfg.sliding_window,
                        kpad=kp)
                    k, v = true_kv(lp, xs, pos, kp)
                    return y, k, v

                def mixed(ops):
                    xs, apm, mk, mv, hit, pos, kp = ops
                    y, _ = attn_mod.gqa_apply(
                        lp["mix"], xs, cfg, positions=pos,
                        mask_kind="causal", window=cfg.sliding_window,
                        kpad=kp, memo=attn_mod.Memo(apm=apm, hit=hit))
                    k, v = true_kv(lp, xs, pos, kp)
                    m = hit[:, None, None, None]
                    return y, jnp.where(m, mk, k), jnp.where(m, mv, v)

                n_hit = jnp.sum(hit.astype(jnp.int32))
                return jax.lax.cond(
                    n_hit == size, all_hit,
                    lambda ops: jax.lax.cond(n_hit == 0, all_miss, mixed,
                                             ops),
                    (xs, apm, mk, mv, hit, pos, kp))

            def run(lp, emb_p, sargs, db_parts, ent_lens, h, thr, a, b,
                    positions, qlen, kpad):
                x = bb.norm_apply(lp["norm1"], h, cfg.norm)
                emb = embed_apply(emb_p, x, pool, act, lengths=qlen,
                                  full_len=arena_len)
                if sharded:
                    d2, idx, drows = index.search_fetch(
                        emb, args=sargs, parts=db_parts)
                else:
                    drows = None
                    d2, idx = index.search_device(emb, args=sargs)
                dist = jnp.sqrt(jnp.maximum(d2[:, 0], 0.0))
                sim = a * dist + b
                hit = sim > thr
                idx0 = idx[:, 0].astype(jnp.int32)
                S = x.shape[1]
                # the length gate (see _layer_fused) — doubly load-
                # bearing here: a replayed KV prefix is only valid at
                # the length it was captured at
                hit = hit & (jnp.take(ent_lens, idx0)
                             == (qlen if varlen else S))
                rows = (drows if sharded
                        else tuple(jnp.take(p, idx0, axis=0)
                                   for p in db_parts))
                apm = codec.decode_rows(rows).astype(jnp.float32)
                if apm.shape[-1] != S:
                    apm = apm[..., :S, :S]
                kv = codec.decode_kv_rows(rows).astype(jnp.float32)
                mk, mv = unstack_kv_rows(kv[:, :, :S], n_kv, dh)
                if nq == 1:
                    y, k_new, v_new = bucketed(
                        lp, x, apm, mk, mv, hit, positions, kpad, B)
                else:
                    order = jnp.argsort(jnp.logical_not(hit))
                    inv = jnp.argsort(order)
                    qs = B // nq

                    def take(arr):
                        return (None if arr is None
                                else jnp.take(arr, order, 0))
                    x_s, apm_s, mk_s, mv_s = map(take, (x, apm, mk, mv))
                    hit_s, pos_s, kp_s = map(take, (hit, positions, kpad))
                    ys, ks, vs = [], [], []
                    for g in range(nq):
                        sl = slice(g * qs, (g + 1) * qs)
                        yq, kq, vq = bucketed(
                            lp, x_s[sl], apm_s[sl], mk_s[sl], mv_s[sl],
                            hit_s[sl], pos_s[sl],
                            None if kp_s is None else kp_s[sl], qs)
                        ys.append(yq)
                        ks.append(kq)
                        vs.append(vq)
                    y = jnp.take(jnp.concatenate(ys, 0), inv, 0)
                    k_new = jnp.take(jnp.concatenate(ks, 0), inv, 0)
                    v_new = jnp.take(jnp.concatenate(vs, 0), inv, 0)
                pad = ((0, 0), (0, Sc - S), (0, 0), (0, 0))
                ck = jnp.pad(k_new, pad).astype(cdt)
                cv = jnp.pad(v_new, pad).astype(cdt)
                out = (self._chan_tail(lp, h + y, li), ck, cv,
                       sim, hit, idx0)
                if capture:
                    # miss capture: the true APM + KV, computed exactly
                    # like the miss path (an admitted entry replays
                    # bit-for-bit); only these outputs are consumed, so
                    # XLA dead-code-eliminates the probe's APM·V
                    _, apm_cap = attn_mod.gqa_apply(
                        lp["mix"], x, cfg, positions=positions,
                        mask_kind="causal", window=cfg.sliding_window,
                        kpad=kpad, return_apm=True)
                    kc, vc = true_kv(lp, x, positions, kpad)
                    kv_cap = jnp.stack(
                        [kc.reshape(B, S, -1), vc.reshape(B, S, -1)],
                        1).astype(jnp.float16)
                    out = out + (emb, apm_cap.astype(jnp.float16), kv_cap)
                return out
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, self.embedder.params, view.search_args,
                  view.db_parts, view.lengths, h, thr_dev,
                  jnp.float32(view.sim_a), jnp.float32(view.sim_b),
                  positions, qlen, kpad)

    def _layer_plain_prefill(self, lp, h, kind, li, positions, cache,
                             kpad=None):
        """Non-memoized layers of a prefill batch: the backbone's exact
        prefill step (attention + cache build for attn/mla, recurrent
        state for the linear mixers) as one jitted dispatch."""
        key = ("plainpf", kind, li if self.cfg.moe else 0, h.shape,
               kpad is not None, bb.cache_len_from(cache))
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg

            def run(lp, h, positions, cache, kpad):
                out, c, _, _ = bb._layer_apply(
                    lp, h, cfg, kind, li, mode="prefill",
                    positions=positions, pos=None, cache=cache,
                    kpad=kpad)
                return out, c
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, h, positions, cache, kpad)

    # ------------------------------------------------------- prefill API
    def prefill(self, batch, *, threshold: Optional[float] = None,
                active_layers: Optional[Sequence[int]] = None,
                stats: Optional[MemoStats] = None):
        """Memoized causal prefill (DESIGN.md §2.13). Returns
        (last-token logits (B, V), decode caches, stats): a hit skips
        the layer's attention AND materializes that layer's decode cache
        from the stored KV entry; a miss runs exact prefill and (under
        admission sampling) captures APM + KV. Decode continues via
        ``self.model.decode_step`` on the returned caches."""
        st = stats or MemoStats()
        prep = self.prepare_batch(batch, threshold=threshold,
                                  active_layers=active_layers,
                                  prefill=True)
        self.run_layers(prep)
        (logits, caches), st, payload = self.finalize(prep, stats=st)
        self.apply_maintenance(payload, stats=st)
        return logits, caches, st

    def prefill_exact(self, batch, *, cache_len: Optional[int] = None):
        """Exact (memo-free) prefill: the degraded-mode leg the
        MemoServer falls back to, and the parity reference the prefill
        benchmark asserts against. Returns (logits (B, V), caches)."""
        tokens = jnp.asarray(batch["tokens"])
        Sc = (int(cache_len) if cache_len
              else self._prefill_cache_len(int(tokens.shape[1])))
        key = ("pfexact", Sc)
        fn = self._jit_cache.get(key)
        if fn is None:
            model = self.model

            def run(params, tokens):
                return model.prefill(params, {"tokens": tokens},
                                     cache_len=Sc)
            fn = self._jit_cache[key] = jax.jit(run)
        return fn(self.params, tokens)

    def _capture_now(self, use_memo: bool, prefill: bool = False) -> bool:
        """Admission sampling: capture misses on every Nth served batch
        (``admit_every``) when online admission is enabled. With prefill
        memoization on, ONLY prefill batches capture — an APM-only
        admission would store zero KV planes and a later prefill hit
        would replay an empty decode cache."""
        if self.mc.prefill.enabled and not prefill:
            return False
        return (use_memo and self.mc.admit and self.store is not None
                and not self.is_encdec
                and self._serve_batches % max(1, self.mc.admit_every) == 0)

    # --------------------------------------------------- prefill serving
    def _check_prefill_supported(self):
        """Prefill memoization preconditions (DESIGN.md §2.13). The
        causal requirement IS the mask-kind gate: every stored entry was
        captured under the causal prefill mask, and a causal-only engine
        can never replay one against a bidirectional query."""
        if self.is_encdec:
            raise ValueError(
                "prefill memoization needs a decoder-only model (enc-dec "
                "hands no decode cache back from its encoder)")
        if not self.cfg.causal:
            raise ValueError(
                "prefill memoization requires a causal model: stored "
                "entries are causal-prefill states and may only be "
                "replayed under the same mask kind")
        bad = sorted(li for li, kind, _ in self._iter_layers()
                     if li in self.layers and kind != "attn")
        if bad:
            raise ValueError(
                f"prefill memoization serves GQA 'attn' layers only "
                f"(MLA caches latents, not K/V); memoized layers {bad} "
                f"are a different mixer kind")

    def _prefill_cache_len(self, S: int) -> int:
        """Decode-cache length for a prompt of length ``S``:
        ``prefill_cache_len`` if set, else 2·S headroom."""
        cl = self.mc.prefill.cache_len
        Sc = int(cl) if cl else 2 * S
        if Sc < S:
            raise ValueError(
                f"prefill_cache_len={Sc} is shorter than the prompt "
                f"({S}): the decode cache must hold the whole prefix")
        return Sc

    def _kv_probe(self, lp, x):
        """Post-RoPE K/V of one captured block, stacked into the stored
        (B, 2, S, D) plane — the KV side-channel for build-time prefill
        admission. Positions run from 0 (prefill is absolute), so the
        stored K drops into a decode cache verbatim."""
        key = ("kv_probe", x.shape)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg

            def run(lp, x):
                B, S = x.shape[0], x.shape[1]
                positions = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), (B, S))
                _, k, v = attn_mod._qkv(lp["mix"], x, cfg, positions)
                return jnp.stack([k.reshape(B, S, -1),
                                  v.reshape(B, S, -1)],
                                 1).astype(jnp.float16)
            fn = self._jit_cache[key] = jax.jit(run)
        return fn(lp, x)

    def _split_caches(self, caches) -> dict:
        """Flatten a ``model.init_caches`` pytree into {layer_idx: cache}
        — the per-layer view the step-wise prefill executor works in.
        Scan segments carry a leading reps axis; slicing it off here and
        re-stacking in ``_merge_caches`` mirrors exactly what the
        backbone's unroll branch does."""
        out = {}
        for si, seg in enumerate(bb.scan_plan(self.cfg)):
            grp = caches[f"seg{si}"]
            if seg.kind == "single":
                for u in range(len(seg.unit)):
                    out[seg.start + u] = grp[f"l{u}"]
            else:
                for r in range(seg.reps):
                    rep = jax.tree.map(lambda a: a[r], grp)
                    for u in range(len(seg.unit)):
                        out[seg.start + r * len(seg.unit) + u] = rep[f"l{u}"]
        return out

    def _merge_caches(self, by_li: dict):
        """Inverse of ``_split_caches``: {layer_idx: cache} → the segment
        pytree ``model.decode_step`` consumes."""
        caches = {}
        for si, seg in enumerate(bb.scan_plan(self.cfg)):
            if seg.kind == "single":
                caches[f"seg{si}"] = {
                    f"l{u}": by_li[seg.start + u]
                    for u in range(len(seg.unit))}
            else:
                groups = [
                    {f"l{u}": by_li[seg.start + r * len(seg.unit) + u]
                     for u in range(len(seg.unit))}
                    for r in range(seg.reps)]
                caches[f"seg{si}"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *groups)
        return caches

    def _drain_stats(self, prep: PreparedBatch,
                     st: MemoStats) -> MaintenancePayload:
        """Materialize the per-layer device counters in O(1) stacked host
        transfers per batch (TWO: sims+hits as one f32 block, slots as one
        i32 block — plus embs and APMs under capture), after the trailing
        barrier. Rows past ``n_valid`` (runtime batch padding) are
        dropped. Returns the MaintenancePayload — reuse slots and captured
        misses — WITHOUT touching the store: the caller decides where
        maintenance runs (inline vs the MemoServer worker)."""
        pend = prep.pend
        out = MaintenancePayload(
            generation=getattr(prep.view, "generation", -1))
        if not pend:
            return out
        nv = prep.n_valid
        payload = np.asarray(jnp.stack(
            [jnp.stack([p[1], p[2].astype(jnp.float32)]) for p in pend]))
        slots = np.asarray(jnp.stack([p[3] for p in pend]))[:, :nv]
        hits = payload[:, 1, :nv] > 0.5                          # (L, nv)
        sims = payload[:, 0, :nv]
        for p, s_row, h_row in zip(pend, sims, hits):
            li = p[0]
            st.n_layer_attempts += int(s_row.shape[0])
            nh = int(h_row.sum())
            st.n_hits += nh
            st.per_layer_hits[li] = st.per_layer_hits.get(li, 0) + nh
            st.sims.extend(s_row.tolist())
        if hits.any():
            out.reuse_slots = slots[hits]
        if prep.capture and len(pend[0]) > 4:
            embs = np.asarray(jnp.stack([p[4] for p in pend]))[:, :nv]
            apms = np.asarray(jnp.stack([p[5] for p in pend]))[:, :nv]
            # prefill capture stages the KV plane at pend[6]
            kvs = (np.asarray(jnp.stack([p[6] for p in pend]))[:, :nv]
                   if len(pend[0]) > 6 else None)
            lens = None if prep.lengths is None else prep.lengths[:nv]
            for l in range(embs.shape[0]):
                miss = ~hits[l]
                if miss.any():
                    out.admissions.append(self._stage_capture(
                        apms[l][miss], embs[l][miss],
                        None if lens is None else lens[miss],
                        None if kvs is None else kvs[l][miss]))
        return out

    def _stage_capture(self, apms, embs, lens, kv=None):
        """Normalize one captured miss block for admission: pad the APMs
        (and the KV plane, when prefill capture staged one) to the arena
        (calibration) length and zero the pad-query rows, so a stored
        entry is identical no matter which bucket captured it — only its
        true length matters (the length gate guarantees it is only ever
        replayed at that length)."""
        S_max = self.store.apm_shape[-1]
        B, H, S = apms.shape[:3]
        if lens is None:
            lens = np.full(B, S, np.int32)
        elif isinstance(lens, np.ndarray):
            lens = lens.astype(np.int32, copy=False)
        else:
            lens = np.asarray(lens, np.int32)
        if S < S_max:
            padded = np.zeros((B, H, S_max, S_max), apms.dtype)
            padded[:, :, :S, :S] = apms
            apms = padded
            if kv is not None:
                pk = np.zeros(kv.shape[:2] + (S_max, kv.shape[-1]),
                              kv.dtype)
                pk[:, :, :S] = kv
                kv = pk
        if (lens < S_max).any():
            row_ok = np.arange(S_max)[None, :] < lens[:, None]
            apms = apms * row_ok[:, None, :, None].astype(apms.dtype)
            if kv is not None:
                kv = kv * row_ok[:, None, :, None].astype(kv.dtype)
        return apms, embs, lens, kv

    def apply_maintenance(self, payload: Optional[MaintenancePayload],
                          stats: Optional[MemoStats] = None) -> None:
        """Run one batch's host-tier store work — reuse-clock feeding,
        budgeted admission + eviction, generation-counted delta sync, and
        periodic recalibration — finishing with an atomic snapshot
        publish. ``infer`` calls this inline (synchronous batch-boundary
        maintenance); the MemoServer's background worker calls it
        off-thread, double-buffered against the next batch's device
        compute (DESIGN.md §2.7). Exactly one maintenance actor may run
        at a time; the MemoStore's lock backstops misuse.

        Retry-safe (the supervised worker's contract, DESIGN.md §2.9):
        payload fields are CONSUMED as they land — reuse feeding and the
        move into ``_pending_admissions`` happen at most once — so
        re-applying a payload whose first attempt died mid-sync cannot
        double-admit; the retry just drives the store back to a clean,
        published generation (the trailing ``device_stale`` sync)."""
        if payload is None or self.store is None:
            return
        st = stats or MemoStats()
        if payload.reuse_slots is not None and payload.reuse_slots.size:
            slots, payload.reuse_slots = payload.reuse_slots, None
            self.store.note_reuse(slots)
        if payload.admissions:
            adds, payload.admissions = payload.admissions, []
            self._pending_admissions.extend(adds)
        self._flush_admissions(st)
        if self.store.device_stale:
            # nothing pending but host/device generations diverged — a
            # previous attempt admitted and then failed to sync (or a
            # quarantine dirtied slots); one generation-counted sync
            # re-converges (a clean store skips this entirely)
            self.store.sync()

    def _flush_admissions(self, st: MemoStats):
        """Batch-boundary admission: push captured misses into the host
        tier under the byte budget, then delta-sync the device tier. Never
        on the per-layer hot path."""
        if not self._pending_admissions:
            return
        pend, self._pending_admissions = self._pending_admissions, []
        apms = np.concatenate([p[0] for p in pend], 0)
        embs = np.concatenate([p[1] for p in pend], 0)
        lens = np.concatenate([p[2] for p in pend], 0)
        # KV planes ride along iff every staged block carries one (APM-
        # only and prefill captures never mix: _capture_now gates them)
        kv = (np.concatenate([p[3] for p in pend], 0)
              if all(p[3] is not None for p in pend) else None)
        cspec = self.mc.capacity
        if (apms.shape[0] and cspec.promote
                and self.store.capacity is not None):
            # async promotion (DESIGN.md §2.11): misses the disk tier can
            # satisfy are re-admitted bit-identically from their durable
            # copies instead of re-encoded from the fresh capture — the
            # promoted rows ride the same delta sync as the admissions
            promoted = self.store.promote_for(
                embs, lens, threshold=float(self.mc.threshold),
                max_promote=int(cspec.promote_max))
            if promoted.any():
                keep = ~promoted
                apms, embs, lens = apms[keep], embs[keep], lens[keep]
                kv = kv[keep] if kv is not None else None
        if apms.shape[0]:
            slots = self.store.admit(apms, embs, lens, kv=kv)
            st.add_admitted(int(slots.size))
            self.store.sync()
            self._flush_count += 1
            if self.mc.recal_every:
                self._recal_buf.append((apms, embs))
                self._recal_buf = self._recal_buf[-16:]   # rolling window
                if self._flush_count % self.mc.recal_every == 0:
                    self._recalibrate_online()
                    # recal changed sim_cal: re-publish so the next batch
                    # serves the refreshed calibration
                    self.store.publish()

    def _recalibrate_online(self, n_pairs: int = 192, blend: float = 0.5):
        """Refit sim ≈ a·dist + b from recently captured misses — each
        carries its embedding AND its true APM, i.e. exactly the data
        build-time ``_calibrate`` uses. Under drift the stale map
        under-predicts similarity (the top-1 match is the right template,
        but its predicted sim starves the threshold); refitting on
        current-traffic pairs restores the threshold's true-similarity
        meaning. Blended (EMA) for stability."""
        apms = np.concatenate([a for a, _ in self._recal_buf], 0)
        embs = np.concatenate([e for _, e in self._recal_buf], 0)
        n = apms.shape[0]
        if n < 8:
            return
        rng = np.random.default_rng(self._serve_batches)
        ia, ib = rng.integers(0, n, n_pairs), rng.integers(0, n, n_pairs)
        dist = np.linalg.norm(embs[ia] - embs[ib], axis=-1)
        if np.std(dist) < 1e-9:
            return
        sim = np.asarray(jax.vmap(similarity_score)(
            jnp.asarray(apms[ia], jnp.float32),
            jnp.asarray(apms[ib], jnp.float32)))
        a, b = np.polyfit(dist, sim, 1)
        a0, b0 = self.sim_cal
        self.sim_cal = (blend * float(a) + (1 - blend) * a0,
                        blend * float(b) + (1 - blend) * b0)

    def _infer_encdec(self, batch, thr, active, st: MemoStats, use_memo):
        """Whisper path: memoized encoder, plain decoder."""
        from repro.models import encdec as ed
        cfg, params = self.cfg, self.params
        frames = batch["frames"]
        st.n_inputs += frames.shape[0]
        h = (frames.astype(params["enc_pos"].dtype)
             + params["enc_pos"][None, : frames.shape[1]])
        ecfg = self.model._ecfg
        positions = jnp.broadcast_to(
            jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2])
        for li in range(cfg.encoder.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["enc_layers"])
            memo = None
            if use_memo and li in active and self.db is not None:
                memo = self._lookup(lp, h, "attn", thr, st, li)
            key = ("enc_layer", memo is not None, h.shape)
            fn = self._jit_cache.get(key)
            if fn is None:
                def run(lp, hh, memo, positions):
                    from repro.models import attention as am
                    from repro.models.layers import mlp_apply
                    x = bb.norm_apply(lp["norm1"], hh, cfg.norm)
                    y, _ = am.gqa_apply(lp["attn"], x, ecfg,
                                        positions=positions,
                                        mask_kind="bidir", memo=memo,
                                        use_rope=False)
                    hh = hh + y
                    x = bb.norm_apply(lp["norm2"], hh, cfg.norm)
                    return hh + mlp_apply(lp["mlp"], x, cfg.act, cfg.glu)
                fn = jax.jit(run)
                self._jit_cache[key] = fn
            h = fn(lp, h, memo, positions)
        enc_h = bb.norm_apply(params["enc_norm"], h, cfg.norm)
        hd, _ = ed.decode_tokens(params, batch["tokens"], enc_h, cfg,
                                 mode="full")
        hd = bb.norm_apply(params["final_norm"], hd, cfg.norm)
        return hd @ params["embed"].T, st

    def _lookup(self, lp, h, kind, thr, st: MemoStats, li,
                positions=None, capture: bool = False, lengths=None,
                kpad=None, n_valid: Optional[int] = None):
        cfg = self.cfg
        S = h.shape[1]
        nv = h.shape[0] if n_valid is None else n_valid
        t0 = time.perf_counter()
        x = bb.norm_apply(lp["norm1"], h, cfg.norm)
        emb = self._embed(x, lengths=lengths)
        jax.block_until_ready(emb)
        t1 = time.perf_counter()
        emb_np = np.asarray(emb)
        dist, idx = self.store.lookup(emb_np, 1)
        sim_est = self.predict_sim(dist[:, 0])
        hit = sim_est > thr
        # length gate (host leg), ALWAYS on — mirrors the fused path: a
        # fixed-length batch's true length is S
        ent = self.store.entry_lengths(idx[:, 0])
        hit = hit & (ent == (np.asarray(lengths, np.int32)
                             if lengths is not None else S))
        t2 = time.perf_counter()
        apm = self.db.get(idx[:, 0])                     # host arena gather
        if apm.shape[-1] != S:
            apm = apm[:, :, :S, :S]      # arena rows sliced to the bucket
        t3 = time.perf_counter()
        st.t_embed += t1 - t0
        st.t_search += t2 - t1
        st.t_fetch += t3 - t2
        st.n_layer_attempts += nv
        nh = int(hit[:nv].sum())
        st.n_hits += nh
        st.per_layer_hits[li] = st.per_layer_hits.get(li, 0) + nh
        st.sims.extend(sim_est[:nv].tolist())
        if capture and positions is not None and (~hit[:nv]).any():
            apm_true = np.asarray(self._apm_probe(lp, x, kind, positions,
                                                  kpad=kpad))
            miss = ~hit[:nv]
            self._pending_admissions.append(self._stage_capture(
                apm_true[:nv][miss], emb_np[:nv][miss],
                None if lengths is None
                else np.asarray(lengths, np.int32)[:nv][miss]))
        # keep the APM batch in the arena dtype (f16) and on the host —
        # the jitted consumer casts on-device (one transfer, no copies)
        return attn_mod.Memo(apm=apm, hit=hit, idx=idx[:, 0])

    def _apm_probe(self, lp, x, kind, positions, kpad=None):
        """The true APM of the normed input, computed with the exact miss
        path semantics — the host-path analogue of the fused capture (only
        the apm output is used, so the probe's APM·V + output projection
        are dead-code-eliminated inside the jit)."""
        key = ("apm_probe", kind, x.shape, kpad is not None)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg
            f_attn = (attn_mod.gqa_apply if kind == "attn"
                      else attn_mod.mla_apply)
            mask_kind = "causal" if cfg.causal else "bidir"

            def run(lp, x, positions, kpad):
                _, apm = f_attn(lp["mix"], x, cfg, positions=positions,
                                mask_kind=mask_kind, kpad=kpad,
                                window=cfg.sliding_window, return_apm=True)
                return apm.astype(jnp.float16)
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, x, positions, kpad)

    # -- layer application --------------------------------------------------
    def _chan_tail(self, lp, h, li):
        """norm2 + channel mixer (moe/mlp) tail shared by every jitted
        layer body — traceable, so it is called INSIDE the jits; one copy
        keeps the fast/host/kernel paths from diverging."""
        cfg = self.cfg
        x = bb.norm_apply(lp["norm2"], h, cfg.norm)
        if bb._chan_kind(cfg, li) == "moe":
            from repro.models import moe as moe_mod
            out, _ = moe_mod.moe_apply(lp["chan"], x, cfg)
        else:
            from repro.models.layers import mlp_apply
            out = mlp_apply(lp["chan"], x, cfg.act, cfg.glu)
        return h + out

    def _layer_plain(self, lp, h, kind, li, memo, positions, kpad=None):
        key = ("plain", kind, li if self.cfg.moe else 0, memo is not None,
               h.shape, kpad is not None)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg

            def run(lp, h, memo, positions, kpad):
                out, _, _, _ = bb._layer_apply(
                    lp, h, cfg, kind, li, mode="full", positions=positions,
                    pos=None, cache=None, memo=memo, kpad=kpad)
                return out
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, h, memo, positions, kpad)

    def _layer_bucket(self, lp, h, kind, li, memo, positions):
        """Split rows into hit/miss buckets; hits use the memo-only
        attention (skips QKᵀ+softmax for real), misses run normally.
        The whole layer (norm → bucketed attention → scatter-combine →
        channel mixer) is ONE jitted dispatch — the engine-level analogue
        of cutting the paper's 'cascaded memory access' chain (§5.3)."""
        cfg = self.cfg
        hit = np.asarray(memo.hit)
        B = h.shape[0]
        hit_idx = np.nonzero(hit)[0]
        miss_idx = np.nonzero(~hit)[0]
        if hit_idx.size == 0:
            return self._layer_plain(lp, h, kind, li, None, positions)
        # power-of-2 bucket padding bounds the number of distinct compiled
        # shapes to log2(B) per layer kind
        q = self.mc.bucket_quantum

        def pad_to(n):
            p = q
            while p < n:
                p *= 2
            return min(p, B)

        nh = pad_to(hit_idx.size)
        nm = pad_to(miss_idx.size) if miss_idx.size else 0
        sel_h = np.concatenate([hit_idx,
                                np.zeros(nh - hit_idx.size, np.int64)])
        sel_m = (np.concatenate([miss_idx,
                                 np.zeros(nm - miss_idx.size, np.int64)])
                 if nm else np.zeros(0, np.int64))
        # ship only the hit APMs, in the arena dtype (f16)
        apm_hit = np.asarray(memo.apm)[sel_h]

        key = ("bucket", kind, li if self.cfg.moe else 0, h.shape, nh, nm)
        fn = self._jit_cache.get(key)
        if fn is None:
            n_hit_real = None  # shapes only; real counts via masks below

            def run(lp, h, apm, sel_h, sel_m, keep_h, keep_m, positions):
                x = bb.norm_apply(lp["norm1"], h, cfg.norm)
                f_memo = (attn_mod.gqa_apply_memo if kind == "attn"
                          else attn_mod.mla_apply_memo)
                y = jnp.zeros_like(h)
                y_hit = f_memo(lp["mix"], jnp.take(x, sel_h, 0), cfg,
                               apm.astype(jnp.float32))
                y = y.at[sel_h].add(y_hit * keep_h[:, None, None])
                if sel_m.shape[0]:
                    f_attn = (attn_mod.gqa_apply if kind == "attn"
                              else attn_mod.mla_apply)
                    y_miss, _ = f_attn(
                        lp["mix"], jnp.take(x, sel_m, 0), cfg,
                        positions=jnp.take(positions, sel_m, 0),
                        mask_kind="causal" if cfg.causal else "bidir",
                        window=cfg.sliding_window)
                    y = y.at[sel_m].add(y_miss * keep_m[:, None, None])
                return self._chan_tail(lp, h + y, li)
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        keep_h = (np.arange(nh) < hit_idx.size).astype(np.float32)
        keep_m = (np.arange(nm) < miss_idx.size).astype(np.float32)
        return fn(lp, h, jnp.asarray(apm_hit), jnp.asarray(sel_h),
                  jnp.asarray(sel_m), jnp.asarray(keep_h),
                  jnp.asarray(keep_m), positions)

    def _layer_kernel(self, lp, h, li, memo, positions, lengths=None):
        """The host-synchronous kernel-mode layer: hits are served by the
        fused memo_attention dispatch — APM tiles gathered from the
        device-resident DB by scalar-prefetched index, the hit flag
        driving the BlockSpec index maps so misses fetch zero DB bytes
        and hits skip the Q/K stream. The implementation is
        ``_kernel_impl`` ("pallas" on accelerators / explicit interpret;
        the one-matmul XLA form on CPU). ``lengths`` (B,) serves
        variable-length batches through the kernel's per-sequence key
        mask."""
        cfg = self.cfg
        self.store.sync()        # generation-counted: no-op unless stale
        hit_idx = jnp.asarray(memo.idx, jnp.int32)
        hit = jnp.asarray(memo.hit, jnp.int32)
        interpret = self._interpret
        impl = self._kernel_impl
        store = self.store
        varlen = lengths is not None
        if varlen:
            lengths = jnp.asarray(lengths, jnp.int32)
        key = ("kernel", li if cfg.moe else 0, h.shape, store.codec.key,
               varlen, impl)
        fn = self._jit_cache.get(key)
        if fn is None:
            codec_name = store.codec.name

            def run(lp, h, db_parts, hit_idx, hit, positions, lengths):
                from repro.kernels.memo_attention.ops import memo_attention
                x = bb.norm_apply(lp["norm1"], h, cfg.norm)
                q, k, v = attn_mod._qkv(lp["mix"], x, cfg, positions)
                S = x.shape[1]
                blk = max(8, min(128, S))
                kw = dict(causal=cfg.causal, window=cfg.sliding_window,
                          block_q=blk, block_k=blk, impl=impl,
                          interpret=(interpret if impl == "pallas"
                                     else None))
                if varlen:
                    kw["lengths"] = lengths
                if codec_name == "int8":   # fused-dequant gather in VMEM
                    out = memo_attention(q, k, v, db_parts[0], hit_idx, hit,
                                         db_scales=db_parts[1], **kw)
                elif codec_name == "f16":
                    out = memo_attention(q, k, v, db_parts[0], hit_idx, hit,
                                         **kw)
                else:                      # factorized: decode B rows only
                    rows = tuple(jnp.take(p, hit_idx, axis=0)
                                 for p in db_parts)
                    out = memo_attention(
                        q, k, v, store.codec.decode_rows(rows),
                        jnp.arange(h.shape[0], dtype=jnp.int32), hit, **kw)
                y = jnp.einsum("bshe,hed->bsd", out, lp["mix"]["wo"])
                return self._chan_tail(lp, h + y, li)
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, h, self.device_db.parts, hit_idx, hit, positions,
                  lengths)

    def _memo_only(self, lp, x, kind, apm):
        key = ("memo_only", kind, x.shape)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg
            f = (attn_mod.gqa_apply_memo if kind == "attn"
                 else attn_mod.mla_apply_memo)
            fn = jax.jit(lambda lp, x, apm: f(lp["mix"], x, cfg, apm))
            self._jit_cache[key] = fn
        return fn(lp, x, apm)

    def _attn_only(self, lp, x, kind, positions):
        key = ("attn_only", kind, x.shape)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg
            mask_kind = "causal" if cfg.causal else "bidir"
            f = attn_mod.gqa_apply if kind == "attn" else attn_mod.mla_apply

            def run(lp, x, positions):
                y, _ = f(lp["mix"], x, cfg, positions=positions,
                         mask_kind=mask_kind, window=cfg.sliding_window)
                return y
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, x, positions)

    def _chan_only(self, lp, h, li):
        key = ("chan", li if self.cfg.moe else 0, h.shape)
        fn = self._jit_cache.get(key)
        if fn is None:
            cfg = self.cfg
            ck = bb._chan_kind(cfg, li)

            def run(lp, h):
                x = bb.norm_apply(lp["norm2"], h, cfg.norm)
                if ck == "moe":
                    from repro.models import moe as moe_mod
                    y, _ = moe_mod.moe_apply(lp["chan"], x, cfg)
                else:
                    from repro.models.layers import mlp_apply
                    y = mlp_apply(lp["chan"], x, cfg.act, cfg.glu)
                return h + y
            fn = jax.jit(run)
            self._jit_cache[key] = fn
        return fn(lp, h)

    # ------------------------------------------------------------- selective
    def _fused_lookup_probe(self, x):
        """The memo overhead the FAST PATH actually pays, as one jitted
        dispatch: embed → device search → compressed gather → dequant —
        exactly the lookup portion of ``_layer_fused``, minus the
        attention both branches share. Used by ``profile``; the old
        host-synchronous chain (numpy search + arena fetch + per-step
        barriers) overstated t_overhead by the round-trips and disabled
        layers the fused path would win on."""
        store = self.store
        key = ("profov", x.shape, store.codec.key,
               type(store.device_index).__name__)
        fn = self._jit_cache.get(key)
        if fn is None:
            pool, act = self.embedder.pool, self.embedder.act
            from repro.core.embedding import embed_apply

            sharded = getattr(store.device_index, "is_sharded", False)

            def run(emb_p, x, sargs, db_parts, a, b):
                emb = embed_apply(emb_p, x, pool, act)
                if sharded:     # rows ride the combine (position-indexed
                    d2, _, rows = store.device_index.search_fetch(
                        emb, args=sargs, parts=db_parts)    # arenas)
                else:
                    d2, idx = store.device_index.search_device(
                        emb, args=sargs)
                    idx0 = idx[:, 0].astype(jnp.int32)
                    rows = tuple(jnp.take(p, idx0, axis=0)
                                 for p in db_parts)
                dist = jnp.sqrt(jnp.maximum(d2[:, 0], 0.0))
                return (a * dist + b,
                        store.codec.decode_rows(rows).astype(jnp.float32))
            fn = self._jit_cache[key] = jax.jit(run)
        a, b = self.sim_cal
        return fn(self.embedder.params, x, self.device_index.search_args,
                  self.device_db.parts, jnp.float32(a), jnp.float32(b))

    def profile(self, batch, *, alpha_from: Optional[MemoStats] = None
                ) -> PerfModel:
        """Offline profiler (paper §5.4): measure per-layer attention time
        and memo overhead on a calibration batch; α comes from calibration
        stats (or a dry lookup pass). t_overhead is measured on the path
        that will serve: the fused-jit lookup when the device fast path
        is active, the host-synchronous chain otherwise."""
        cfg = self.cfg
        fast = self._use_fast_path()
        if fast:
            self.store.sync()      # materialize the tier the probe times
        h = bb.embed_tokens(self.params, batch["tokens"], cfg)
        positions = jnp.broadcast_to(
            jnp.arange(batch["tokens"].shape[1], dtype=jnp.int32),
            batch["tokens"].shape)
        if alpha_from is None:
            st = MemoStats()
            self.infer(batch, stats=st)
            alpha_from = st
        profiles = {}
        for li, kind, lp in self._iter_layers():
            if li not in self.layers:
                h = self._layer_plain(lp, h, kind, li, None, positions)
                continue
            t_attn = timeit_median(
                lambda lp=lp, h=h, k=kind: self._attn_only(lp, h, k,
                                                           positions), reps=3)
            if fast:
                t_over = timeit_median(
                    lambda h=h: self._fused_lookup_probe(h), reps=3)
            else:
                t_over = timeit_median(
                    lambda h=h: self._embed(h), reps=3)
                emb = np.asarray(self._embed(h))
                t0 = time.perf_counter()
                dist, idx = self.index.search(emb, 1)
                self.db.get(idx[:, 0], count_reuse=False)
                t_over += time.perf_counter() - t0
            alpha = (alpha_from.per_layer_hits.get(li, 0)
                     / max(1, alpha_from.n_inputs))
            profiles[li] = LayerProfile(t_attn=t_attn, t_overhead=t_over,
                                        alpha=min(1.0, alpha))
            h = self._layer_plain(lp, h, kind, li, None, positions)
        self.perf = PerfModel(profiles)
        return self.perf

"""MemoStore — the lifecycle-managed two-tier memo subsystem (DESIGN.md §2.5).

AttMemo's database is built offline and frozen; under drifting serving
traffic the hit rate decays unless the store adapts online. MemoStore
owns ALL memoization state — the host tier (`AttentionDB` arena + a
slot-aligned host index) and the device tier (`DeviceDB` + `DeviceIndex`)
— behind one lifecycle API:

* ``lookup(embs, k)``   — host-tier search (the device tier is searched
                          inside the engine's fused jit via
                          ``device_index.search_device``).
* ``admit(apms, embs)`` — online admission under a byte budget: misses
                          captured during serving become entries; slots
                          are recycled from the arena free-list (no
                          compaction, so slot ids are stable and the
                          device tier can be patched in place).
* ``evict(n)``          — reuse-frequency/recency CLOCK over the arena's
                          ``reuse_counts``: hot entries get their counter
                          halved (a decaying second chance), cold entries
                          are released and their index rows tombstoned.
* ``sync()``            — generation-counted incremental device sync:
                          a no-op when nothing changed, a ``.at[slots]``
                          delta of exactly the dirty slots when the
                          preallocated device slack can hold them, and a
                          full re-materialization (with fresh slack) only
                          when the arena outgrew the device allocation.

The engine calls ``sync`` once per batch boundary; because deltas are
host→device pushes of staged numpy rows, the fast path's
zero-per-layer-host-sync invariant (tests/test_fastpath.py) is untouched.

Compression is first-class (DESIGN.md §2.6): the ``codec`` selects the
APM storage format for BOTH tiers (f16 | int8 | lowrank — see
``core/codec.py``), byte budgets and sync receipts are denominated in
codec-true bytes, and the device index flips from exhaustive to the
clustered (IVF) layout once the entry count crosses
``cluster_crossover`` (``device_index_kind="auto"``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.database import AttentionDB, DeviceDB
from repro.core.index import (
    TOMBSTONE, ClusteredDeviceIndex, DeviceIndex, ExactIndex, IVFIndex)


@dataclass
class StoreStats:
    """Lifecycle + transfer accounting (the delta-vs-full receipts)."""
    n_admitted: int = 0
    n_evicted: int = 0
    n_noop_syncs: int = 0
    n_delta_syncs: int = 0
    n_full_syncs: int = 0
    bytes_delta: int = 0          # bytes moved by delta syncs
    bytes_full: int = 0           # bytes moved by full re-materializations

    @property
    def bytes_total(self) -> int:
        return self.bytes_delta + self.bytes_full


class MemoStore:
    """Both memo tiers behind one lifecycle (lookup/admit/evict/sync)."""

    def __init__(self, apm_shape: Tuple[int, int, int], embed_dim: int, *,
                 index_kind: str = "exact", budget_bytes: Optional[int] = None,
                 capacity: int = 64, interpret: Optional[bool] = None,
                 device_slack: float = 1.0, n_lists: Optional[int] = None,
                 mesh=None, codec: str = "f16", apm_rank: Optional[int] = None,
                 device_index_kind: str = "auto",
                 cluster_crossover: int = 4096, nprobe: int = 16,
                 n_clusters: Optional[int] = None):
        self.apm_shape = tuple(apm_shape)
        self.embed_dim = embed_dim
        self.index_kind = index_kind
        self.budget_bytes = budget_bytes
        self.device_slack = device_slack
        self._interpret = interpret
        self._mesh = mesh
        # device-tier compression + search scaling (DESIGN.md §2.6)
        self.device_index_kind = device_index_kind  # flat|clustered|auto
        self.cluster_crossover = cluster_crossover  # auto: IVF when n >= this
        self.nprobe = nprobe
        self.n_clusters = n_clusters
        self.db = AttentionDB(self.apm_shape, capacity=capacity,
                              codec=codec, rank=apm_rank)
        if index_kind == "ivf":
            self.index = IVFIndex(embed_dim, n_lists=n_lists or 8)
        elif index_kind == "device":
            self.index = DeviceIndex(embed_dim, interpret=interpret,
                                     mesh=mesh)
        else:
            self.index = ExactIndex(embed_dim)
        self.sim_cal: Tuple[float, float] = (-1.0, 1.0)
        # slot-aligned host staging of embeddings: the uniform source for
        # device-index deltas regardless of the host index kind
        self._embs_host = np.full((capacity, embed_dim), TOMBSTONE,
                                  np.float32)
        # lifecycle state
        self.generation = 0           # bumped on every host-tier mutation
        self.device_generation = -1   # generation the device tier reflects
        self._dirty: set = set()      # host slots changed since last sync
        self._synced_n = 0            # arena prefix length at last sync
        self._clock_hand = 0
        self.stats = StoreStats()
        # device tier (materialized by the first sync)
        self.device_db: Optional[DeviceDB] = None
        self.device_index: Optional[DeviceIndex] = None

    # ------------------------------------------------------------ accounting
    @property
    def codec(self):
        return self.db.codec

    @property
    def entry_nbytes(self) -> int:
        """Codec-true bytes per entry (compressed APM payload + the f32
        embedding row) — what the byte budget and the delta-vs-full
        receipts are denominated in."""
        return self.db.entry_nbytes + self.embed_dim * 4

    @property
    def logical_entry_nbytes(self) -> int:
        """What an uncompressed f16 entry would cost (receipt baseline)."""
        return self.db.logical_entry_nbytes + self.embed_dim * 4

    @property
    def live_count(self) -> int:
        return self.db.live_count

    @property
    def budget_entries(self) -> Optional[int]:
        if self.budget_bytes is None:
            return None
        return max(1, int(self.budget_bytes) // self.entry_nbytes)

    @property
    def device_stale(self) -> bool:
        return (self.device_db is None
                or self.device_generation != self.generation
                or len(self.db) > self._synced_n)

    def __len__(self):
        return len(self.db)

    # --------------------------------------------------------------- lookup
    def lookup(self, embs, k: int = 1):
        """Host-tier search: (L2 dists (B,k), slots (B,k)). Tombstoned
        (evicted) slots can never be returned against any live entry."""
        return self.index.search(np.asarray(embs, np.float32), k)

    def note_reuse(self, slots: Sequence[int]) -> None:
        """Record device-tier hits (drained once per batch) so the
        eviction clock sees the same reuse signal as host-tier ``get``."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size:
            np.add.at(self.db.reuse_counts, slots, 1)

    # --------------------------------------------------------------- admit
    def _ensure_emb_capacity(self, need: int) -> None:
        cap = self._embs_host.shape[0]
        if need <= cap:
            return
        new = np.full((max(need, 2 * cap), self.embed_dim), TOMBSTONE,
                      np.float32)
        new[:cap] = self._embs_host
        self._embs_host = new

    def admit(self, apms, embs) -> np.ndarray:
        """Online admission under the byte budget. apms: (B, H, L, L),
        embs: (B, embed_dim). Returns the assigned arena slots (recycled
        free slots first, then fresh appends). When the budget would be
        exceeded the CLOCK evicts cold entries first; if the batch alone
        exceeds the whole budget only its newest entries are kept."""
        apms = np.asarray(apms, self.db.dtype)
        embs = np.asarray(embs, np.float32)
        n_new = apms.shape[0]
        if n_new == 0:
            return np.zeros(0, np.int64)
        cap = self.budget_entries
        if cap is not None:
            if n_new > cap:
                apms, embs = apms[-cap:], embs[-cap:]
                n_new = cap
            over = self.live_count + n_new - cap
            if over > 0:
                self.evict(over)
        slots = self.db.put(apms)
        self._ensure_emb_capacity(int(slots.max()) + 1)
        self._embs_host[slots] = embs
        # when the host-tier index IS the device table, sync() lands the
        # rows (one delta, counted once); otherwise update the host index
        # now so lookups between admit and sync see the new entries
        if self.index is not self.device_index:
            self.index.assign(slots, embs)
        self._dirty.update(int(s) for s in slots)
        self.generation += 1
        self.stats.n_admitted += n_new
        return slots

    # --------------------------------------------------------------- evict
    def evict(self, n: int = 1) -> List[int]:
        """Reuse-aware CLOCK eviction: sweep the arena; entries with a
        nonzero reuse counter survive the pass with the counter halved
        (frequency-decaying second chance), zero-count entries are
        evicted. If everything is hot after two sweeps, the coldest live
        entries go. Evicted slots are released to the arena free-list and
        tombstoned in the index, so a hit on them is impossible."""
        db = self.db
        evicted: List[int] = []
        if n <= 0 or db._n == 0 or db.live_count == 0:
            return evicted
        n = min(n, db.live_count)
        counts = db.reuse_counts
        hand = self._clock_hand % db._n
        scanned, limit = 0, 2 * db._n
        while len(evicted) < n and scanned < limit:
            slot, hand = hand, (hand + 1) % db._n
            scanned += 1
            if not db._live[slot]:
                continue
            if counts[slot] > 0:
                counts[slot] //= 2
            else:
                evicted.append(slot)
        self._clock_hand = hand
        if len(evicted) < n:      # all hot: fall back to coldest-first
            live = np.flatnonzero(db.live_mask)
            live = live[~np.isin(live, evicted)]
            order = live[np.argsort(counts[live], kind="stable")]
            evicted.extend(int(s) for s in order[: n - len(evicted)])
        db.release(evicted)
        self.index.remove(evicted)
        self._ensure_emb_capacity(max(evicted) + 1)
        self._embs_host[evicted] = TOMBSTONE
        self._dirty.update(evicted)
        self.generation += 1
        self.stats.n_evicted += len(evicted)
        return evicted

    # ---------------------------------------------------------------- sync
    def _device_index_kind(self, n: int) -> str:
        """flat | clustered. ``auto`` flips to the IVF index once the
        entry count crosses ``cluster_crossover`` — below it, exhaustive
        search is one well-shaped matmul and the two-stage overhead
        (centroid matmul + candidate gather) doesn't pay (DESIGN.md
        §2.6); above it, search cost drops ~N/(nprobe·m)."""
        if self.device_index_kind == "auto":
            return ("clustered" if n >= self.cluster_crossover else "flat")
        return self.device_index_kind

    @staticmethod
    def _device_index_kind_of(index) -> Optional[str]:
        if index is None:
            return None
        return ("clustered" if isinstance(index, ClusteredDeviceIndex)
                else "flat")

    def _absorb_external_growth(self) -> None:
        """Backstop for out-of-band mutation (code that still calls
        ``db.add``/``index.add`` directly): any arena prefix growth since
        the last sync is treated as dirty, and its embeddings are mirrored
        into the slot-aligned host staging from the index."""
        lo, hi = self._synced_n, len(self.db)
        if hi <= lo:
            return
        ext = range(lo, hi)
        fresh = [s for s in ext if s not in self._dirty]
        if fresh:
            rows = getattr(self.index, "_embs", None)
            self._ensure_emb_capacity(hi)
            for s in fresh:
                if rows is not None and s < rows.shape[0]:
                    self._embs_host[s] = rows[s]
            self._dirty.update(fresh)
            self.generation += 1

    def sync(self, force_full: bool = False) -> Dict[str, object]:
        """Incremental device sync. Generation-counted: a clean store is a
        cheap host-side no-op; dirty slots that fit the device slack move
        as ONE scatter each for APMs and embeddings; only arena growth
        past the device allocation (or ``force_full``) re-materializes —
        with fresh slack sized by ``device_slack`` so subsequent
        admissions go back to deltas."""
        self._absorb_external_growth()
        n = len(self.db)
        if (self.device_db is not None and not force_full
                and not self._dirty):
            self.stats.n_noop_syncs += 1
            return {"kind": "noop", "bytes": 0}
        need_full = (force_full or self.device_db is None
                     or n > self.device_db.capacity
                     or self.device_index is None
                     or n > self.device_index.capacity
                     or self._device_index_kind(n)
                     != self._device_index_kind_of(self.device_index))
        if need_full:
            cap = n + max(8, int(n * self.device_slack))
            self.device_db = DeviceDB.from_host(self.db, capacity=cap)
            if self._device_index_kind(n) == "clustered":
                di = ClusteredDeviceIndex(
                    self.embed_dim, nprobe=self.nprobe,
                    n_clusters=self.n_clusters, interpret=self._interpret,
                    capacity=cap, mesh=self._mesh)
            else:
                di = DeviceIndex(self.embed_dim, interpret=self._interpret,
                                 capacity=cap, mesh=self._mesh)
            di.add(self._embs_host[:n])
            if isinstance(di, ClusteredDeviceIndex):
                # build eagerly: the k-means belongs on the sync (batch)
                # boundary, not inside the first serving dispatch, and
                # the full-sync receipt must include the shipped clusters
                di.rebuild()
            if isinstance(self.index, DeviceIndex):
                # the device table IS the host-tier index: swap in the
                # re-materialized one so both roles stay one object
                self.index = di
            self.device_index = di
            shipped = (self.device_db.transfer_bytes
                       + self.device_index.transfer_bytes)
            self.stats.n_full_syncs += 1
            self.stats.bytes_full += shipped
            kind = "full"
        else:
            slots = np.asarray(sorted(self._dirty), np.int64)
            slots = slots[slots < n]
            # ship the COMPRESSED rows: delta bytes shrink by the codec
            # ratio, same as the resident arenas
            shipped = self.device_db.update(slots, self.db.parts_at(slots))
            b0 = self.device_index.transfer_bytes
            # evicted slots go through remove(), not assign(): for the
            # clustered index an assign() would append the tombstone row
            # to the always-scored overflow buffer (and count toward the
            # rebuild trigger); remove() tombstones in place
            dead = slots[~self.db._live[slots]]
            live = slots[self.db._live[slots]]
            if live.size:
                self.device_index.assign(live, self._embs_host[live])
            if dead.size:
                self.device_index.remove(dead)
            shipped += self.device_index.transfer_bytes - b0
            self.stats.n_delta_syncs += 1
            self.stats.bytes_delta += shipped
            kind = "delta"
        self._dirty.clear()
        self._synced_n = n
        self.device_generation = self.generation
        return {"kind": kind, "bytes": shipped}

"""MemoStore — the lifecycle-managed two-tier memo subsystem (DESIGN.md §2.5).

AttMemo's database is built offline and frozen; under drifting serving
traffic the hit rate decays unless the store adapts online. MemoStore
owns ALL memoization state — the host tier (`AttentionDB` arena + a
slot-aligned host index) and the device tier (`DeviceDB` + `DeviceIndex`)
— behind one lifecycle API:

* ``lookup(embs, k)``   — host-tier search (the device tier is searched
                          inside the engine's fused jit via
                          ``device_index.search_device``).
* ``admit(apms, embs)`` — online admission under a byte budget: misses
                          captured during serving become entries; slots
                          are recycled from the arena free-list (no
                          compaction, so slot ids are stable and the
                          device tier can be patched in place).
* ``evict(n)``          — reuse-frequency/recency CLOCK over the arena's
                          ``reuse_counts``: hot entries get their counter
                          halved (a decaying second chance), cold entries
                          are released and their index rows tombstoned.
* ``sync()``            — generation-counted incremental device sync:
                          a no-op when nothing changed, a ``.at[slots]``
                          delta of exactly the dirty slots when the
                          preallocated device slack can hold them, and a
                          full re-materialization (with fresh slack) only
                          when the arena outgrew the device allocation.

The engine calls ``sync`` once per batch boundary; because deltas are
host→device pushes of staged numpy rows, the fast path's
zero-per-layer-host-sync invariant (tests/test_fastpath.py) is untouched.

Compression is first-class (DESIGN.md §2.6): the ``codec`` selects the
APM storage format for BOTH tiers (f16 | int8 | lowrank — see
``core/codec.py``), byte budgets and sync receipts are denominated in
codec-true bytes, and the device index flips from exhaustive to the
clustered (IVF) layout once the entry count crosses
``cluster_crossover`` (``device_index_kind="auto"``).

Pluggable pieces — the host/device index layouts and the eviction
policy — resolve through the string-keyed registries
(``repro.core.registry`` / DESIGN.md §2.8), and
``state_dict``/``load_state_dict`` round-trip the whole host tier for
``MemoSession.save``/``load`` warm starts.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.capacity import CapacityTier
from repro.core.database import AttentionDB, DeviceDB, pad_delta_pow2
from repro.core.faults import FaultInjector, MemoStoreError, fire
from repro.core.index import TOMBSTONE, ClusteredDeviceIndex, DeviceIndex
from repro.core.registry import DEVICE_INDEXES, EVICTIONS, HOST_INDEXES


class StoreSnapshot(NamedTuple):
    """An immutable view of the device tier, published atomically.

    The serving thread reads ``store.snapshot`` ONCE per batch and traces
    every fused layer against these arrays; the maintenance worker builds
    the next snapshot off-thread and swaps it in with a single reference
    assignment (atomic under the GIL). In-flight batches keep serving the
    arrays they captured — jnp updates are functional, so the previous
    generation stays valid until its last reader drops it — and no batch
    can ever observe half of a delta sync (DESIGN.md §2.7)."""
    generation: int
    db_parts: Tuple[jnp.ndarray, ...]     # DeviceDB codec parts
    index: object                         # the DeviceIndex that produced
    #                                       search_args (its search_device
    #                                       is pure given args — the pair
    #                                       must never be mixed across
    #                                       generations)
    search_args: object                   # device-index traced pytree
    index_key: str                        # jit-cache key component
    codec_key: object
    lengths: jnp.ndarray                  # (cap,) int32 entry lengths
    sim_a: float                          # dist→similarity calibration
    sim_b: float


@dataclass
class StoreStats:
    """Lifecycle + transfer accounting (the delta-vs-full receipts)."""
    n_admitted: int = 0
    n_evicted: int = 0
    n_noop_syncs: int = 0
    n_delta_syncs: int = 0
    n_full_syncs: int = 0
    bytes_delta: int = 0          # bytes moved by delta syncs
    bytes_full: int = 0           # bytes moved by full re-materializations
    n_quarantined: int = 0        # entries tombstoned on checksum mismatch
    n_evict_rejected: int = 0     # bogus policy slots the store refused
    # capacity tier (DESIGN.md §2.11)
    n_demoted: int = 0            # evictions that kept a disk copy (cooled)
    n_promoted: int = 0           # disk rows re-admitted into the host tier
    n_disk_quarantined: int = 0   # disk rows retired on checksum mismatch
    n_disk_errors: int = 0        # tier ops that failed (→ RAM-only)

    @property
    def bytes_total(self) -> int:
        return self.bytes_delta + self.bytes_full


class MemoStore:
    """Both memo tiers behind one lifecycle (lookup/admit/evict/sync)."""

    def __init__(self, apm_shape: Tuple[int, int, int], embed_dim: int, *,
                 index_kind: str = "exact", budget_bytes: Optional[int] = None,
                 capacity: int = 64, interpret: Optional[bool] = None,
                 device_slack: float = 1.0, n_lists: Optional[int] = None,
                 mesh=None, codec: str = "f16", apm_rank: Optional[int] = None,
                 device_index_kind: str = "auto",
                 cluster_crossover: int = 4096, nprobe: int = 16,
                 n_clusters: Optional[int] = None,
                 eviction: str = "clock",
                 faults: Optional[FaultInjector] = None,
                 capacity_dir: Optional[str] = None,
                 capacity_budget_mb: Optional[float] = None,
                 capacity_fsync: bool = True,
                 capacity_stall_s: float = 5.0):
        self.apm_shape = tuple(apm_shape)
        self.embed_dim = embed_dim
        self.index_kind = index_kind
        self.budget_bytes = budget_bytes
        self.device_slack = device_slack
        self._interpret = interpret
        self._mesh = mesh
        # device-tier compression + search scaling (DESIGN.md §2.6)
        self.device_index_kind = device_index_kind  # flat|clustered|auto
        self.cluster_crossover = cluster_crossover  # auto: IVF when n >= this
        self.nprobe = nprobe
        self.n_clusters = n_clusters
        self.db = AttentionDB(self.apm_shape, capacity=capacity,
                              codec=codec, rank=apm_rank)
        # pluggable pieces resolve through the string-keyed registries
        # (repro.memo API v1) — unknown keys fail HERE, listing choices
        self.eviction_kind = eviction
        self._evict_policy = EVICTIONS.resolve(eviction)
        if device_index_kind != "auto":
            DEVICE_INDEXES.resolve(device_index_kind)   # fail-fast only
        self.index = HOST_INDEXES.resolve(index_kind)(
            embed_dim, n_lists=n_lists, interpret=interpret, mesh=mesh)
        self.sim_cal: Tuple[float, float] = (-1.0, 1.0)
        # slot-aligned host staging of embeddings: the uniform source for
        # device-index deltas regardless of the host index kind
        self._embs_host = np.full((capacity, embed_dim), TOMBSTONE,
                                  np.float32)
        # per-entry valid sequence length (−1 = dead slot): variable-length
        # serving gates hits on length equality, so a padded query can only
        # reuse an APM captured at its own true length (DESIGN.md §2.7)
        self._lens_host = np.full((capacity,), -1, np.int32)
        self._dev_lens: Optional[jnp.ndarray] = None
        # one maintenance actor at a time (admit/evict/sync/recal run on
        # either the serving thread or the MemoServer worker, never both
        # concurrently — the lock makes misuse safe, not fast)
        self._lock = threading.RLock()
        self._snapshot: Optional[StoreSnapshot] = None
        # fault injection (DESIGN.md §2.9) — None in production, so every
        # fault site is one ``is None`` check
        self._faults = faults
        # lifecycle state
        self.generation = 0           # bumped on every host-tier mutation
        self.device_generation = -1   # generation the device tier reflects
        self._dirty: set = set()      # host slots changed since last sync
        self._synced_n = 0            # arena prefix length at last sync
        self._clock_hand = 0
        self.stats = StoreStats()
        # device tier (materialized by the first sync)
        self.device_db: Optional[DeviceDB] = None
        self.device_index: Optional[DeviceIndex] = None
        # capacity tier (DESIGN.md §2.11): the durable mmap-backed disk
        # tier. Eviction becomes demotion (host copy dropped, disk copy
        # cooled) and misses can promote disk → host → device. Any disk
        # error detaches the tier (``capacity_error`` set) — serving
        # continues RAM-only; ``reattach_capacity`` re-opens it.
        self._capacity_dir = capacity_dir
        self._capacity_budget_mb = capacity_budget_mb
        self._capacity_fsync = capacity_fsync
        self._capacity_stall_s = float(capacity_stall_s)
        self.capacity: Optional[CapacityTier] = None
        self.capacity_error: Optional[str] = None
        self._host_to_disk: Dict[int, int] = {}
        self._disk_to_host: Dict[int, int] = {}
        if capacity_dir is not None:
            try:
                self._open_capacity_locked()
            except Exception as e:       # noqa: BLE001 — degrade, don't die
                self._capacity_fail(e)

    # ------------------------------------------------------------ accounting
    @property
    def codec(self):
        return self.db.codec

    @property
    def entry_nbytes(self) -> int:
        """Codec-true bytes per entry (compressed APM payload + the f32
        embedding row) — what the byte budget and the delta-vs-full
        receipts are denominated in."""
        return self.db.entry_nbytes + self.embed_dim * 4

    @property
    def logical_entry_nbytes(self) -> int:
        """What an uncompressed f16 entry would cost (receipt baseline)."""
        return self.db.logical_entry_nbytes + self.embed_dim * 4

    @property
    def live_count(self) -> int:
        return self.db.live_count

    @property
    def budget_entries(self) -> Optional[int]:
        if self.budget_bytes is None:
            return None
        return max(1, int(self.budget_bytes) // self.entry_nbytes)

    @property
    def device_stale(self) -> bool:
        return (self.device_db is None
                or self.device_generation != self.generation
                or len(self.db) > self._synced_n)

    def __len__(self):
        return len(self.db)

    # --------------------------------------------------------------- lookup
    def lookup(self, embs, k: int = 1):
        """Host-tier search: (L2 dists (B,k), slots (B,k)). Tombstoned
        (evicted) slots can never be returned against any live entry."""
        return self.index.search(np.asarray(embs, np.float32), k)

    def note_reuse(self, slots: Sequence[int]) -> None:
        """Record device-tier hits (drained once per batch) so the
        eviction clock sees the same reuse signal as host-tier ``get``."""
        slots = np.asarray(slots).reshape(-1)
        if slots.size:
            with self._lock:
                np.add.at(self.db.reuse_counts, slots, 1)

    @property
    def default_len(self) -> int:
        """Entry length when admission doesn't say otherwise — the arena
        sequence length (fixed-length calibration corpora)."""
        return int(self.apm_shape[-1])

    def entry_lengths(self, slots) -> np.ndarray:
        """Valid sequence length per slot (−1 for dead slots) — the host
        leg of the length gate; the device leg rides in the snapshot."""
        slots = np.asarray(slots).reshape(-1)
        return self._lens_host[slots]

    def embeddings_at(self, slots) -> np.ndarray:
        """Stored embedding rows per slot (TOMBSTONE rows for dead
        slots) — the public read of the slot-aligned staging mirror."""
        slots = np.asarray(slots).reshape(-1)
        return self._embs_host[slots].copy()

    # ------------------------------------------------------- capacity tier
    @property
    def capacity_ok(self) -> bool:
        """True while the disk tier is attached and error-free."""
        return self.capacity is not None and self.capacity_error is None

    def _open_capacity_locked(self) -> None:
        budget = (None if self._capacity_budget_mb is None
                  else int(float(self._capacity_budget_mb) * 1e6))
        self.capacity = CapacityTier(
            self._capacity_dir, codec=self.db.codec,
            embed_dim=self.embed_dim, capacity=self.db.capacity,
            budget_bytes=budget, faults=self._faults,
            fsync=self._capacity_fsync)
        self.capacity.on_retire = self._on_disk_retire
        self.capacity.on_compact = self._on_disk_compact
        # a recovered manifest carries the calibration it was
        # checkpointed under — adopt it so a dir-load serves with the
        # sim map the entries were admitted against
        cal = (self.capacity.extra_meta or {}).get("sim_cal")
        if cal is not None and len(cal) == 2:
            self.sim_cal = (float(cal[0]), float(cal[1]))

    def _capacity_fail(self, e: BaseException) -> None:
        """Disk fault: flag the tier offline (RAM-only serving) — never
        raise into admission/eviction/serving (DESIGN.md §2.11)."""
        self.capacity_error = f"{type(e).__name__}: {e}"
        self.stats.n_disk_errors += 1

    def _on_disk_retire(self, slots) -> None:
        """Tier callback: disk rows retired (budget/quarantine) — drop
        any host↔disk mapping so a recycled disk slot can't alias."""
        for d in np.asarray(slots).reshape(-1):
            h = self._disk_to_host.pop(int(d), None)
            if h is not None:
                self._host_to_disk.pop(h, None)

    def _on_disk_compact(self, old_slots, new_slots) -> None:
        """Tier callback: compaction renumbered every live disk slot —
        rewrite the host↔disk maps so mirrored entries stay linked (a
        stale map would alias the write-through dedup)."""
        remap = {int(o): int(w) for o, w in zip(
            np.asarray(old_slots).reshape(-1),
            np.asarray(new_slots).reshape(-1))}
        h2d, d2h = {}, {}
        for h, d in self._host_to_disk.items():
            w = remap.get(int(d))
            if w is not None:
                h2d[h] = w
                d2h[w] = h
        self._host_to_disk, self._disk_to_host = h2d, d2h

    def _capacity_op(self, fn, *args, **kwargs):
        """Run one tier op with the stall watchdog: an op slower than
        ``capacity_stall_s`` (an injected ``stall_s`` rider, a hung
        disk) fails the tier just like an IO error — promotion stalls
        degrade to RAM-only serving, never block it indefinitely."""
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        dt = time.perf_counter() - t0
        if dt > self._capacity_stall_s:
            raise TimeoutError(
                f"capacity tier op {getattr(fn, '__name__', fn)!r} took "
                f"{dt:.3f}s (stall threshold {self._capacity_stall_s}s)")
        return out

    def _mirror_to_capacity_locked(self, slots) -> None:
        """Write-through: durably append the given host slots' encoded
        rows (+ their recorded checksums) to the disk tier. Already
        mirrored slots are skipped — demotion is then free (drop the
        host copy; the disk copy is the cooled entry)."""
        fresh = [int(s) for s in np.asarray(slots).reshape(-1)
                 if int(s) not in self._host_to_disk]
        if not fresh:
            return
        arr = np.asarray(fresh, np.int64)
        parts = self.db.parts_at(arr)
        csums = [c[arr] for c in self.db.checksums]
        dslots = self._capacity_op(
            self.capacity.append, parts, self._embs_host[arr],
            self._lens_host[arr], csums)
        for h, d in zip(fresh, dslots):
            self._host_to_disk[h] = int(d)
            self._disk_to_host[int(d)] = h

    def promote_for(self, embs, lengths=None, *, threshold: float,
                    max_promote: int = 64) -> np.ndarray:
        """Asynchronous promotion disk → host → device: search the disk
        tier for the given miss embeddings; rows whose calibrated
        predicted similarity clears ``threshold`` (and whose stored
        length matches) are re-admitted into the host arena
        *bit-identically* (``put_parts``) after a per-row CRC re-check
        — corrupt disk rows are quarantined through the retire path.
        Promoted slots are dirty; the next generation-counted delta
        sync ships them to the device tier (the publish protocol is
        unchanged). Returns a (B,) bool mask of queries satisfied by a
        disk-resident entry (already-resident matches count — their
        capture need not be re-admitted)."""
        embs = np.asarray(embs, np.float32)
        B = embs.shape[0]
        satisfied = np.zeros(B, bool)
        if B == 0 or not self.capacity_ok:
            return satisfied
        with self._lock:
            tier = self.capacity
            lens = (np.full(B, self.default_len, np.int32)
                    if lengths is None
                    else np.asarray(lengths, np.int32).reshape(-1))
            try:
                d2, dslots = self._capacity_op(tier.search, embs, 1)
            except Exception as e:      # noqa: BLE001 — degrade
                self._capacity_fail(e)
                return satisfied
            a, b = self.sim_cal
            sim = a * np.sqrt(np.maximum(d2[:, 0], 0.0)) + b
            chosen = np.full(B, -1, np.int64)   # query → disk slot
            picks: List[int] = []               # unique disk slots to pull
            for i in range(B):
                d = int(dslots[i, 0])
                if d < 0 or sim[i] < float(threshold) \
                        or int(tier._lens[d]) != int(lens[i]):
                    continue
                h = self._disk_to_host.get(d)
                if h is not None and self.db._live[h]:
                    satisfied[i] = True         # already resident
                    continue
                if d in picks or len(picks) < int(max_promote):
                    satisfied[i] = True
                    chosen[i] = d
                    if d not in picks:
                        picks.append(d)
            if not picks:
                return satisfied
            dlist = np.asarray(picks, np.int64)
            try:
                parts, dembs, dlens, dcsums = self._capacity_op(
                    tier.rows_at, dlist)
            except Exception as e:      # noqa: BLE001 — degrade
                self._capacity_fail(e)
                return np.zeros(B, bool)
            good = np.ones(dlist.size, bool)
            for p, c in zip(parts, dcsums):
                good &= AttentionDB._crc_rows(p) == c
            if not good.all():
                bad = dlist[~good]
                try:
                    tier.retire(bad)
                except Exception as e:  # noqa: BLE001
                    self._capacity_fail(e)
                self.stats.n_disk_quarantined += int(bad.size)
                satisfied[np.isin(chosen, bad)] = False
                dlist = dlist[good]
                parts = tuple(p[good] for p in parts)
                dembs, dlens = dembs[good], dlens[good]
                dcsums = tuple(c[good] for c in dcsums)
            if dlist.size == 0:
                return satisfied
            cap = self.budget_entries
            if cap is not None:
                over = self.live_count + int(dlist.size) - cap
                if over > 0:
                    self.evict(over)
            slots = self.db.put_parts(parts, dcsums)
            self._ensure_emb_capacity(int(slots.max()) + 1)
            self._embs_host[slots] = dembs
            self._lens_host[slots] = dlens
            if self.index is not self.device_index:
                self.index.assign(slots, dembs)
            self._dirty.update(int(s) for s in slots)
            self.generation += 1
            self.stats.n_promoted += int(slots.size)
            tier.note_reuse(dlist)
            for h, d in zip(slots, dlist):
                self._host_to_disk[int(h)] = int(d)
                self._disk_to_host[int(d)] = int(h)
        return satisfied

    def checkpoint(self) -> bool:
        """Flush the disk tier's WAL into a fresh shadow manifest (the
        supervised worker calls this every ``checkpoint_every`` applied
        payloads). Failures detach the tier, never raise."""
        with self._lock:
            if not self.capacity_ok:
                return False
            try:
                self._capacity_op(
                    self.capacity.checkpoint,
                    {"sim_cal": [float(self.sim_cal[0]),
                                 float(self.sim_cal[1])]})
                return True
            except Exception as e:      # noqa: BLE001 — degrade
                self._capacity_fail(e)
                return False

    def compact_capacity(self, min_retired: float = 0.0) -> Optional[dict]:
        """Re-compact the disk tier when at least ``min_retired`` of its
        allocated slots are retired holes (the maintenance worker calls
        this on the ``CapacitySpec.compact_ratio`` trigger). Returns the
        tier's compaction report, or ``None`` when below the threshold /
        tier detached. Failures detach the tier, never raise — the
        crash-consistency contract is the tier's (epoch publish)."""
        with self._lock:
            if not self.capacity_ok:
                return None
            tier = self.capacity
            if tier.retired_fraction < float(min_retired):
                return None
            try:
                # deliberately not under the stall watchdog: rewriting
                # every live row is legitimately proportional to the
                # arena, not a hung-disk signal
                return tier.compact()
            except Exception as e:      # noqa: BLE001 — degrade
                self._capacity_fail(e)
                return None

    def reattach_capacity(self) -> bool:
        """Re-open the capacity tier after a disk fault (the
        ``MemoServer.recover`` path): recover the directory, clear the
        error, rebuild the host↔disk mapping by checksum (so entries
        already on disk are not duplicated) and write-through anything
        the disk missed during the outage."""
        with self._lock:
            if self._capacity_dir is None:
                return False
            old, self.capacity = self.capacity, None
            if old is not None:
                try:
                    old.close()
                except Exception:       # noqa: BLE001 — already failed
                    pass
            self.capacity_error = None
            self._host_to_disk.clear()
            self._disk_to_host.clear()
            try:
                self._open_capacity_locked()
                self._remirror_locked()
                return True
            except Exception as e:      # noqa: BLE001 — stay detached
                self._capacity_fail(e)
                return False

    def _remirror_locked(self) -> None:
        """Reconcile host tier → disk tier: map host entries to disk
        rows whose primary-part checksum matches (no duplicate
        appends), then write through the rest."""
        tier = self.capacity
        by_csum: Dict[int, int] = {}
        for d in tier.live_slots:
            by_csum.setdefault(int(tier._csums[0][d]), int(d))
        unmapped: List[int] = []
        for h in np.flatnonzero(self.db.live_mask):
            h = int(h)
            if h in self._host_to_disk:
                continue
            d = by_csum.get(int(self.db.checksums[0][h]))
            if d is not None and d not in self._disk_to_host:
                self._host_to_disk[h] = d
                self._disk_to_host[d] = h
            else:
                unmapped.append(h)
        if unmapped:
            self._mirror_to_capacity_locked(unmapped)

    def demote_to_budget(self) -> List[int]:
        """Cool the host tier down to its byte budget (capacity-leg
        benchmarks; a plain evict when no disk tier is attached — with
        one, every evicted entry keeps its durable disk copy)."""
        cap = self.budget_entries
        if cap is None:
            return []
        over = self.live_count - cap
        return self.evict(over) if over > 0 else []

    # --------------------------------------------------------------- admit
    def _ensure_emb_capacity(self, need: int) -> None:
        cap = self._embs_host.shape[0]
        if need <= cap:
            return
        new = np.full((max(need, 2 * cap), self.embed_dim), TOMBSTONE,
                      np.float32)
        new[:cap] = self._embs_host
        self._embs_host = new
        lens = np.full((new.shape[0],), -1, np.int32)
        lens[:cap] = self._lens_host
        self._lens_host = lens

    def admit(self, apms, embs, lengths=None, kv=None) -> np.ndarray:
        """Online admission under the byte budget. apms: (B, H, L, L),
        embs: (B, embed_dim), lengths: optional (B,) true sequence lengths
        (defaults to the arena length — fixed-length corpora), kv: the
        codec's side-channel payload (the (B, 2, S, D) KV planes under a
        prefill codec — DESIGN.md §2.13; plain APM codecs ignore it).
        Returns the assigned arena slots (recycled free slots first, then
        fresh appends). When the budget would be exceeded the CLOCK evicts
        cold entries first; if the batch alone exceeds the whole budget
        only its newest entries are kept."""
        with self._lock:
            return self._admit_locked(apms, embs, lengths, kv)

    def _admit_locked(self, apms, embs, lengths, kv=None) -> np.ndarray:
        apms = np.asarray(apms, self.db.dtype)
        embs = np.asarray(embs, np.float32)
        if kv is not None:
            kv = np.asarray(kv)
        lengths = (np.full(apms.shape[0], self.default_len, np.int32)
                   if lengths is None
                   else np.asarray(lengths, np.int32).reshape(-1))
        n_new = apms.shape[0]
        if n_new == 0:
            return np.zeros(0, np.int64)
        cap = self.budget_entries
        if cap is not None:
            if n_new > cap:
                apms, embs = apms[-cap:], embs[-cap:]
                lengths = lengths[-cap:]
                if kv is not None:
                    kv = kv[-cap:]
                n_new = cap
            over = self.live_count + n_new - cap
            if over > 0:
                self.evict(over)
        slots = self.db.put(apms, aux=kv)
        self._ensure_emb_capacity(int(slots.max()) + 1)
        self._embs_host[slots] = embs
        self._lens_host[slots] = lengths
        # when the host-tier index IS the device table, sync() lands the
        # rows (one delta, counted once); otherwise update the host index
        # now so lookups between admit and sync see the new entries
        if self.index is not self.device_index:
            self.index.assign(slots, embs)
        self._dirty.update(int(s) for s in slots)
        self.generation += 1
        self.stats.n_admitted += n_new
        # write-through (DESIGN.md §2.11): every admission is durably
        # journaled + appended to the disk tier NOW, so demotion later
        # is free (drop the host copy, keep the cooled disk copy). Runs
        # before the corrupt_row fault site: the disk keeps the bytes
        # as encoded, exactly like the recorded checksums do.
        if self.capacity_ok:
            try:
                self._mirror_to_capacity_locked(slots)
            except Exception as e:      # noqa: BLE001 — degrade
                self._capacity_fail(e)
        if fire(self._faults, "store.corrupt_row") is not None:
            # bit-flip the newest row's primary arena part WITHOUT
            # refreshing its checksum — the sync-boundary verification
            # must catch and quarantine it before it ships to the device
            row = self.db._arenas[0][int(slots[-1])]
            row.view(np.uint8)[...] ^= 0xFF
        return slots

    # --------------------------------------------------------------- evict
    def evict(self, n: int = 1) -> List[int]:
        """Evict ``n`` entries. *Selection* is the registered eviction
        policy (``eviction="clock"`` by default — see ``clock_eviction``;
        extensions via ``repro.memo.register_eviction``); the store does
        the shared bookkeeping: evicted slots are released to the arena
        free-list and tombstoned in the index, so a hit on them is
        impossible."""
        db = self.db
        if n <= 0 or db._n == 0 or db.live_count == 0:
            return []
        with self._lock:
            n = min(n, db.live_count)
            evicted = [int(s) for s in self._evict_policy(self, n)]
            if fire(self._faults, "store.evict_bogus") is not None:
                # bookkeeping fault: the policy hands back garbage —
                # a duplicate, an out-of-range id and a dead slot; the
                # validation below must refuse all three
                dead = np.flatnonzero(~db.live_mask)
                evicted += ([evicted[0]] if evicted else []) \
                    + [db._n + 7] \
                    + ([int(dead[0])] if dead.size else [])
            # registered policies are user code: validate their output
            # (live, in-range, unique) so a buggy policy costs entries
            # it names, never store invariants
            seen: set = set()
            valid = []
            for s in evicted:
                if 0 <= s < db._n and db._live[s] and s not in seen:
                    valid.append(s)
                    seen.add(s)
                else:
                    self.stats.n_evict_rejected += 1
            evicted = valid
            if not evicted:
                return evicted
            self._retire_slots_locked(evicted)
            self.stats.n_evicted += len(evicted)
        return evicted

    def _retire_slots_locked(self, slots: List[int],
                             demote: bool = True) -> None:
        """Shared eviction/quarantine bookkeeping: release the arena
        slots and tombstone every index row, so a hit on them is
        impossible (the PR 2 tombstone invariant). With a healthy
        capacity tier and ``demote=True`` (eviction), the entries are
        COOLED, not lost: any not yet mirrored are written through
        first, then only the host copy is dropped — the disk row stays
        live and promotable. Quarantine passes ``demote=False`` (its
        host bytes are corrupt; the disk copy, written at admission
        before the corruption, survives if it exists)."""
        db = self.db
        if demote and self.capacity_ok:
            try:
                self._mirror_to_capacity_locked(slots)
                self.stats.n_demoted += len(slots)
            except Exception as e:      # noqa: BLE001 — plain eviction
                self._capacity_fail(e)
        for h in slots:                 # host slots recycle; unlink maps
            d = self._host_to_disk.pop(int(h), None)
            if d is not None:
                self._disk_to_host.pop(d, None)
        db.release(slots)
        self.index.remove(slots)
        self._ensure_emb_capacity(max(slots) + 1)
        self._embs_host[slots] = TOMBSTONE
        self._lens_host[slots] = -1
        self._dirty.update(slots)
        self.generation += 1

    # ------------------------------------------------------------ integrity
    def _quarantine_locked(self, bad: np.ndarray) -> List[int]:
        bad = [int(s) for s in np.asarray(bad).reshape(-1)]
        if bad:
            self._retire_slots_locked(bad, demote=False)
            self.stats.n_quarantined += len(bad)
        return bad

    def verify_integrity(self, quarantine: bool = True) -> List[int]:
        """Recompute every live entry's per-codec-part checksums against
        the arenas. Mismatched entries are quarantined (released +
        tombstoned — they can never hit again) when ``quarantine`` is
        set; returns the bad slot ids either way. The full-arena sweep
        is the recovery path (``MemoServer.recover``); routine syncs
        verify just the delta (see ``_sync_locked``). With a capacity
        tier attached the sweep extends to every live DISK row — torn
        or bit-flipped rows are retired there the same way (counted in
        ``stats.n_disk_quarantined``); the returned list stays
        host-tier slot ids."""
        with self._lock:
            if self.capacity_ok:
                try:
                    dbad = self.capacity.verify()
                    if dbad.size and quarantine:
                        self.capacity.retire(dbad)
                        self.stats.n_disk_quarantined += int(dbad.size)
                except Exception as e:  # noqa: BLE001 — degrade
                    self._capacity_fail(e)
            bad = self.db.verify()
            if quarantine:
                return self._quarantine_locked(bad)
            return [int(s) for s in bad]

    # ---------------------------------------------------------------- sync
    def _device_index_kind(self, n: int) -> str:
        """flat | clustered. ``auto`` flips to the IVF index once the
        entry count crosses ``cluster_crossover`` — below it, exhaustive
        search is one well-shaped matmul and the two-stage overhead
        (centroid matmul + candidate gather) doesn't pay (DESIGN.md
        §2.6); above it, search cost drops ~N/(nprobe·m)."""
        if self.device_index_kind == "auto":
            return ("clustered" if n >= self.cluster_crossover else "flat")
        return self.device_index_kind

    @staticmethod
    def _device_index_kind_of(index) -> Optional[str]:
        if index is None:
            return None
        kind = getattr(index, "_registry_kind", None)
        if kind is not None:
            return kind
        return ("clustered" if isinstance(index, ClusteredDeviceIndex)
                else "flat")

    def _absorb_external_growth(self) -> None:
        """Backstop for out-of-band mutation (code that still calls
        ``db.add``/``index.add`` directly): any arena prefix growth since
        the last sync is treated as dirty, and its embeddings are mirrored
        into the slot-aligned host staging from the index."""
        lo, hi = self._synced_n, len(self.db)
        if hi <= lo:
            return
        ext = range(lo, hi)
        fresh = [s for s in ext if s not in self._dirty]
        if fresh:
            rows = getattr(self.index, "_embs", None)
            self._ensure_emb_capacity(hi)
            for s in fresh:
                if rows is not None and s < rows.shape[0]:
                    self._embs_host[s] = rows[s]
                self._lens_host[s] = self.default_len
            self._dirty.update(fresh)
            self.generation += 1

    def sync(self, force_full: bool = False) -> Dict[str, object]:
        """Incremental device sync. Generation-counted: a clean store is a
        cheap host-side no-op; dirty slots that fit the device slack move
        as ONE scatter each for APMs, embeddings and entry lengths; only
        arena growth past the device allocation (or ``force_full``)
        re-materializes — with fresh slack sized by ``device_slack`` so
        subsequent admissions go back to deltas. Finishes by publishing a
        fresh ``StoreSnapshot`` (the only view serving threads read)."""
        with self._lock:
            return self._sync_locked(force_full)

    def _need_full_sync_locked(self, n: int, force_full: bool) -> bool:
        """Full-vs-delta decision — overridable (the sharded store adds
        its own position-capacity criteria). Base: re-materialize when
        forced, when the device tier doesn't exist yet, when the arena
        outgrew the device allocation, or when the auto index kind
        flipped across ``cluster_crossover``."""
        return (force_full or self.device_db is None
                or n > self.device_db.capacity
                or self.device_index is None
                or n > self.device_index.capacity
                or self._device_index_kind(n)
                != self._device_index_kind_of(self.device_index))

    def _full_sync_device_locked(self, n: int) -> int:
        """Re-materialize the whole device tier (DB + index + lengths)
        with fresh slack; returns bytes shipped. Overridable — the
        sharded store replaces the layout wholesale."""
        cap = n + max(8, int(n * self.device_slack))
        self.device_db = DeviceDB.from_host(self.db, capacity=cap)
        kind = self._device_index_kind(n)
        di = DEVICE_INDEXES.resolve(kind)(
            self.embed_dim, capacity=cap, nprobe=self.nprobe,
            n_clusters=self.n_clusters, interpret=self._interpret,
            mesh=self._mesh)
        di._registry_kind = kind
        di.add(self._embs_host[:n])
        if isinstance(di, ClusteredDeviceIndex):
            # build eagerly: the k-means belongs on the sync (batch)
            # boundary, not inside the first serving dispatch, and
            # the full-sync receipt must include the shipped clusters
            di.rebuild()
        if isinstance(self.index, DeviceIndex):
            # the device table IS the host-tier index: swap in the
            # re-materialized one so both roles stay one object
            self.index = di
        self.device_index = di
        lens = np.full((cap,), -1, np.int32)
        lens[:n] = self._lens_host[:n]
        self._dev_lens = jnp.asarray(lens)
        return (self.device_db.transfer_bytes
                + self.device_index.transfer_bytes + int(lens.nbytes))

    def _delta_sync_device_locked(self, n: int,
                                  slots: np.ndarray) -> int:
        """Ship exactly the dirty ``slots`` (< n, sorted) as scatter
        deltas; returns bytes shipped. Overridable — the sharded store
        routes each slot to a shard-owned position instead."""
        # ship the COMPRESSED rows: delta bytes shrink by the codec
        # ratio, same as the resident arenas
        shipped = self.device_db.update(slots, self.db.parts_at(slots))
        b0 = self.device_index.transfer_bytes
        # evicted slots go through remove(), not assign(): for the
        # clustered index an assign() would append the tombstone row
        # to the always-scored overflow buffer (and count toward the
        # rebuild trigger); remove() tombstones in place
        dead = slots[~self.db._live[slots]]
        live = slots[self.db._live[slots]]
        if live.size:
            self.device_index.assign(live, self._embs_host[live])
        if dead.size:
            self.device_index.remove(dead)
        shipped += self.device_index.transfer_bytes - b0
        if self._dev_lens is None:      # device tier predates lengths
            lens = np.full((self.device_db.capacity,), -1, np.int32)
            lens[:n] = self._lens_host[:n]
            self._dev_lens = jnp.asarray(lens)
            shipped += int(lens.nbytes)
        if slots.size:
            sl, vals = pad_delta_pow2(slots, self._lens_host[slots])
            self._dev_lens = self._dev_lens.at[jnp.asarray(sl)].set(
                jnp.asarray(vals))
            shipped += int(vals.nbytes + sl.size * 4)
        return shipped

    def _sync_locked(self, force_full: bool) -> Dict[str, object]:
        if fire(self._faults, "store.sync_fail") is not None:
            # injected BEFORE any mutation: a retried sync starts clean
            raise MemoStoreError(
                f"injected delta-sync failure (store generation "
                f"{self.generation})")
        self._absorb_external_growth()
        n = len(self.db)
        if (self.device_db is not None and not force_full
                and not self._dirty):
            self.stats.n_noop_syncs += 1
            if self._snapshot is None:
                self.publish()
            return {"kind": "noop", "bytes": 0}
        need_full = self._need_full_sync_locked(n, force_full)
        # integrity gate on what is about to ship (DESIGN.md §2.9): a
        # full sync re-verifies every live entry, a delta verifies the
        # dirty rows in flight; mismatches are quarantined (tombstoned)
        # BEFORE publication, so a corrupt entry can never hit
        check = (None if need_full
                 else np.asarray(sorted(self._dirty), np.int64))
        bad = self.db.verify(check)
        if bad.size:
            self._quarantine_locked(bad)
        if need_full:
            shipped = self._full_sync_device_locked(n)
            self.stats.n_full_syncs += 1
            self.stats.bytes_full += shipped
            kind = "full"
        else:
            slots = np.asarray(sorted(self._dirty), np.int64)
            slots = slots[slots < n]
            shipped = self._delta_sync_device_locked(n, slots)
            self.stats.n_delta_syncs += 1
            self.stats.bytes_delta += shipped
            kind = "delta"
        self._dirty.clear()
        self._synced_n = n
        self.device_generation = self.generation
        self.publish()
        return {"kind": kind, "bytes": shipped}

    # ------------------------------------------------------------- publish
    @property
    def snapshot(self) -> Optional[StoreSnapshot]:
        """The last published device-tier view (None until first sync)."""
        return self._snapshot

    def publish(self) -> StoreSnapshot:
        """Build and atomically install a fresh ``StoreSnapshot``. Called
        at the end of every sync and after online recalibration — the
        single reference assignment is the generation-publish protocol's
        commit point: readers see the previous snapshot or this one,
        never a mix (DESIGN.md §2.7). Taken under the store lock so the
        component reads (parts / search_args / lengths / sim_cal) come
        from ONE generation even if two maintenance actors misuse the
        single-actor contract."""
        with self._lock:
            return self._publish_locked()

    def _publish_locked(self) -> StoreSnapshot:
        di = self.device_index
        snap = StoreSnapshot(
            generation=self.generation,
            db_parts=self.device_db.parts,
            index=di,
            # reading ``search_args`` here freezes the index's cached
            # per-row squared norms into the snapshot: the O(N·dim)
            # reduction runs once per mutation generation at publish,
            # and every fused-path search (and the nn_search kernel's
            # norm sliver) reuses it until the next sync republishes.
            search_args=di.search_args,
            index_key=type(di).__name__,
            codec_key=self.codec.key,
            lengths=self._dev_lens,
            sim_a=float(self.sim_cal[0]),
            sim_b=float(self.sim_cal[1]))
        self._snapshot = snap
        return snap

    # --------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Every host-tier array needed to reconstruct this store exactly
        (``MemoSession.save``): the codec-part arenas, the slot-aligned
        embedding/length mirrors, liveness + reuse counters, the
        free-list (ORDER matters — ``put`` recycles LIFO), the eviction
        clock hand and ``sim_cal``. The device tier is derived state and
        is re-materialized by the first ``sync()`` after load."""
        with self._lock:
            n = len(self.db)
            out = {
                "n": np.asarray(n, np.int64),
                "free": np.asarray(self.db._free, np.int64),
                "live": self.db._live[:n].copy(),
                "reuse": self.db.reuse_counts[:n].copy(),
                "embs": self._embs_host[:n].copy(),
                "lens": self._lens_host[:n].copy(),
                "clock_hand": np.asarray(self._clock_hand, np.int64),
                "sim_cal": np.asarray(self.sim_cal, np.float64),
            }
            for spec, arena, csum in zip(self.codec.parts, self.db._arenas,
                                         self.db.checksums):
                out[f"part_{spec.name}"] = arena[:n].copy()
                out[f"csum_{spec.name}"] = csum[:n].copy()
            # the host index's staging array, at its FULL grown shape:
            # approximate indexes (ivf) k-means over the whole array
            # including TOMBSTONE slack rows, so reproducing searches
            # bit-identically requires the exact array, not the prefix
            embs = getattr(self.index, "_embs", None)
            if embs is not None:
                out["index_embs"] = np.asarray(embs).copy()
            return out

    def load_state_dict(self, state: Dict[str, np.ndarray],
                        adopt_arenas: bool = False) -> None:
        """Restore ``state_dict`` output into this (freshly constructed,
        identically configured) store. The host index is rebuilt from the
        slot mirrors — assign() for live rows, remove() for dead ones —
        which reproduces the saved index state exactly (tombstones and
        all), so host-tier lookups are bit-identical across a
        save/load round trip. The device tier stays unmaterialized; the
        next ``sync()`` performs the full (deterministic) upload.

        ``adopt_arenas=True`` (the ``MemoSession.load(..., mmap=True)``
        path) installs the given part arrays AS the arenas instead of
        copying rows in — with format-3 copy-on-write memmaps the
        arena bytes stay on disk until first written (zero-copy open;
        the untouched preallocated zeros are never faulted in)."""
        with self._lock:
            n = int(np.asarray(state["n"]).reshape(-1)[0])
            db = self.db
            db._grow_to(n)
            parts_state = [state.get(f"part_{spec.name}")
                           for spec in self.codec.parts]
            adopted = (adopt_arenas and n > 0 and db.capacity == n
                       and all(p is not None
                               and p.shape == a.shape and p.dtype == a.dtype
                               for p, a in zip(parts_state, db._arenas)))
            if adopted:
                db._arenas = [p if isinstance(p, np.memmap)
                              else np.ascontiguousarray(p)
                              for p in parts_state]
            for spec, arena, csum in zip(self.codec.parts, db._arenas,
                                         db.checksums):
                if not adopted:
                    arena[:n] = state[f"part_{spec.name}"]
                saved = state.get(f"csum_{spec.name}")
                if saved is not None:
                    csum[:n] = saved
                else:                       # pre-integrity save: rebaseline
                    csum[:n] = db._crc_rows(arena[:n])
            db._n = n
            db._live[:n] = state["live"]
            db.reuse_counts[:n] = state["reuse"]
            db._free = [int(s) for s in state["free"]]
            self._ensure_emb_capacity(n)
            self._embs_host[:n] = state["embs"]
            self._lens_host[:n] = state["lens"]
            self._clock_hand = int(
                np.asarray(state["clock_hand"]).reshape(-1)[0])
            self.sim_cal = tuple(
                float(v) for v in np.asarray(state["sim_cal"]).reshape(-1))
            # restore the host index from the saved staging array at its
            # EXACT shape — approximate indexes (ivf) k-means over the
            # whole array including slack rows, and assign()'s minimum
            # growth would change it. Fall back to a slot-aligned assign
            # of the mirror for index kinds without a settable staging
            # array (DeviceIndex: exhaustive, slack rows are TOMBSTONE
            # and cannot win a search)
            embs = state.get("index_embs")
            if embs is not None and len(embs):
                try:
                    self.index._embs = np.asarray(embs, np.float32).copy()
                    if hasattr(self.index, "_built"):
                        self.index._built = False
                except AttributeError:     # computed staging view
                    self.index.assign(np.arange(len(embs)), embs)
            elif n:
                self.index.assign(np.arange(n), self._embs_host[:n])
            # clean host tier, unmaterialized device tier: the next sync
            # takes the full-materialization branch (device_db is None)
            # without re-dirtying the loaded slots
            self._dirty.clear()
            self._synced_n = n
            self.generation = 0
            self.device_generation = -1
            self.device_db = None
            self.device_index = None
            self._dev_lens = None
            self._snapshot = None
            # a capacity dir attached to a file-load: reconcile the two
            # (checksum-matched mapping, write-through for the rest) so
            # the disk tier mirrors the loaded host tier from the start
            if self.capacity_ok:
                try:
                    self._remirror_locked()
                except Exception as e:  # noqa: BLE001 — degrade
                    self._capacity_fail(e)

    def adopt_capacity(self, max_entries: Optional[int] = None) -> int:
        """Populate an EMPTY host tier from the recovered disk tier (the
        ``MemoSession.load(<capacity dir>)`` warm start): hottest disk
        rows first (reuse-ordered), up to ``max_entries`` / the byte
        budget, admitted bit-identically via ``put_parts``. Returns the
        number of promoted entries; the rest stay disk-resident and
        promotable on demand."""
        with self._lock:
            if not self.capacity_ok:
                return 0
            tier = self.capacity
            live = tier.live_slots
            if live.size == 0:
                return 0
            order = live[np.argsort(-tier._reuse[live], kind="stable")]
            cap = self.budget_entries
            take = live.size if max_entries is None else int(max_entries)
            if cap is not None:
                take = min(take, max(0, cap - self.live_count))
            order = order[:take]
            if order.size == 0:
                return 0
            try:
                parts, dembs, dlens, dcsums = tier.rows_at(order)
            except Exception as e:      # noqa: BLE001 — degrade
                self._capacity_fail(e)
                return 0
            slots = self.db.put_parts(parts, dcsums)
            self._ensure_emb_capacity(int(slots.max()) + 1)
            self._embs_host[slots] = dembs
            self._lens_host[slots] = dlens
            if self.index is not self.device_index:
                self.index.assign(slots, dembs)
            self._dirty.update(int(s) for s in slots)
            self.generation += 1
            tier.note_reuse(order)
            for h, d in zip(slots, order):
                self._host_to_disk[int(h)] = int(d)
                self._disk_to_host[int(d)] = int(h)
            return int(slots.size)


# ------------------------------------------------------ eviction policies
def clock_eviction(store: MemoStore, n: int) -> List[int]:
    """Reuse-aware CLOCK: sweep the arena; entries with a nonzero reuse
    counter survive the pass with the counter halved (frequency-decaying
    second chance), zero-count entries are selected. If everything is hot
    after two sweeps, the coldest live entries go. Called under the store
    lock; the clock hand persists on the store across calls."""
    db = store.db
    counts = db.reuse_counts
    evicted: List[int] = []
    hand = store._clock_hand % db._n
    scanned, limit = 0, 2 * db._n
    while len(evicted) < n and scanned < limit:
        slot, hand = hand, (hand + 1) % db._n
        scanned += 1
        if not db._live[slot]:
            continue
        if counts[slot] > 0:
            counts[slot] //= 2
        else:
            evicted.append(slot)
    store._clock_hand = hand
    if len(evicted) < n:   # all hot: fall back to coldest-first
        live = np.flatnonzero(db.live_mask)
        live = live[~np.isin(live, evicted)]
        order = live[np.argsort(counts[live], kind="stable")]
        evicted.extend(int(s) for s in order[: n - len(evicted)])
    return evicted


def coldest_eviction(store: MemoStore, n: int) -> List[int]:
    """Strict coldest-first: the ``n`` live entries with the lowest reuse
    counts (ties broken by slot id). No second chances — simpler and
    deterministic, but a single scan burst can evict a recently-hot
    entry the CLOCK would have spared."""
    db = store.db
    live = np.flatnonzero(db.live_mask)
    order = live[np.argsort(db.reuse_counts[live], kind="stable")]
    return [int(s) for s in order[:n]]


EVICTIONS.register("clock", clock_eviction)
EVICTIONS.register("coldest", coldest_eviction)

"""Fault injection + the store error type (DESIGN.md §2.9).

AttMemo's contract is acceleration "with negligible loss in inference
accuracy" — which obligates the serving stack to a stronger one: a memo
fault may cost hit rate, never correctness or availability. This module
is the *testable* half of that contract: a registry of named fault
points threaded through the store (``repro.core.store``), the serving
runtime (``repro.core.runtime``) and session persistence
(``repro.memo.session``), so the chaos harness
(``benchmarks/serve_faults.py``) and tests/test_faults.py can drive
every failure mode deterministically.

Zero cost in production: faults are enabled through
``RuntimeSpec(faults={...})``. When that field is ``None`` (the
default) no ``FaultInjector`` is ever constructed and every fault site
compiles down to one ``x is None`` check — no RNG, no dict lookups, no
locks. ``faults={}`` builds an (idle) injector so harness code can
``arm()`` points after construction.

Trigger semantics (per armed point; each probe counts one activation):

* ``p=0.3``            — fire independently with probability 0.3
* ``at=5``             — fire from the 5th activation onward
* ``every=3``          — fire on every 3rd activation
* ``count=2``          — cap: at most 2 total fires (combines with all)
* extra kwargs (e.g. ``stall_s``) ride along and are returned to the
  fault site when the point fires.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


class MemoStoreError(ValueError):
    """Corrupt or incompatible memo-store state: a failed arena/save-file
    checksum, a truncated or unreadable save file, a spec that does not
    match the persisted arrays, or an injected store fault. Subclasses
    ``ValueError`` so pre-v2 callers catching the old save-format error
    keep working."""


# every fault point the stack knows, with where it fires — arming an
# unknown name fails fast against this table (the "registry")
FAULT_POINTS: Dict[str, str] = {
    "store.corrupt_row":    "MemoStore.admit: flip the freshly admitted "
                            "arena row's bytes (checksum left stale)",
    "store.sync_fail":      "MemoStore.sync: raise MemoStoreError before "
                            "any device mutation (delta-sync failure)",
    "store.evict_bogus":    "MemoStore.evict: policy returns dead / "
                            "duplicate / out-of-range slots (bookkeeping "
                            "fault)",
    "server.maint_crash":   "MemoServer worker: apply_maintenance raises",
    "server.maint_stall":   "MemoServer worker: sleep ``stall_s`` before "
                            "applying (staleness-watchdog food)",
    "server.queue_overflow": "MemoServer: treat the maintenance queue as "
                             "full (payload must be shed, not the batch)",
    "session.save_truncate": "MemoSession.save: crash between the temp "
                             "write and os.replace (torn temp, target "
                             "untouched)",
    "session.load_bitflip":  "MemoSession.load: flip one byte of a store "
                             "array before checksum verification",
    # capacity tier (DESIGN.md §2.11)
    "capacity.disk_write_io":   "CapacityTier.append: raise OSError before "
                                "any mutation — or, with a ``stall_s`` "
                                "rider, sleep (promotion stall)",
    "capacity.journal_torn":    "Journal.append: only a prefix of the "
                                "frame hits the disk, then the append "
                                "raises (crash mid-WAL-write)",
    "capacity.checkpoint_crash": "CapacityTier.checkpoint: die after the "
                                 "manifest temp write, before os.replace "
                                 "(old manifest + journal survive)",
    "capacity.mmap_bitflip":    "CapacityTier.append: flip one arena byte "
                                "after its row checksum was recorded",
    "capacity.compact_crash":   "CapacityTier.compact: die after the new "
                                "epoch's dense arenas are staged, before "
                                "the manifest publishes (old epoch + "
                                "journal survive; strays GC'd on reopen)",
}


@dataclass
class _Armed:
    p: float = 0.0
    at: Optional[int] = None
    every: Optional[int] = None
    count: Optional[int] = None
    args: Dict[str, object] = field(default_factory=dict)


class FaultInjector:
    """Thread-safe named fault points with deterministic + probabilistic
    triggering. One injector per engine (shared by its store, server and
    session); the serving thread and the maintenance worker probe it
    concurrently."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._armed: Dict[str, _Armed] = {}
        self.activations: Dict[str, int] = {}   # probes per point
        self.fired: Dict[str, int] = {}         # fires per point

    # ------------------------------------------------------------- config
    @classmethod
    def from_spec(cls, spec: Optional[Dict[str, Dict]], seed: int = 0
                  ) -> Optional["FaultInjector"]:
        """``RuntimeSpec.faults`` → injector. ``None`` → ``None`` (the
        production zero-cost path); a dict (possibly empty) → an
        injector with those points armed."""
        if spec is None:
            return None
        inj = cls(seed=seed)
        for point, kw in spec.items():
            inj.arm(point, **dict(kw or {}))
        return inj

    def arm(self, point: str, *, p: float = 0.0, at: Optional[int] = None,
            every: Optional[int] = None, count: Optional[int] = None,
            **args) -> None:
        """Arm one fault point. With no trigger kwargs at all the point
        fires on every activation (``p``/``at``/``every`` all unset)."""
        if point not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {point!r}; registered: "
                f"{sorted(FAULT_POINTS)}")
        if p == 0.0 and at is None and every is None:
            at = 1                                # unconditional
        with self._lock:
            self._armed[point] = _Armed(p=float(p), at=at, every=every,
                                        count=count, args=dict(args))

    def disarm(self, point: Optional[str] = None) -> None:
        with self._lock:
            if point is None:
                self._armed.clear()
            else:
                self._armed.pop(point, None)

    def armed(self, point: str) -> bool:
        with self._lock:
            return point in self._armed

    # ------------------------------------------------------------- firing
    def fire(self, point: str) -> Optional[Dict[str, object]]:
        """Probe one fault point. Returns the armed extra-args dict when
        the point fires (possibly empty — test ``is not None``), else
        ``None``. Every probe counts one activation, fired or not."""
        with self._lock:
            self.activations[point] = k = self.activations.get(point, 0) + 1
            spec = self._armed.get(point)
            if spec is None:
                return None
            if spec.count is not None \
                    and self.fired.get(point, 0) >= spec.count:
                return None
            hit = False
            if spec.p > 0.0:
                hit = bool(self._rng.random() < spec.p)
            elif spec.every is not None:
                hit = k % max(1, int(spec.every)) == 0
            elif spec.at is not None:
                hit = k >= int(spec.at)
            if not hit:
                return None
            self.fired[point] = self.fired.get(point, 0) + 1
            return dict(spec.args)

    def reset(self) -> None:
        """Clear counters (armed points stay armed)."""
        with self._lock:
            self.activations.clear()
            self.fired.clear()


def fire(injector: Optional[FaultInjector], point: str
         ) -> Optional[Dict[str, object]]:
    """The one-liner fault sites use: ``None`` injector (production)
    short-circuits before any lookup."""
    if injector is None:
        return None
    return injector.fire(point)


# chaos-class presets: fault-point arming per failure scenario, shared
# by benchmarks/serve_faults.py and ``repro.launch.server --fault``
CHAOS_PRESETS: Dict[str, Dict[str, Dict]] = {
    "corrupt_row":    {"store.corrupt_row": {"every": 2}},
    "sync_fail":      {"store.sync_fail": {"p": 0.5}},
    "evict_bogus":    {"store.evict_bogus": {}},
    "maint_crash":    {"server.maint_crash": {"p": 1.0}},
    "maint_stall":    {"server.maint_stall": {"p": 0.4, "stall_s": 0.05}},
    "queue_overflow": {"server.queue_overflow": {"p": 1.0}},
    # disk-fault classes (capacity tier, DESIGN.md §2.11): serving must
    # ride each out at RAM speed (DISK_DEGRADED, never unavailable)
    "disk_write_io":    {"capacity.disk_write_io": {"p": 1.0}},
    "journal_torn":     {"capacity.journal_torn": {"p": 1.0}},
    "checkpoint_crash": {"capacity.checkpoint_crash": {"p": 1.0}},
    "mmap_bitflip":     {"capacity.mmap_bitflip": {"every": 2}},
    "compact_crash":    {"capacity.compact_crash": {"p": 1.0}},
}

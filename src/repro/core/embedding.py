"""Hidden-state embedding model + Siamese trainer (paper §5.2).

A lightweight 3-layer MLP maps a hidden state (L, H) to a 128-d feature
vector. Per the paper all neurons are linear (y = wx + b) — the composition
is a learned linear metric, which is exactly why it is cheap enough for the
memo fast-path; a ``tanh`` variant is available as a knob.

Training uses the Siamese scheme: two weight-tied towers embed a pair of
hidden states; the loss is
    ( ‖e₁ − e₂‖₂ − d_gt )²   with   d_gt = 1 − SC(APM₁, APM₂)
so embedding distance learns to predict APM dissimilarity — no labels
needed (paper §5.2 "Training the embedding model").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.similarity import similarity_score
from repro.models.layers import dense_init


@dataclass
class Embedder:
    params: dict
    pool: int              # token-pool stride before flatten
    act: str               # "linear" | "tanh"

    @staticmethod
    def init(key, seq_len: int, hidden: int, *, dim: int = 128,
             widths: Tuple[int, int] = (512, 256), pool: int = 8,
             act: str = "linear") -> "Embedder":
        """pool: mean-pool the token axis by this stride before the MLP so
        the input layer stays 'tens of thousands of neurons' (paper)."""
        pooled = max(1, seq_len // pool)
        d_in = pooled * hidden
        ks = jax.random.split(key, 3)
        params = {
            "w1": dense_init(ks[0], (d_in, widths[0])),
            "b1": jnp.zeros((widths[0],)),
            "w2": dense_init(ks[1], (widths[0], widths[1])),
            "b2": jnp.zeros((widths[1],)),
            "w3": dense_init(ks[2], (widths[1], dim)),
            "b3": jnp.zeros((dim,)),
        }
        return Embedder(params, pool, act)

    def __call__(self, hidden):
        return embed_apply(self.params, hidden, self.pool, self.act)


def _maybe_act(x, act):
    return jnp.tanh(x) if act == "tanh" else x


def embed_apply(params, hidden, pool: int, act: str):
    """hidden: (B, L, H) → (B, dim)."""
    B, L, H = hidden.shape
    pooled = max(1, L // pool)
    h = hidden[:, : pooled * pool].reshape(B, pooled, pool, H).mean(2)
    h = h.reshape(B, -1).astype(jnp.float32)
    h = _maybe_act(h @ params["w1"] + params["b1"], act)
    h = _maybe_act(h @ params["w2"] + params["b2"], act)
    return h @ params["w3"] + params["b3"]


def siamese_loss(params, pair_a, pair_b, d_gt, pool, act):
    ea = embed_apply(params, pair_a, pool, act)
    eb = embed_apply(params, pair_b, pool, act)
    dist = jnp.sqrt(jnp.sum(jnp.square(ea - eb), -1) + 1e-12)
    return jnp.mean(jnp.square(dist - d_gt))


def train_embedder(key, embedder: Embedder, hiddens, apms, *, steps=300,
                   pair_batch=64, lr=1e-3) -> Tuple[Embedder, list]:
    """hiddens: (N, L, H); apms: (N, H_heads, L, L). Returns trained
    embedder + loss history."""
    from repro.optim.adamw import adamw_init, adamw_update

    n = hiddens.shape[0]
    opt_state = adamw_init(embedder.params)
    loss_fn = jax.jit(lambda p, a, b, d: siamese_loss(
        p, a, b, d, embedder.pool, embedder.act))
    grad_fn = jax.jit(jax.value_and_grad(lambda p, a, b, d: siamese_loss(
        p, a, b, d, embedder.pool, embedder.act)))
    params = embedder.params
    history = []
    rng = np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    for step in range(steps):
        ia = rng.integers(0, n, pair_batch)
        ib = rng.integers(0, n, pair_batch)
        d_gt = 1.0 - jax.vmap(similarity_score)(apms[ia], apms[ib])
        loss, grads = grad_fn(params, hiddens[ia], hiddens[ib], d_gt)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        history.append(float(loss))
    return Embedder(params, embedder.pool, embedder.act), history

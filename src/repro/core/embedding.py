"""Hidden-state embedding model + Siamese trainer (paper §5.2).

A lightweight 3-layer MLP maps a hidden state (L, H) to a 128-d feature
vector. Per the paper all neurons are linear (y = wx + b) — the composition
is a learned linear metric, which is exactly why it is cheap enough for the
memo fast-path; a ``tanh`` variant is available as a knob.

Training uses the Siamese scheme: two weight-tied towers embed a pair of
hidden states; the loss is
    ( ‖e₁ − e₂‖₂ − d_gt )²   with   d_gt = 1 − SC(APM₁, APM₂)
so embedding distance learns to predict APM dissimilarity — no labels
needed (paper §5.2 "Training the embedding model").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.similarity import similarity_score
from repro.models.layers import dense_init


@dataclass
class Embedder:
    params: dict
    pool: int              # token-pool stride before flatten
    act: str               # "linear" | "tanh"

    @staticmethod
    def init(key, seq_len: int, hidden: int, *, dim: int = 128,
             widths: Tuple[int, int] = (512, 256), pool: int = 8,
             act: str = "linear") -> "Embedder":
        """pool: mean-pool the token axis by this stride before the MLP so
        the input layer stays 'tens of thousands of neurons' (paper)."""
        pooled = max(1, seq_len // pool)
        d_in = pooled * hidden
        ks = jax.random.split(key, 3)
        params = {
            "w1": dense_init(ks[0], (d_in, widths[0])),
            "b1": jnp.zeros((widths[0],)),
            "w2": dense_init(ks[1], (widths[0], widths[1])),
            "b2": jnp.zeros((widths[1],)),
            "w3": dense_init(ks[2], (widths[1], dim)),
            "b3": jnp.zeros((dim,)),
        }
        return Embedder(params, pool, act)

    def __call__(self, hidden, lengths=None):
        return embed_apply(self.params, hidden, self.pool, self.act,
                           lengths=lengths)


def _maybe_act(x, act):
    return jnp.tanh(x) if act == "tanh" else x


def n_segments(params, hidden_dim: int) -> int:
    """The token-pool segment count the embedder was trained with —
    recoverable from the input layer: d_in = n_seg * H."""
    return int(params["w1"].shape[0]) // int(hidden_dim)


def _masked_pool(hidden, lengths, n_seg: int, pool: int, full_len: int):
    """Length-scaled integer-chunk pooling: each sequence's VALID prefix
    is split into ``n_seg`` contiguous chunks of ``max(1, len·pool //
    full_len)`` tokens and mean-pooled, so the pooled feature count —
    and hence the embedder input width — is independent of both the
    padded bucket length and the true length. Padded positions get
    weight 0, so a sequence padded to any bucket embeds identically to
    its unpadded run (mask-aware memo lookup, DESIGN.md §2.7).

    The chunk size is scaled against ``full_len`` (the calibration /
    arena sequence length) so that a FULL-length sequence reproduces the
    ``lengths=None`` layout exactly — chunks of ``pool`` tokens,
    truncated past ``n_seg·pool`` — for every ``full_len``, including
    ones not divisible by ``pool``; otherwise full-length serving
    queries would systematically miss calibration entries embedded by
    the contiguous path."""
    B, L, H = hidden.shape
    ln = lengths.astype(jnp.int32)
    chunk = jnp.maximum((ln * pool) // max(int(full_len), 1), 1)   # (B,)
    t = jnp.arange(L, dtype=jnp.int32)
    seg = t[None, :] // chunk[:, None]                             # (B, L)
    valid = t[None, :] < jnp.minimum(ln, chunk * n_seg)[:, None]
    w = ((seg[:, :, None] == jnp.arange(n_seg)[None, None, :])
         & valid[:, :, None]).astype(jnp.float32)       # (B, L, n_seg)
    pooled = jnp.einsum("bls,blh->bsh", w, hidden.astype(jnp.float32))
    return pooled / jnp.maximum(w.sum(1), 1.0)[:, :, None]


def embed_apply(params, hidden, pool: int, act: str, lengths=None,
                full_len=None):
    """hidden: (B, L, H) → (B, dim). With ``lengths`` (B,), pooling is
    mask-aware (padded rows ignored, chunks span the true length);
    ``full_len`` is the calibration sequence length the chunk scale is
    anchored to (default: the embedder's covered length ``n_seg·pool``,
    exact whenever the training length was divisible by ``pool``)."""
    B, L, H = hidden.shape
    if lengths is None:
        pooled = max(1, L // pool)
        h = hidden[:, : pooled * pool].reshape(B, pooled, pool, H).mean(2)
    else:
        n_seg = n_segments(params, H)
        if full_len is None:
            full_len = n_seg * pool
        h = _masked_pool(hidden, lengths, n_seg, pool, full_len)
    h = h.reshape(B, -1).astype(jnp.float32)
    h = _maybe_act(h @ params["w1"] + params["b1"], act)
    h = _maybe_act(h @ params["w2"] + params["b2"], act)
    return h @ params["w3"] + params["b3"]


def siamese_loss(params, pair_a, pair_b, d_gt, pool, act):
    ea = embed_apply(params, pair_a, pool, act)
    eb = embed_apply(params, pair_b, pool, act)
    dist = jnp.sqrt(jnp.sum(jnp.square(ea - eb), -1) + 1e-12)
    return jnp.mean(jnp.square(dist - d_gt))


def train_embedder(key, embedder: Embedder, hiddens, apms, *, steps=300,
                   pair_batch=64, lr=1e-3) -> Tuple[Embedder, list]:
    """hiddens: (N, L, H); apms: (N, H_heads, L, L). Returns trained
    embedder + loss history."""
    from repro.optim.adamw import adamw_init, adamw_update

    n = hiddens.shape[0]
    opt_state = adamw_init(embedder.params)
    loss_fn = jax.jit(lambda p, a, b, d: siamese_loss(
        p, a, b, d, embedder.pool, embedder.act))
    grad_fn = jax.jit(jax.value_and_grad(lambda p, a, b, d: siamese_loss(
        p, a, b, d, embedder.pool, embedder.act)))
    params = embedder.params
    history = []
    rng = np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    for step in range(steps):
        ia = rng.integers(0, n, pair_batch)
        ib = rng.integers(0, n, pair_batch)
        d_gt = 1.0 - jax.vmap(similarity_score)(apms[ia], apms[ib])
        loss, grads = grad_fn(params, hiddens[ia], hiddens[ib], d_gt)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        history.append(float(loss))
    return Embedder(params, embedder.pool, embedder.act), history

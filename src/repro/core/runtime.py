"""MemoServer — asynchronous continuous-batching serving runtime
(DESIGN.md §2.7).

The engine serves *batches*; production traffic is *requests*: individual
variable-length sequences arriving open-loop. MemoServer owns the gap:

* **length-bucketed continuous batching** — each request lands in the
  smallest length bucket that fits it; a batch launches when a bucket
  fills ``max_batch`` or its head request has waited ``max_delay``.
  Tokens are padded to the bucket length and the batch row count is
  padded to a power of two (filler rows replay row 0 and are dropped at
  ``n_valid``), so the jit-shape set is bounded by
  ``len(buckets) * log2(max_batch)`` — no recompiles under arbitrary
  traffic.
* **step-wise engine execution** — the runtime calls the engine's
  ``prepare_batch → run_layers → finalize`` split directly, keeping the
  zero-per-layer-host-sync invariant (one barrier per batch, enforced by
  tests/test_runtime.py).
* **off-thread store maintenance** — ``finalize`` returns a
  ``MaintenancePayload`` (device-tier reuse, captured misses); in async
  mode a single background worker applies it (admission under budget,
  CLOCK eviction, delta-sync prep + ship, recalibration) while the
  serving thread is already driving batch t+1's device compute. The
  worker finishes each payload by atomically publishing a fresh
  ``StoreSnapshot``; the serving thread reads exactly one snapshot per
  batch, so the fused fast path can never observe a half-applied sync.
  In sync mode the same payload is applied inline at the batch boundary
  — the head-of-line-latency baseline the benchmark A/Bs against.
* **supervised maintenance + graceful degradation** (DESIGN.md §2.9) —
  the worker retries failed payloads with exponential backoff; a
  payload that exhausts its retries is SHED (dropped), never re-raised
  into a request. Health walks an explicit ladder::

      HEALTHY → DEGRADED → MEMO_DISABLED

  DEGRADED keeps serving from the last atomically-published
  ``StoreSnapshot`` (memo path intact, maintenance shedding);
  ``disable_after`` consecutive payload failures escalate to
  MEMO_DISABLED, which routes every batch through exact attention —
  bit-identical logits to ``engine.infer(use_memo=False)``. A
  staleness watchdog flags a stalled worker, ``drain_maintenance``
  takes a ``timeout`` and checks worker liveness, and ``recover()``
  re-materializes the device tier from the host mirrors (quarantining
  entries that fail checksum validation) to restore the pre-fault hit
  rate.
"""
from __future__ import annotations

import enum
import queue
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import MemoEngine, MemoStats
from repro.core.faults import fire


class Health(enum.Enum):
    """The serving-health ladder (DESIGN.md §2.9). Order matters:
    each step gives up store durability, then freshness, then the memo
    path, never the request."""
    HEALTHY = "healthy"
    DISK_DEGRADED = "disk_degraded"  # capacity tier detached: serve from
    #                                  RAM at full speed, no durability /
    #                                  demotion (recover() reattaches)
    DEGRADED = "degraded"            # serve last snapshot; shed maintenance
    MEMO_DISABLED = "memo_disabled"  # exact attention; no maintenance


class MemoMaintenanceError(RuntimeError):
    """A maintenance payload failed after every retry. Chained
    (``__cause__``) to the original worker exception, with the store
    generation the payload was drained against in the message."""


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (length,) int32
    arrival: float              # runtime-clock seconds (scheduled arrival)
    enqueue: float              # when it actually entered its bucket queue
    prefill: bool = False       # memoized-prefill request (DESIGN.md §2.13)


@dataclass
class Completion:
    rid: int
    logits: np.ndarray          # unpadded: (n_classes,) or (length, vocab);
    #                             prefill requests: (vocab,) last-token row
    latency: float              # completion − arrival (queue + compute)
    length: int
    bucket: int
    batch_rows: int             # real rows in the batch that served it
    caches: Optional[dict] = None   # prefill only: this request's decode
    #                                 caches (batch row 0), ready for
    #                                 model.decode_step / gqa_decode


def pow2_buckets(max_len: int, n: int = 3, min_len: int = 8
                 ) -> Tuple[int, ...]:
    """Halving length buckets ending at ``max_len`` (the arena length):
    e.g. 64 → (16, 32, 64)."""
    out = [int(max_len)]
    while len(out) < n and out[-1] // 2 >= min_len:
        out.append(out[-1] // 2)
    return tuple(sorted(out))


class MemoServer:
    """Open-loop serving runtime over a built (fast-path) MemoEngine.

    ``async_maintenance=True`` moves ALL host-tier store work onto the
    background worker; ``False`` applies it inline at each batch boundary
    (the synchronous baseline). Everything else is identical, so the A/B
    isolates the overlap.
    """

    def __init__(self, engine: MemoEngine, *,
                 buckets: Optional[Sequence[int]] = None,
                 max_batch: int = 16, max_delay: float = 2e-3,
                 batch_quantum: int = 4, async_maintenance: bool = True,
                 maint_queue_depth: int = 4, maint_retries: int = 2,
                 maint_backoff_s: float = 0.02, watchdog_s: float = 30.0,
                 disable_after: int = 3, maint_put_timeout: float = 0.25,
                 health_log_cap: int = 64,
                 checkpoint_every: Optional[int] = None):
        if engine.store is None:
            raise RuntimeError("build() the engine before serving")
        if not engine._use_fast_path():
            raise RuntimeError("MemoServer drives the device fast path; "
                               "use RuntimeSpec(mode='bucket')")
        if engine.mc.mode == "kernel":
            raise RuntimeError("variable-length serving supports bucket "
                               "mode (the kernel path is fixed-length)")
        self.engine = engine
        s_max = engine.store.apm_shape[-1]
        self.buckets = tuple(sorted(int(b) for b in (
            buckets if buckets is not None else pow2_buckets(s_max))))
        if self.buckets[-1] > s_max:
            raise ValueError(f"bucket {self.buckets[-1]} exceeds the "
                             f"arena length {s_max}")
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.batch_quantum = max(1, int(batch_quantum))
        self.async_maintenance = bool(async_maintenance)
        # queues are keyed (bucket, prefill-kind): a batch must be
        # homogeneous — classify/LM batches and prefill batches run
        # different engine legs (finalize returns (logits, caches) for
        # prefill) and therefore never mix rows
        self._queues: Dict[Tuple[int, bool], deque] = {
            (b, pf): deque() for b in self.buckets for pf in (False, True)}
        self._rid = 0
        self._t0 = time.perf_counter()
        # global stats: per-batch MemoStats are merged in (serving thread)
        # and the maintenance worker bumps admission counters — both via
        # the lock-guarded MemoStats/SimReservoir paths
        self.stats = MemoStats()
        self.n_batches = 0
        self.n_filler_rows = 0          # pow2 batch-padding overhead
        self.maintenance_errors: List[BaseException] = []
        # supervision (DESIGN.md §2.9)
        self.faults = engine.faults       # None in production
        self.maint_retries = max(0, int(maint_retries))
        self.maint_backoff_s = float(maint_backoff_s)
        self.watchdog_s = float(watchdog_s)
        self.disable_after = max(1, int(disable_after))
        self.maint_put_timeout = float(maint_put_timeout)
        self.health = Health.HEALTHY
        # BOUNDED transition history: a flapping fault must not grow
        # memory without limit over a long serve; n_health_transitions
        # keeps the total count honest past the ring's horizon
        self.health_log: deque = deque(maxlen=max(1, int(health_log_cap)))
        self.n_health_transitions = 0
        # capacity-tier checkpoint cadence (DESIGN.md §2.11): flush the
        # WAL into a fresh shadow manifest every N applied payloads
        self.checkpoint_every = int(
            engine.mc.capacity.checkpoint_every if checkpoint_every is None
            else checkpoint_every)
        self._applies_since_ckpt = 0
        self.n_checkpoints = 0
        # background re-compaction (DESIGN.md §2.11): when the tier's
        # retired-hole fraction crosses compact_ratio, the maintenance
        # actor rewrites it densely right after a checkpoint
        self.compact_ratio = engine.mc.capacity.compact_ratio
        self.n_compactions = 0
        self.n_maint_shed = 0             # payloads dropped, never requests
        self.n_maint_retries = 0
        self.n_exact_batches = 0          # batches served in MEMO_DISABLED
        self._consec_failures = 0
        self._health_lock = threading.Lock()
        self._maint_busy_since: Optional[float] = None
        self._maint_q: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None
        if self.async_maintenance:
            # BOUNDED: each payload pins full captured-miss APM blocks;
            # if maintenance falls more than ``maint_queue_depth`` batches
            # behind, put() blocks up to ``maint_put_timeout`` (transient
            # backpressure toward the sync baseline) and then SHEDS the
            # payload — store freshness is sacrificed before request
            # latency, and memory stays bounded
            self._maint_q = queue.Queue(maxsize=max(1, maint_queue_depth))
            self._worker = self._start_worker()

    def _start_worker(self) -> threading.Thread:
        w = threading.Thread(target=self._maintenance_loop,
                             name="memo-maintenance", daemon=True)
        w.start()
        return w

    # ------------------------------------------------------------- clock
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    # --------------------------------------------------------- queueing
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def bucket_for(self, length: int) -> int:
        for b in self.buckets:
            if length <= b:
                return b
        raise ValueError(f"request length {length} exceeds the largest "
                         f"bucket {self.buckets[-1]}")

    def submit(self, tokens, arrival: Optional[float] = None,
               prefill: bool = False) -> int:
        """Enqueue one request; returns its id. ``arrival`` defaults to
        now — open-loop drivers pass the scheduled arrival time so queue
        delay is charged to the server, not the generator.

        ``prefill=True`` requests the memoized-prefill leg (DESIGN.md
        §2.13): the completion's ``logits`` is the last-token row and
        its ``caches`` carries this request's decode caches."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError("empty request")
        if prefill and not self.engine.mc.prefill.enabled:
            raise RuntimeError("prefill request on a server whose engine "
                               "has prefill disabled (set prefill_enabled "
                               "in the MemoSpec)")
        now = self._now()
        rid, self._rid = self._rid, self._rid + 1
        req = Request(rid=rid, tokens=tokens,
                      arrival=now if arrival is None else float(arrival),
                      enqueue=now, prefill=bool(prefill))
        self._queues[(self.bucket_for(tokens.size), bool(prefill))
                     ].append(req)
        return rid

    def _ready_bucket(self, now: float, flush: bool
                      ) -> Optional[Tuple[int, bool]]:
        """Batching policy: a bucket is ready when full or when its head
        request has waited past ``max_delay``; among ready buckets the
        oldest head wins (head-of-line fairness across buckets)."""
        best, best_t = None, None
        for key, q in self._queues.items():
            if not q:
                continue
            head_wait = now - q[0].enqueue
            if flush or len(q) >= self.max_batch \
                    or head_wait >= self.max_delay:
                if best is None or q[0].enqueue < best_t:
                    best, best_t = key, q[0].enqueue
        return best

    def _pad_rows(self, n: int) -> int:
        """Pow2 row padding from the bounded set {quantum, 2·quantum, …,
        max_batch} — the jit-shape budget's batch leg."""
        p = self.batch_quantum
        while p < n:
            p *= 2
        return min(p, self.max_batch)

    # ---------------------------------------------------------- serving
    def step(self, flush: bool = False) -> List[Completion]:
        """Assemble and serve at most one batch. Returns completions
        (empty when no bucket is ready)."""
        now = self._now()
        key = self._ready_bucket(now, flush)
        if key is None:
            return []
        q = self._queues[key]
        reqs = [q.popleft() for _ in range(min(len(q), self.max_batch))]
        return self._execute(key[0], reqs, prefill=key[1])

    def _execute(self, bucket: int, reqs: List[Request],
                 prefill: bool = False) -> List[Completion]:
        eng = self.engine
        n = len(reqs)
        rows = self._pad_rows(n)
        toks = np.zeros((rows, bucket), np.int32)
        lens = np.empty((rows,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : r.tokens.size] = r.tokens
            lens[i] = r.tokens.size
        if rows > n:                    # filler rows replay row 0
            toks[n:] = toks[0]
            lens[n:] = lens[0]
            self.n_filler_rows += rows - n
        batch = {"tokens": jnp.asarray(toks), "lengths": lens,
                 "n_valid": n}
        st = MemoStats()
        if self.async_maintenance:
            self._check_worker()
        if self.health is Health.MEMO_DISABLED:
            # the bottom of the degradation ladder: exact attention via
            # the engine's no-memo path — logits bit-identical to
            # ``infer(use_memo=False)`` / ``prefill_exact``, no store
            # reads, no maintenance
            if prefill:
                out = eng.prefill_exact(batch)
            else:
                out, st = eng.infer(batch, stats=st, use_memo=False)
            self.n_exact_batches += 1
        else:
            prep = eng.prepare_batch(batch, prefill=prefill,
                                     sync_store=not self.async_maintenance)
            eng.run_layers(prep)
            out, st, payload = eng.finalize(prep, stats=st)
            if self.async_maintenance:
                if self._worker is None:   # closed: nobody drains the
                    raise RuntimeError(    # queue — fail loudly instead
                        "MemoServer is closed")  # of blocking on put()
                self._enqueue_payload(payload)
            else:
                eng.apply_maintenance(payload, stats=self.stats)
                self._after_apply()
        self.stats.merge(st)
        self.n_batches += 1
        done = self._now()
        comps = []
        if prefill:
            logits_all, caches = out
            out_np = np.asarray(logits_all)          # (rows, vocab)
            by_li = eng._split_caches(caches)
            for i, r in enumerate(reqs):
                # per-request decode caches: slice batch row i out of
                # every cache leaf, then re-merge into the segment
                # pytree model.decode_step consumes (slicing the merged
                # tree directly would hit scan segments' leading reps
                # axis instead of the batch axis)
                c_i = eng._merge_caches({
                    li: jax.tree.map(lambda a, i=i: a[i: i + 1], c)
                    for li, c in by_li.items()})
                comps.append(Completion(
                    rid=r.rid, logits=out_np[i], latency=done - r.arrival,
                    length=int(r.tokens.size), bucket=bucket,
                    batch_rows=n, caches=c_i))
            return comps
        out_np = np.asarray(out)
        for i, r in enumerate(reqs):
            logits = (out_np[i] if out_np.ndim == 2
                      else out_np[i, : r.tokens.size])
            comps.append(Completion(
                rid=r.rid, logits=logits, latency=done - r.arrival,
                length=int(r.tokens.size), bucket=bucket, batch_rows=n))
        return comps

    # ----------------------------------------------------------- health
    def _set_health(self, health: Health, reason: str) -> None:
        with self._health_lock:
            if self.health is health:
                return
            self.health = health
            self.n_health_transitions += 1
            self.health_log.append((self._now(), health.value, reason))

    def _note_disk(self) -> None:
        """Walk HEALTHY down to DISK_DEGRADED when the capacity tier has
        detached (disk I/O error, stalled promotion, failed checkpoint).
        Never touches DEGRADED/MEMO_DISABLED — losing the disk tier is
        the mildest rung — and never auto-heals: reattaching the tier is
        ``recover()``'s job."""
        store = self.engine.store
        if store.capacity_error is not None \
                and self.health is Health.HEALTHY:
            self._set_health(
                Health.DISK_DEGRADED,
                f"capacity tier detached ({store.capacity_error}); "
                f"serving RAM-only (recover() to reattach)")

    def _after_apply(self) -> None:
        """Post-payload bookkeeping on the maintenance actor: capacity
        checkpoint cadence + disk-health probe. Checkpoint failures
        detach the tier inside ``store.checkpoint`` (never raise)."""
        store = self.engine.store
        if store.capacity_ok:
            self._applies_since_ckpt += 1
            if self._applies_since_ckpt >= max(1, self.checkpoint_every):
                self._applies_since_ckpt = 0
                if store.checkpoint():
                    self.n_checkpoints += 1
                if self.compact_ratio is not None \
                        and store.compact_capacity(
                            self.compact_ratio) is not None:
                    self.n_compactions += 1
        self._note_disk()

    def _check_worker(self) -> None:
        """Serving-thread supervision, once per batch: restart a dead
        worker (DEGRADED until a payload applies cleanly again) and run
        the staleness watchdog — a payload in flight longer than
        ``watchdog_s`` marks the worker stalled. Neither path ever
        blocks or fails the batch."""
        w = self._worker
        if w is not None and not w.is_alive():
            self._set_health(Health.DEGRADED,
                             "maintenance worker died; restarted")
            self._worker = self._start_worker()
        busy = self._maint_busy_since
        if busy is not None \
                and time.monotonic() - busy > self.watchdog_s:
            self._set_health(
                Health.DEGRADED,
                f"maintenance stalled > {self.watchdog_s:.3g}s "
                f"(staleness watchdog)")
        self._note_disk()

    def _enqueue_payload(self, payload) -> None:
        """Hand one payload to the worker, shedding — never blocking the
        serving thread past ``maint_put_timeout`` — when the bounded
        queue stays full (shed maintenance, not requests)."""
        forced = fire(self.faults, "server.queue_overflow") is not None
        if not forced:
            try:
                self._maint_q.put_nowait(payload)
                return
            except queue.Full:
                try:          # transient backpressure before giving up
                    self._maint_q.put(payload,
                                      timeout=self.maint_put_timeout)
                    return
                except queue.Full:
                    pass
        self.n_maint_shed += 1
        self._set_health(Health.DEGRADED,
                         "maintenance queue overflow; shedding payloads")

    # ------------------------------------------------------ maintenance
    def _maintenance_loop(self):
        while True:
            item = self._maint_q.get()
            try:
                if item is None:
                    return
                self._apply_supervised(item)
            finally:
                self._maint_busy_since = None
                self._maint_q.task_done()

    def _apply_supervised(self, payload) -> None:
        """Apply one payload with bounded retry + exponential backoff.
        ``apply_maintenance`` is retry-safe (fields are consumed on
        first touch), so a retry after a mid-sync failure re-converges
        the store instead of double-admitting. A payload that exhausts
        its retries is recorded (traceback + generation preserved) and
        shed; ``disable_after`` consecutive shed payloads walk health
        down to MEMO_DISABLED."""
        self._maint_busy_since = time.monotonic()
        gen = getattr(payload, "generation", -1)
        delay = self.maint_backoff_s
        for attempt in range(self.maint_retries + 1):
            stall = fire(self.faults, "server.maint_stall")
            if stall is not None:
                time.sleep(float(stall.get("stall_s", 0.5)))
            try:
                if fire(self.faults, "server.maint_crash") is not None:
                    raise RuntimeError(
                        "injected maintenance-worker crash")
                self.engine.apply_maintenance(payload, stats=self.stats)
            except BaseException as e:  # noqa: BLE001 — supervised
                if attempt < self.maint_retries:
                    self.n_maint_retries += 1
                    time.sleep(delay)
                    delay *= 2
                    continue
                try:
                    raise MemoMaintenanceError(
                        f"maintenance failed after {attempt + 1} "
                        f"attempt(s) applying the payload drained at "
                        f"store generation {gen}: "
                        f"{type(e).__name__}: {e}") from e
                except MemoMaintenanceError as wrapped:
                    self.maintenance_errors.append(wrapped)
                self._note_failure()
                return
            self._note_success()
            self._after_apply()
            return

    def _note_failure(self) -> None:
        with self._health_lock:
            self._consec_failures += 1
            n = self._consec_failures
        if n >= self.disable_after:
            self._set_health(
                Health.MEMO_DISABLED,
                f"{n} consecutive maintenance failures; serving exact "
                f"attention (recover() to re-arm the memo path)")
            self._purge_queue()
        else:
            self._set_health(Health.DEGRADED,
                             "maintenance payload shed after retries")

    def _note_success(self) -> None:
        with self._health_lock:
            self._consec_failures = 0
            back = self.health is Health.DEGRADED
        if back:
            # DEGRADED heals itself the moment maintenance flows again;
            # MEMO_DISABLED stays down until an explicit recover()
            self._set_health(Health.HEALTHY, "maintenance applied cleanly")

    def _purge_queue(self) -> None:
        """Drop every queued payload without applying it (entering
        MEMO_DISABLED: nothing will read the store)."""
        if self._maint_q is None:
            return
        while True:
            try:
                item = self._maint_q.get_nowait()
            except queue.Empty:
                return
            if item is None:      # keep the shutdown sentinel's contract
                self._maint_q.task_done()
                self._maint_q.put(None)
                return
            self.n_maint_shed += 1
            self._maint_q.task_done()

    def drain_maintenance(self, timeout: Optional[float] = None,
                          raise_errors: bool = True):
        """Block until every queued payload has been applied (and its
        snapshot published) — the quiesce point for tests/benchmarks.
        Raises (and clears) the first worker error since the last drain
        unless ``raise_errors=False`` (chaos harnesses inspect
        ``maintenance_errors``/health instead).

        ``timeout`` bounds the wait (``TimeoutError``); a worker that is
        no longer alive with payloads still queued raises immediately
        instead of blocking forever."""
        q = self._maint_q
        if q is not None:
            deadline = (None if timeout is None
                        else time.monotonic() + float(timeout))
            with q.all_tasks_done:
                while q.unfinished_tasks:
                    w = self._worker
                    if w is None or not w.is_alive():
                        raise MemoMaintenanceError(
                            f"maintenance worker is not alive with "
                            f"{q.unfinished_tasks} payload(s) pending")
                    if deadline is not None \
                            and time.monotonic() >= deadline:
                        raise TimeoutError(
                            f"drain_maintenance timed out after "
                            f"{timeout}s with {q.unfinished_tasks} "
                            f"payload(s) pending")
                    q.all_tasks_done.wait(0.05)
        if self.maintenance_errors:
            errs, self.maintenance_errors = self.maintenance_errors, []
            if raise_errors:
                raise errs[0]

    # ----------------------------------------------------------- recover
    def recover(self) -> Dict[str, object]:
        """Re-arm the memo path after faults (DESIGN.md §2.9): verify
        every live entry's checksums (quarantining and tombstoning the
        corrupt ones), re-materialize the device tier from the host
        mirrors with a forced full sync, restart the worker if it died,
        and reset health to HEALTHY. The host tier survives worker
        crashes and shed payloads untouched, so post-recovery hit rate
        returns to the fault-free level (minus quarantined entries).
        A detached capacity tier is re-opened (journal replay + CRC
        sweep) and re-checkpointed; if the disk stays broken the tier
        stays detached and serving continues RAM-only."""
        store = self.engine.store
        if store.capacity_error is not None:
            if store.reattach_capacity():
                store.checkpoint()
        quarantined = store.verify_integrity(quarantine=True)
        store.sync(force_full=True)
        if self.async_maintenance and self._maint_q is not None \
                and (self._worker is None or not self._worker.is_alive()):
            self._worker = self._start_worker()
        with self._health_lock:
            self._consec_failures = 0
        # recovery acknowledges the fault window: the shed-payload
        # errors are part of what was recovered from
        self.maintenance_errors = []
        self._set_health(Health.HEALTHY, "recovered: device tier "
                         "re-materialized from host mirrors")
        self._note_disk()       # a still-broken disk re-degrades at once
        return {"quarantined": len(quarantined),
                "live_entries": store.live_count,
                "generation": store.generation,
                # None when no capacity dir is configured — only a real
                # tier can be meaningfully (not-)ok
                "capacity_ok": (store.capacity_ok
                                if store._capacity_dir else None)}

    def close(self):
        if self._worker is not None:
            w = self._worker
            while w.is_alive():
                try:
                    self._maint_q.put(None, timeout=0.1)
                    break
                except queue.Full:    # stalled worker: wait for space
                    continue
            w.join(timeout=30)
            self._worker = None
        # parting durability: fold the WAL tail into a clean manifest so
        # a reopen replays nothing (best-effort; failures just detach)
        store = self.engine.store
        if store is not None and store.capacity_ok:
            self.engine.store.checkpoint()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        try:
            self.drain_maintenance()
        finally:
            self.close()

    # ---------------------------------------------------------- warm-up
    def warmup(self, batch_sizes: Optional[Sequence[int]] = None):
        """Compile the bounded jit-shape set outside the measured window:
        one dummy batch per (bucket, padded-row-count, capture-variant)
        combination — with ``admit_every > 1`` the fused jit has BOTH a
        capturing and a non-capturing variant per shape, and serving
        alternates between them, so both must be compiled here or a
        mid-trace XLA compile lands in the p99. Maintenance payloads are
        dropped and counters rolled back, so warm-up leaves the store
        untouched."""
        sizes = list(batch_sizes) if batch_sizes is not None else None
        if sizes is None:
            sizes, p = [], self.batch_quantum
            while p < self.max_batch:
                sizes.append(p)
                p *= 2
            sizes.append(self.max_batch)
        eng = self.engine
        serve_counter = eng._serve_batches
        # _capture_now keys off _serve_batches % admit_every: batch
        # parity 0 captures (when admission is on), parity 1 does not
        parities = ([0, 1] if eng.mc.admit and eng.mc.admit_every > 1
                    else [0])
        kinds = [False] + ([True] if eng.mc.prefill.enabled else [])
        try:
            for b in self.buckets:
                for rows in sizes:
                    for parity in parities:
                        for pf in kinds:
                            eng._serve_batches = parity
                            toks = np.zeros((rows, b), np.int32)
                            lens = np.full((rows,), max(1, b // 2),
                                           np.int32)
                            batch = {"tokens": jnp.asarray(toks),
                                     "lengths": lens, "n_valid": rows}
                            prep = eng.prepare_batch(batch, prefill=pf,
                                                     sync_store=False)
                            eng.run_layers(prep)
                            eng.finalize(prep, stats=MemoStats())
        finally:
            eng._serve_batches = serve_counter

    # --------------------------------------------------------- open loop
    def run(self, workload: Sequence[Tuple],
            ) -> List[Completion]:
        """Serve an open-loop trace: ``workload`` is [(arrival_s, tokens)]
        — or [(arrival_s, tokens, prefill)] to mix in prefill requests —
        on the runtime clock starting now. Arrivals are injected by
        schedule regardless of server progress (queueing delay is the
        server's problem — that is the open-loop point); returns one
        Completion per request with end-to-end latency."""
        wl = sorted(workload, key=lambda a: a[0])
        base = self._now()
        i, comps = 0, []
        while i < len(wl) or self.queued:
            now = self._now() - base
            while i < len(wl) and wl[i][0] <= now:
                item = wl[i]
                self.submit(item[1], arrival=base + item[0],
                            prefill=bool(item[2]) if len(item) > 2
                            else False)
                i += 1
            got = self.step(flush=i >= len(wl))
            if got:
                comps.extend(got)
                continue
            if i < len(wl):
                time.sleep(min(max(wl[i][0] - (self._now() - base), 0.0),
                               self.max_delay))
        return comps

"""MemoServer — asynchronous continuous-batching serving runtime
(DESIGN.md §2.7).

The engine serves *batches*; production traffic is *requests*: individual
variable-length sequences arriving open-loop. MemoServer owns the gap:

* **length-bucketed continuous batching** — each request lands in the
  smallest length bucket that fits it; a batch launches when a bucket
  fills ``max_batch`` or its head request has waited ``max_delay``.
  Tokens are padded to the bucket length and the batch row count is
  padded to a power of two (filler rows replay row 0 and are dropped at
  ``n_valid``), so the jit-shape set is bounded by
  ``len(buckets) * log2(max_batch)`` — no recompiles under arbitrary
  traffic.
* **step-wise engine execution** — the runtime calls the engine's
  ``prepare_batch → run_layers → finalize`` split directly, keeping the
  zero-per-layer-host-sync invariant (one barrier per batch, enforced by
  tests/test_runtime.py).
* **off-thread store maintenance** — ``finalize`` returns a
  ``MaintenancePayload`` (device-tier reuse, captured misses); in async
  mode a single background worker applies it (admission under budget,
  CLOCK eviction, delta-sync prep + ship, recalibration) while the
  serving thread is already driving batch t+1's device compute. The
  worker finishes each payload by atomically publishing a fresh
  ``StoreSnapshot``; the serving thread reads exactly one snapshot per
  batch, so the fused fast path can never observe a half-applied sync.
  In sync mode the same payload is applied inline at the batch boundary
  — the head-of-line-latency baseline the benchmark A/Bs against.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.engine import MemoEngine, MemoStats


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (length,) int32
    arrival: float              # runtime-clock seconds (scheduled arrival)
    enqueue: float              # when it actually entered its bucket queue


@dataclass
class Completion:
    rid: int
    logits: np.ndarray          # unpadded: (n_classes,) or (length, vocab)
    latency: float              # completion − arrival (queue + compute)
    length: int
    bucket: int
    batch_rows: int             # real rows in the batch that served it


def pow2_buckets(max_len: int, n: int = 3, min_len: int = 8
                 ) -> Tuple[int, ...]:
    """Halving length buckets ending at ``max_len`` (the arena length):
    e.g. 64 → (16, 32, 64)."""
    out = [int(max_len)]
    while len(out) < n and out[-1] // 2 >= min_len:
        out.append(out[-1] // 2)
    return tuple(sorted(out))


class MemoServer:
    """Open-loop serving runtime over a built (fast-path) MemoEngine.

    ``async_maintenance=True`` moves ALL host-tier store work onto the
    background worker; ``False`` applies it inline at each batch boundary
    (the synchronous baseline). Everything else is identical, so the A/B
    isolates the overlap.
    """

    def __init__(self, engine: MemoEngine, *,
                 buckets: Optional[Sequence[int]] = None,
                 max_batch: int = 16, max_delay: float = 2e-3,
                 batch_quantum: int = 4, async_maintenance: bool = True,
                 maint_queue_depth: int = 4):
        if engine.store is None:
            raise RuntimeError("build() the engine before serving")
        if not engine._use_fast_path():
            raise RuntimeError("MemoServer drives the device fast path; "
                               "use RuntimeSpec(mode='bucket')")
        if engine.mc.mode == "kernel":
            raise RuntimeError("variable-length serving supports bucket "
                               "mode (the kernel path is fixed-length)")
        self.engine = engine
        s_max = engine.store.apm_shape[-1]
        self.buckets = tuple(sorted(int(b) for b in (
            buckets if buckets is not None else pow2_buckets(s_max))))
        if self.buckets[-1] > s_max:
            raise ValueError(f"bucket {self.buckets[-1]} exceeds the "
                             f"arena length {s_max}")
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.batch_quantum = max(1, int(batch_quantum))
        self.async_maintenance = bool(async_maintenance)
        self._queues: Dict[int, deque] = {b: deque() for b in self.buckets}
        self._rid = 0
        self._t0 = time.perf_counter()
        # global stats: per-batch MemoStats are merged in (serving thread)
        # and the maintenance worker bumps admission counters — both via
        # the lock-guarded MemoStats/SimReservoir paths
        self.stats = MemoStats()
        self.n_batches = 0
        self.n_filler_rows = 0          # pow2 batch-padding overhead
        self.maintenance_errors: List[BaseException] = []
        self._maint_q: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None
        if self.async_maintenance:
            # BOUNDED: each payload pins full captured-miss APM blocks;
            # if maintenance falls more than ``maint_queue_depth`` batches
            # behind, the serving thread blocks on put() — backpressure
            # degrades toward the sync baseline instead of growing the
            # queue (and memory) without bound
            self._maint_q = queue.Queue(maxsize=max(1, maint_queue_depth))
            self._worker = threading.Thread(
                target=self._maintenance_loop, name="memo-maintenance",
                daemon=True)
            self._worker.start()

    # ------------------------------------------------------------- clock
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    # --------------------------------------------------------- queueing
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def bucket_for(self, length: int) -> int:
        for b in self.buckets:
            if length <= b:
                return b
        raise ValueError(f"request length {length} exceeds the largest "
                         f"bucket {self.buckets[-1]}")

    def submit(self, tokens, arrival: Optional[float] = None) -> int:
        """Enqueue one request; returns its id. ``arrival`` defaults to
        now — open-loop drivers pass the scheduled arrival time so queue
        delay is charged to the server, not the generator."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError("empty request")
        now = self._now()
        rid, self._rid = self._rid, self._rid + 1
        req = Request(rid=rid, tokens=tokens,
                      arrival=now if arrival is None else float(arrival),
                      enqueue=now)
        self._queues[self.bucket_for(tokens.size)].append(req)
        return rid

    def _ready_bucket(self, now: float, flush: bool) -> Optional[int]:
        """Batching policy: a bucket is ready when full or when its head
        request has waited past ``max_delay``; among ready buckets the
        oldest head wins (head-of-line fairness across buckets)."""
        best, best_t = None, None
        for b, q in self._queues.items():
            if not q:
                continue
            head_wait = now - q[0].enqueue
            if flush or len(q) >= self.max_batch \
                    or head_wait >= self.max_delay:
                if best is None or q[0].enqueue < best_t:
                    best, best_t = b, q[0].enqueue
        return best

    def _pad_rows(self, n: int) -> int:
        """Pow2 row padding from the bounded set {quantum, 2·quantum, …,
        max_batch} — the jit-shape budget's batch leg."""
        p = self.batch_quantum
        while p < n:
            p *= 2
        return min(p, self.max_batch)

    # ---------------------------------------------------------- serving
    def step(self, flush: bool = False) -> List[Completion]:
        """Assemble and serve at most one batch. Returns completions
        (empty when no bucket is ready)."""
        now = self._now()
        b = self._ready_bucket(now, flush)
        if b is None:
            return []
        q = self._queues[b]
        reqs = [q.popleft() for _ in range(min(len(q), self.max_batch))]
        return self._execute(b, reqs)

    def _execute(self, bucket: int, reqs: List[Request]
                 ) -> List[Completion]:
        eng = self.engine
        n = len(reqs)
        rows = self._pad_rows(n)
        toks = np.zeros((rows, bucket), np.int32)
        lens = np.empty((rows,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : r.tokens.size] = r.tokens
            lens[i] = r.tokens.size
        if rows > n:                    # filler rows replay row 0
            toks[n:] = toks[0]
            lens[n:] = lens[0]
            self.n_filler_rows += rows - n
        batch = {"tokens": jnp.asarray(toks), "lengths": lens,
                 "n_valid": n}
        st = MemoStats()
        prep = eng.prepare_batch(batch,
                                 sync_store=not self.async_maintenance)
        eng.run_layers(prep)
        out, st, payload = eng.finalize(prep, stats=st)
        if self.async_maintenance:
            if self._worker is None:      # closed: nobody drains the
                raise RuntimeError(       # queue — fail loudly instead
                    "MemoServer is closed")   # of blocking on put()
            self._maint_q.put(payload)
        else:
            eng.apply_maintenance(payload, stats=self.stats)
        self.stats.merge(st)
        self.n_batches += 1
        done = self._now()
        out_np = np.asarray(out)
        comps = []
        for i, r in enumerate(reqs):
            logits = (out_np[i] if out_np.ndim == 2
                      else out_np[i, : r.tokens.size])
            comps.append(Completion(
                rid=r.rid, logits=logits, latency=done - r.arrival,
                length=int(r.tokens.size), bucket=bucket, batch_rows=n))
        return comps

    # ------------------------------------------------------ maintenance
    def _maintenance_loop(self):
        while True:
            item = self._maint_q.get()
            try:
                if item is None:
                    return
                self.engine.apply_maintenance(item, stats=self.stats)
            except BaseException as e:  # noqa: BLE001 — surfaced to caller
                self.maintenance_errors.append(e)
            finally:
                self._maint_q.task_done()

    def drain_maintenance(self):
        """Block until every queued payload has been applied (and its
        snapshot published) — the quiesce point for tests/benchmarks.
        Raises (and clears) the first worker error since the last
        drain."""
        if self._maint_q is not None:
            self._maint_q.join()
        if self.maintenance_errors:
            errs, self.maintenance_errors = self.maintenance_errors, []
            raise errs[0]

    def close(self):
        if self._worker is not None:
            self._maint_q.put(None)
            self._worker.join(timeout=30)
            self._worker = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        try:
            self.drain_maintenance()
        finally:
            self.close()

    # ---------------------------------------------------------- warm-up
    def warmup(self, batch_sizes: Optional[Sequence[int]] = None):
        """Compile the bounded jit-shape set outside the measured window:
        one dummy batch per (bucket, padded-row-count, capture-variant)
        combination — with ``admit_every > 1`` the fused jit has BOTH a
        capturing and a non-capturing variant per shape, and serving
        alternates between them, so both must be compiled here or a
        mid-trace XLA compile lands in the p99. Maintenance payloads are
        dropped and counters rolled back, so warm-up leaves the store
        untouched."""
        sizes = list(batch_sizes) if batch_sizes is not None else None
        if sizes is None:
            sizes, p = [], self.batch_quantum
            while p < self.max_batch:
                sizes.append(p)
                p *= 2
            sizes.append(self.max_batch)
        eng = self.engine
        serve_counter = eng._serve_batches
        # _capture_now keys off _serve_batches % admit_every: batch
        # parity 0 captures (when admission is on), parity 1 does not
        parities = ([0, 1] if eng.mc.admit and eng.mc.admit_every > 1
                    else [0])
        try:
            for b in self.buckets:
                for rows in sizes:
                    for parity in parities:
                        eng._serve_batches = parity
                        toks = np.zeros((rows, b), np.int32)
                        lens = np.full((rows,), max(1, b // 2), np.int32)
                        batch = {"tokens": jnp.asarray(toks),
                                 "lengths": lens, "n_valid": rows}
                        prep = eng.prepare_batch(batch, sync_store=False)
                        eng.run_layers(prep)
                        eng.finalize(prep, stats=MemoStats())
        finally:
            eng._serve_batches = serve_counter

    # --------------------------------------------------------- open loop
    def run(self, workload: Sequence[Tuple[float, np.ndarray]],
            ) -> List[Completion]:
        """Serve an open-loop trace: ``workload`` is [(arrival_s, tokens)]
        on the runtime clock starting now. Arrivals are injected by
        schedule regardless of server progress (queueing delay is the
        server's problem — that is the open-loop point); returns one
        Completion per request with end-to-end latency."""
        wl = sorted(workload, key=lambda a: a[0])
        base = self._now()
        i, comps = 0, []
        while i < len(wl) or self.queued:
            now = self._now() - base
            while i < len(wl) and wl[i][0] <= now:
                self.submit(wl[i][1], arrival=base + wl[i][0])
                i += 1
            got = self.step(flush=i >= len(wl))
            if got:
                comps.extend(got)
                continue
            if i < len(wl):
                time.sleep(min(max(wl[i][0] - (self._now() - base), 0.0),
                               self.max_delay))
        return comps

"""MemoSession — the one facade over the memoization stack (API v1).

AttMemo's promise is memoization *without* touching the transformer;
the facade extends that to the user's code: one object wraps engine
orchestration (``repro.core.engine``), store lifecycle
(``repro.core.store``) and the serving runtime (``repro.core.runtime``)
so examples, launchers and benchmarks never hand-wire
``MemoEngine → MemoStore → MemoServer`` again::

    from repro.memo import MemoSession, MemoSpec

    sess = MemoSession.build(model, params, spec, batches=calib)
    logits, stats = sess.infer({"tokens": toks})
    with sess.serve(max_batch=16) as server:
        completions = server.run(workload)
    sess.save("memo_store.npz")                  # offline-built database
    warm = MemoSession.load("memo_store.npz", model, params)

``save``/``load`` persist the populated store — codec-part arenas, index
state, ``sim_cal``, per-entry lengths, the trained embedder and the full
spec — and round-trip to bit-identical host-tier lookups, enabling the
warm-start serving the paper's offline-built database assumes: build
once, ship the file, serve anywhere.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from repro.core.capacity import (_fsync_dir, is_format3, read_format3,
                                 write_format3)
from repro.core.embedding import Embedder
from repro.core.engine import LEVELS, MemoEngine, MemoStats
from repro.core.faults import MemoStoreError, fire
from repro.core.runtime import MemoServer
from repro.memo.specs import FLAT_FIELDS, MemoSpec

# format 2 added per-array CRC32 checksums in the meta header (and the
# store's per-codec-part arena checksums ride along in state_dict), so
# ``load`` verifies every byte before deserializing — a truncated,
# bit-flipped or spec-mismatched file fails with an actionable
# ``MemoStoreError`` instead of a numpy internal error (DESIGN.md §2.9).
# Format 3 (DESIGN.md §2.11) keeps the same header + arrays but stores
# them uncompressed and page-aligned, so ``load(..., mmap=True)`` maps
# the arenas copy-on-write instead of materializing them. Both formats
# load; ``save`` writes format 3 unless asked for 2.
SAVE_FORMAT = 3
READ_FORMATS = (2, 3)

# the per-directory session descriptor a capacity tier carries so
# ``MemoSession.load(<capacity dir>)`` can reconstruct the session
# (spec + embedder) straight from the durable tier
SESSION_META = "session.m3"


class MemoSession:
    """A built, servable memoization session.

    Construct via ``MemoSession.build`` (calibrate a fresh store) or
    ``MemoSession.load`` (warm-start from a saved one). The underlying
    ``MemoEngine`` stays reachable as ``session.engine`` for advanced
    use; everything routine goes through the facade."""

    def __init__(self, engine: MemoEngine):
        if engine.store is None:
            raise ValueError("MemoSession wraps a BUILT engine; use "
                             "MemoSession.build(...) or .load(...)")
        self.engine = engine
        self._stats = MemoStats()     # session-cumulative serving stats
        # a capacity tier makes the session self-describing: drop the
        # spec + embedder next to the arenas so the DIRECTORY alone
        # reopens via MemoSession.load (crash recovery has no .npz)
        store = engine.store
        if store.capacity_ok:
            sess_path = os.path.join(store.capacity.root, SESSION_META)
            if not os.path.exists(sess_path):
                try:
                    self._write_session_meta(sess_path)
                except OSError as e:    # noqa: PERF203 — degrade
                    store._capacity_fail(e)

    # ------------------------------------------------------------- views
    @property
    def spec(self) -> MemoSpec:
        return self.engine.mc

    @property
    def store(self):
        return self.engine.store

    @property
    def model(self):
        return self.engine.model

    @property
    def params(self):
        return self.engine.params

    # ------------------------------------------------------------- build
    @classmethod
    def build(cls, model, params, spec: Optional[MemoSpec] = None, *,
              batches: Sequence[dict], key=None, train_pairs: int = 512,
              verbose: bool = False) -> "MemoSession":
        """Calibrate a session: run ``batches`` through the model with
        APM capture, train the Siamese embedder, populate both store
        tiers (paper §5.1 'building the database')."""
        eng = MemoEngine(model, params, spec)
        eng.build(key if key is not None else jax.random.PRNGKey(0),
                  batches, train_pairs=train_pairs, verbose=verbose)
        return cls(eng)

    # ------------------------------------------------------------- serve
    def infer(self, batch: dict, **kwargs):
        """Memoized forward; returns ``(logits, MemoStats)``. Per-call
        stats also accumulate into the session summary (``stats()``)
        unless the caller threads their own ``stats=`` object."""
        out, st = self.engine.infer(batch, **kwargs)
        if kwargs.get("stats") is None:
            self._stats.merge(st)
        return out, st

    def serve(self, **kwargs) -> MemoServer:
        """An open-loop continuous-batching server over this session —
        the raw ``MemoServer`` (no wrapper on the per-batch serve path);
        use as a context manager. Serving stats live on
        ``server.stats``; store-lifecycle effects (admissions,
        evictions, sync bytes) land on the shared store and show up in
        ``session.stats()['store']``."""
        return MemoServer(self.engine, **kwargs)

    def suggest_levels(self, batches) -> Dict[str, float]:
        return self.engine.suggest_levels(batches)

    def autotune(self, batches, level: str = "moderate"
                 ) -> Dict[str, float]:
        """Per-model threshold autotune (paper Table 2 / §5.4): set
        ``spec.runtime.threshold`` to the chosen level's percentile and
        return all levels."""
        if level not in LEVELS:
            raise ValueError(f"level must be one of {sorted(LEVELS)}: "
                             f"{level!r}")
        levels = self.suggest_levels(batches)
        self.spec.runtime.threshold = float(levels[level])
        return levels

    def profile(self, batch, **kwargs):
        """Selective-memoization profiler (paper §5.4) → ``PerfModel``."""
        return self.engine.profile(batch, **kwargs)

    def stats(self) -> Dict[str, object]:
        """One summary dict across serving and store lifecycle."""
        st, store = self._stats, self.store
        ss = store.stats
        return {
            "n_inputs": st.n_inputs,
            "n_layer_attempts": st.n_layer_attempts,
            "n_hits": st.n_hits,
            "hit_rate": st.memo_rate,
            "n_admitted": st.n_admitted,
            "threshold": float(self.spec.runtime.threshold),
            "store": {
                "live_entries": store.live_count,
                "entry_nbytes": store.entry_nbytes,
                "live_mb": store.live_count * store.entry_nbytes / 1e6,
                "codec": store.codec.name,
                "admitted": ss.n_admitted,
                "evicted": ss.n_evicted,
                "delta_syncs": ss.n_delta_syncs,
                "full_syncs": ss.n_full_syncs,
                "sync_mb": ss.bytes_total / 1e6,
            },
        }

    # ------------------------------------------------------- persistence
    def _session_meta(self, arrays: Dict[str, np.ndarray],
                      save_format: int) -> dict:
        eng = self.engine
        return {
            "format": int(save_format),
            "spec": self.spec.to_dict(),
            "embedder": {"pool": eng.embedder.pool,
                         "act": eng.embedder.act},
            "apm_shape": list(self.store.apm_shape),
            # host-index build parameter derived from the CALIBRATION
            # size (an ivf store that admitted entries no longer knows
            # it) — persisted so load reconstructs the identical index
            "n_lists": getattr(self.store.index, "n_lists", None),
            # per-array CRC32 of the exact bytes being written — load's
            # integrity gate (dtype/shape checked separately by numpy)
            "checksums": {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                          for k, v in arrays.items()},
        }

    def _write_session_meta(self, path: str) -> None:
        """Drop the session descriptor (spec + embedder, no store
        arrays) next to the capacity arenas — what makes a bare tier
        directory loadable."""
        arrays = {f"emb_param_{k}": np.asarray(v)
                  for k, v in self.engine.embedder.params.items()}
        write_format3(path, self._session_meta(arrays, 3), arrays)

    def save(self, path: str, *, save_format: int = SAVE_FORMAT) -> None:
        """Persist the populated store to one file: spec, trained
        embedder, codec-part arenas, slot mirrors (embeddings, entry
        lengths, liveness, reuse counters, free-list), ``sim_cal``.
        ``MemoSession.load`` round-trips to bit-identical host-tier
        lookups; the device tier is derived and re-materialized on the
        first post-load sync.

        ``save_format=3`` (default) writes the page-aligned uncompressed
        layout that ``load(..., mmap=True)`` maps zero-copy;
        ``save_format=2`` writes the compressed ``.npz``. Both writes
        are ATOMIC — temp file in the target directory, fsync, then
        ``os.replace`` — so a crash (or the ``session.save_truncate``
        fault) mid-save leaves any existing good file untouched."""
        if save_format not in READ_FORMATS:
            raise ValueError(f"save_format must be one of "
                             f"{list(READ_FORMATS)}: {save_format!r}")
        eng = self.engine
        arrays = {f"emb_param_{k}": np.asarray(v)
                  for k, v in eng.embedder.params.items()}
        for k, v in self.store.state_dict().items():
            arrays[f"store_{k}"] = np.asarray(v)
        meta = self._session_meta(arrays, save_format)
        if save_format == 3:
            write_format3(str(path), meta, arrays, faults=eng.faults,
                          fault_point="session.save_truncate")
            return
        tmp = str(path) + ".tmp"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, meta=json.dumps(meta), **arrays)
            f.flush()
            os.fsync(f.fileno())
        if fire(eng.faults, "session.save_truncate") is not None:
            # crash between the temp write and the rename: the temp is
            # torn, the target (if any) still holds the previous save
            size = os.path.getsize(tmp)
            with open(tmp, "rb+") as f:
                f.truncate(max(1, int(size * 0.6)))
            return
        os.replace(tmp, str(path))
        _fsync_dir(os.path.dirname(os.path.abspath(str(path))))

    @staticmethod
    def _spec_from_meta(path: str, meta: dict,
                        overrides: Optional[Dict[str, object]]) -> MemoSpec:
        try:
            spec = MemoSpec.from_dict(meta["spec"])
            for k, v in (overrides or {}).items():
                if k not in FLAT_FIELDS:
                    raise ValueError(
                        f"unknown override field {k!r}; valid flat "
                        f"fields: {sorted(FLAT_FIELDS)}")
                setattr(spec, k, v)     # flat property → re-validates
        except MemoStoreError:
            raise
        except Exception as e:
            raise MemoStoreError(
                f"invalid memo spec in {path!r}: "
                f"{type(e).__name__}: {e}") from e
        return spec

    @classmethod
    def load(cls, path: str, model, params, *, faults=None,
             mmap: bool = False,
             overrides: Optional[Dict[str, object]] = None
             ) -> "MemoSession":
        """Warm-start a session from ``save`` output — or from a bare
        capacity-tier DIRECTORY (crash recovery: the journaled arenas
        plus the ``session.m3`` descriptor are the save). ``model`` /
        ``params`` must be the network the store was built against (the
        file holds the memo state, not the transformer weights).

        Every failure mode — unreadable/truncated file, bad format
        number, per-array checksum mismatch (bit flips), a spec that
        does not describe the persisted arrays — raises a
        ``MemoStoreError`` naming the problem; numpy/zipfile internals
        never escape.

        ``mmap=True`` (format-3 files only) adopts the codec-part
        arenas as copy-on-write memory maps instead of materializing
        them — open is zero-copy and whole-file verification is
        deferred to the store's per-row checksums
        (``store.verify_integrity()``). ``overrides`` remaps flat spec
        fields (e.g. ``{"capacity_dir": ..., "budget_mb": 1.0}``)
        before the store is constructed. ``faults`` (a
        ``FaultInjector``) overrides the injector the file's spec would
        construct — chaos harnesses arm ``session.load_bitflip`` on it;
        production leaves it None."""
        if os.path.isdir(str(path)):
            return cls._load_capacity_dir(str(path), model, params,
                                          faults=faults,
                                          overrides=overrides)
        if is_format3(str(path)):
            meta, arrays = read_format3(str(path), mmap=mmap,
                                        verify=False)
        else:
            if mmap:
                raise MemoStoreError(
                    f"memo store file {path!r} is not format 3 — "
                    f"mmap=True needs the page-aligned layout; re-save "
                    f"with save_format=3 (see MIGRATION.md)")
            try:
                with np.load(str(path), allow_pickle=False) as data:
                    meta = json.loads(str(data["meta"]))
                    arrays = {k: data[k] for k in data.files
                              if k != "meta"}
            except MemoStoreError:
                raise
            except Exception as e:      # zipfile/zlib/json/KeyError...
                raise MemoStoreError(
                    f"unreadable memo store file {path!r} (truncated or "
                    f"corrupt): {type(e).__name__}: {e}") from e
        if meta.get("format") not in READ_FORMATS:
            raise MemoStoreError(
                f"unsupported memo save format {meta.get('format')!r} "
                f"(this build reads formats {list(READ_FORMATS)})")
        spec = cls._spec_from_meta(path, meta, overrides)
        eng = MemoEngine(model, params, spec)
        if faults is not None:
            eng.faults = faults      # threads into the store via _make_store
        if fire(eng.faults, "session.load_bitflip") is not None:
            # flip one byte of the first store array IN MEMORY — the
            # checksum gate below must refuse it
            for k in sorted(arrays):
                if k.startswith("store_part_"):
                    arr = arrays[k].copy()
                    arr.view(np.uint8).reshape(-1)[0] ^= 0xFF
                    arrays[k] = arr
                    break
        cls._verify_arrays(path, meta, arrays, check_crc=not mmap)
        emb_meta = meta["embedder"]
        eng.embedder = Embedder(
            {k[len("emb_param_"):]: jax.numpy.asarray(v)
             for k, v in arrays.items() if k.startswith("emb_param_")},
            int(emb_meta["pool"]), str(emb_meta["act"]))
        state = {k[len("store_"):]: v for k, v in arrays.items()
                 if k.startswith("store_")}
        n = int(state["n"])
        eng.store = eng._make_store(meta["apm_shape"],
                                    capacity=max(1, n),
                                    n_lists=meta.get("n_lists"))
        try:
            eng.store.load_state_dict(state, adopt_arenas=mmap)
        except MemoStoreError:
            raise
        except Exception as e:
            raise MemoStoreError(
                f"memo store state in {path!r} does not fit the spec it "
                f"declares: {type(e).__name__}: {e}") from e
        # mirror build(): materialize the serving tier only when the fast
        # path can reach it (mode switches re-sync lazily)
        if spec.runtime.store == "device" and spec.runtime.mode in (
                "bucket", "kernel"):
            eng.store.sync()
        return cls(eng)

    @classmethod
    def _load_capacity_dir(cls, path: str, model, params, *, faults=None,
                           overrides=None) -> "MemoSession":
        """Reopen a session from its capacity-tier directory: recover
        the journaled arenas (WAL replay + CRC sweep, see
        ``CapacityTier``), rebuild the session from ``session.m3`` and
        warm the host tier from the hottest disk rows. This is the
        crash-recovery path — a process SIGKILLed at ANY instant
        reopens here with at most the un-journaled tail lost."""
        sess_path = os.path.join(path, SESSION_META)
        if not os.path.exists(sess_path):
            raise MemoStoreError(
                f"capacity dir {path!r} has no {SESSION_META} — not a "
                f"memo capacity tier (or the session descriptor was "
                f"never written)")
        meta, arrays = read_format3(sess_path)
        spec = cls._spec_from_meta(sess_path, meta, overrides)
        spec.capacity.dir = path        # the directory may have moved
        eng = MemoEngine(model, params, spec)
        if faults is not None:
            eng.faults = faults
        emb_meta = meta["embedder"]
        eng.embedder = Embedder(
            {k[len("emb_param_"):]: jax.numpy.asarray(v)
             for k, v in arrays.items() if k.startswith("emb_param_")},
            int(emb_meta["pool"]), str(emb_meta["act"]))
        eng.store = eng._make_store(meta["apm_shape"], capacity=1,
                                    n_lists=meta.get("n_lists"))
        if not eng.store.capacity_ok:
            raise MemoStoreError(
                f"capacity dir {path!r} failed recovery: "
                f"{eng.store.capacity_error}")
        eng.store.adopt_capacity()
        if spec.runtime.store == "device" and spec.runtime.mode in (
                "bucket", "kernel"):
            eng.store.sync()
        return cls(eng)

    @staticmethod
    def _verify_arrays(path: str, meta: dict,
                       arrays: Dict[str, np.ndarray], *,
                       check_crc: bool = True) -> None:
        """The load-time integrity + spec-compatibility gate: every
        array's CRC32 must match the checksummed header, the required
        store arrays must exist, and the arrays must actually have the
        shapes the spec/meta describe. All failures are
        ``MemoStoreError`` with the offending keys named.
        ``check_crc=False`` (the mmap path) skips the byte sweep — it
        would fault every page in; per-row arena checksums still guard
        what actually gets served."""
        csums = meta.get("checksums")
        if not isinstance(csums, dict):
            raise MemoStoreError(
                f"memo store file {path!r} has no checksummed header "
                f"(formats {list(READ_FORMATS)} require one)")
        missing = sorted(set(csums) - set(arrays))
        if missing:
            raise MemoStoreError(
                f"memo store file {path!r} is missing arrays the header "
                f"promises: {missing}")
        bad = [] if not check_crc else [
            k for k in sorted(arrays)
            if zlib.crc32(np.ascontiguousarray(arrays[k]).tobytes())
            != csums.get(k)]
        if bad:
            raise MemoStoreError(
                f"checksum mismatch in memo store file {path!r} for "
                f"{bad} — the file is corrupt (bit flips or a partial "
                f"write); rebuild or restore from a good copy")
        for req in ("store_n", "store_embs", "store_lens", "store_live"):
            if req not in arrays:
                raise MemoStoreError(
                    f"memo store file {path!r} is missing required "
                    f"array {req!r}")
        # spec compatibility: the embedding mirror must be as wide as
        # the spec's embed dim, and every persisted arena row count must
        # agree with the entry count — failing here is an actionable
        # "file does not match spec", not a shape error deep in numpy
        spec_d = meta.get("spec") or {}
        embed_dim = int((spec_d.get("embed") or {}).get("dim", -1))
        embs = arrays["store_embs"]
        if embs.ndim != 2 or (embed_dim > 0
                              and embs.shape[1] != embed_dim):
            raise MemoStoreError(
                f"memo store file {path!r} embedding mirror has shape "
                f"{embs.shape} but the spec declares embed dim "
                f"{embed_dim} — the file was saved under a different "
                f"spec")
        n = int(arrays["store_n"])
        rows = {k: arrays[k].shape[0] for k in arrays
                if k.startswith("store_part_")}
        wrong = sorted(k for k, r in rows.items() if r != n)
        if wrong or embs.shape[0] != n:
            raise MemoStoreError(
                f"memo store file {path!r} declares {n} entries but "
                f"arrays {wrong or ['store_embs']} disagree — the file "
                f"is inconsistent")

"""Public re-export of the extension registries (``repro.memo`` API v1).

The implementation lives in ``repro.core.registry`` (a leaf module the
core can import without cycling through the session layer); this module
is the documented import location::

    from repro.memo.registry import register_codec, CODECS

See ``repro.core.registry`` for the factory contracts.
"""
from repro.core.registry import (  # noqa: F401
    CODECS, DEVICE_INDEXES, EVICTIONS, HOST_INDEXES, Registry,
    register_codec, register_eviction, register_index)

"""``repro.memo`` — the public memoization API (v1).

Three pillars (ISSUE 5 / DESIGN.md §2.8):

* **Composable specs** — ``MemoSpec`` composes ``EmbedSpec``,
  ``IndexSpec``, ``CodecSpec``, ``AdmissionPolicy``, ``EvictionPolicy``,
  ``RuntimeSpec`` and the (default-inert) ``CapacitySpec``, each
  validated at construction. The legacy flat
  ``MemoConfig(**kwargs)`` still works (one ``DeprecationWarning``);
  ``MemoSpec.flat(**kwargs)`` is the warning-free bridge.
* **Extension registries** — ``register_codec`` / ``register_index`` /
  ``register_eviction`` add storage codecs, index layouts and eviction
  policies by string key; unknown keys fail fast listing the choices.
* **MemoSession** — build → infer → serve → stats → save/load, one
  facade; ``save``/``load`` persist the populated store for warm-start
  serving.

Typical use::

    from repro.memo import MemoSession, MemoSpec, RuntimeSpec

    spec = MemoSpec(runtime=RuntimeSpec(mode="bucket", threshold=0.9))
    sess = MemoSession.build(model, params, spec, batches=calib)
    logits, stats = sess.infer({"tokens": toks})
    sess.save("memo_store.npz")

Attributes resolve lazily (PEP 562) so ``repro.memo.specs`` and the
registries are importable by core modules without a circular import
through the session layer.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # facade
    "MemoSession": ("repro.memo.session", "MemoSession"),
    # specs
    "MemoSpec": ("repro.memo.specs", "MemoSpec"),
    "MemoConfig": ("repro.memo.specs", "MemoConfig"),
    "EmbedSpec": ("repro.memo.specs", "EmbedSpec"),
    "IndexSpec": ("repro.memo.specs", "IndexSpec"),
    "CodecSpec": ("repro.memo.specs", "CodecSpec"),
    "AdmissionPolicy": ("repro.memo.specs", "AdmissionPolicy"),
    "EvictionPolicy": ("repro.memo.specs", "EvictionPolicy"),
    "RuntimeSpec": ("repro.memo.specs", "RuntimeSpec"),
    "CapacitySpec": ("repro.memo.specs", "CapacitySpec"),
    "ShardSpec": ("repro.memo.specs", "ShardSpec"),
    "PrefillSpec": ("repro.memo.specs", "PrefillSpec"),
    "FLAT_FIELDS": ("repro.memo.specs", "FLAT_FIELDS"),
    # registries
    "register_codec": ("repro.core.registry", "register_codec"),
    "register_index": ("repro.core.registry", "register_index"),
    "register_eviction": ("repro.core.registry", "register_eviction"),
    # serving-surface re-exports (returned/consumed by the facade)
    "MemoServer": ("repro.core.runtime", "MemoServer"),
    "MemoStats": ("repro.core.engine", "MemoStats"),
    "LEVELS": ("repro.core.engine", "LEVELS"),
    # failure model (DESIGN.md §2.9)
    "MemoStoreError": ("repro.core.faults", "MemoStoreError"),
    "FaultInjector": ("repro.core.faults", "FaultInjector"),
    "FAULT_POINTS": ("repro.core.faults", "FAULT_POINTS"),
    "CHAOS_PRESETS": ("repro.core.faults", "CHAOS_PRESETS"),
    "Health": ("repro.core.runtime", "Health"),
    "MemoMaintenanceError": ("repro.core.runtime", "MemoMaintenanceError"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.memo' has no attribute {name!r}") from None
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value         # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

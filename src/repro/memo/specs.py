"""Composable memoization specs — the ``repro.memo`` config surface (v1).

The old flat ``MemoConfig`` grew to 25 fields mixing embedding, index,
codec, admission and runtime knobs. The v1 surface splits it into six
small policy objects, each validated at construction:

* ``EmbedSpec``       — the Siamese embedding model (paper §5.2)
* ``IndexSpec``       — host (calibration/lookup) + device (serving)
                        index layouts, resolved via the index registry
* ``CodecSpec``       — APM storage codec for both tiers (DESIGN.md §2.6)
* ``AdmissionPolicy`` — online miss capture under a byte budget (§2.5)
* ``EvictionPolicy``  — which entries go when the budget binds
* ``RuntimeSpec``     — serving execution (threshold, mode, fast path)
* ``CapacitySpec``    — the big-memory disk tier (DESIGN.md §2.11);
                        default-inert (``dir=None``), so five-component
                        call sites are unaffected

``MemoSpec`` composes the six (plus the inert-by-default capacity
component). For compatibility it also exposes the old
flat field names as read/write properties (``spec.threshold`` ↔
``spec.runtime.threshold``), so existing engine code and call sites that
tweak a knob keep working; writes through the flat view re-validate the
owning component. ``MemoSpec.flat(**kwargs)`` is the sanctioned
flat-kwargs convenience constructor; the legacy ``MemoConfig(**kwargs)``
class does the same mapping but emits a ``DeprecationWarning`` (once per
process). See MIGRATION.md for the field-by-field mapping.

String-keyed fields (codec name, index kinds, eviction kind) validate
against the extension registries (``repro.core.registry``), so an
unknown key fails at spec construction with the registered choices
listed — and a codec/index/eviction registered by user code is
immediately a valid spec value.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Dict, Optional, Tuple


def _registries():
    """Deferred import: ``repro.core.registry`` is imported at VALIDATION
    time, not module-import time — importing any ``repro.core`` submodule
    runs the ``repro.core`` package init, which imports the engine, which
    imports this module (the compat re-export). By first construction of
    a spec the core package is always fully initialized."""
    from repro.core import registry
    return registry

__all__ = [
    "EmbedSpec", "IndexSpec", "CodecSpec", "AdmissionPolicy",
    "EvictionPolicy", "RuntimeSpec", "CapacitySpec", "ShardSpec",
    "PrefillSpec", "MemoSpec", "MemoConfig", "FLAT_FIELDS",
]


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclass
class EmbedSpec:
    """The hidden-state embedding model (paper §5.2)."""
    dim: int = 128            # embedding width (the index vector size)
    pool: int = 8             # token-pool stride before the MLP
    act: str = "linear"       # linear | tanh
    steps: int = 300          # Siamese training steps at build()

    def __post_init__(self):
        _require(int(self.dim) >= 1, f"embed dim must be >= 1: {self.dim}")
        _require(int(self.pool) >= 1,
                 f"embed pool must be >= 1: {self.pool}")
        _require(self.act in ("linear", "tanh"),
                 f"embed act must be 'linear' or 'tanh': {self.act!r}")
        _require(int(self.steps) >= 0,
                 f"embed steps must be >= 0: {self.steps}")


@dataclass
class IndexSpec:
    """Index layouts for both tiers, resolved via the index registries."""
    host: str = "exact"       # calibration/lookup tier (registry: host)
    device: str = "auto"      # serving tier: auto | flat | clustered | …
    cluster_crossover: int = 4096   # auto: clustered when n >= this
    nprobe: int = 16
    n_clusters: Optional[int] = None   # clustered: None = sqrt(N)

    def __post_init__(self):
        reg = _registries()
        if self.host not in reg.HOST_INDEXES:
            raise ValueError(
                f"unknown host index {self.host!r}; registered: "
                f"{list(reg.HOST_INDEXES.choices())}")
        if self.device != "auto" and self.device not in reg.DEVICE_INDEXES:
            raise ValueError(
                f"unknown device index {self.device!r}; registered: "
                f"{['auto'] + list(reg.DEVICE_INDEXES.choices())}")
        _require(int(self.cluster_crossover) >= 1,
                 f"cluster_crossover must be >= 1: {self.cluster_crossover}")
        _require(int(self.nprobe) >= 1,
                 f"nprobe must be >= 1: {self.nprobe}")
        _require(self.n_clusters is None or int(self.n_clusters) >= 1,
                 f"n_clusters must be None or >= 1: {self.n_clusters}")


@dataclass
class CodecSpec:
    """APM storage format for BOTH memo tiers (DESIGN.md §2.6)."""
    name: str = "int8"        # registry: codec (f16 | int8 | lowrank | …)
    rank: Optional[int] = None     # lowrank rank (None = L//8)

    def __post_init__(self):
        reg = _registries()
        if self.name not in reg.CODECS:
            raise ValueError(
                f"unknown APM codec {self.name!r}; registered: "
                f"{list(reg.CODECS.choices())}")
        _require(self.rank is None or int(self.rank) >= 1,
                 f"codec rank must be None or >= 1: {self.rank}")


@dataclass
class AdmissionPolicy:
    """Online miss capture → admission under a byte budget (§2.5)."""
    enabled: bool = False
    budget_mb: Optional[float] = None   # store byte budget (None = ∞)
    every: int = 1                      # capture every Nth served batch
    recal_every: Optional[int] = None   # refit sim_cal every N flushes

    def __post_init__(self):
        _require(int(self.every) >= 1,
                 f"admission every must be >= 1: {self.every}")
        _require(self.budget_mb is None or float(self.budget_mb) > 0,
                 f"budget_mb must be None or > 0: {self.budget_mb}")
        _require(self.recal_every is None or int(self.recal_every) >= 1,
                 f"recal_every must be None or >= 1: {self.recal_every}")


@dataclass
class EvictionPolicy:
    """Which entries go when the budget binds (registry: eviction)."""
    kind: str = "clock"       # clock | coldest | …

    def __post_init__(self):
        reg = _registries()
        if self.kind not in reg.EVICTIONS:
            raise ValueError(
                f"unknown eviction policy {self.kind!r}; registered: "
                f"{list(reg.EVICTIONS.choices())}")


@dataclass
class RuntimeSpec:
    """Serving execution: threshold, mode, fast path, sync slack."""
    threshold: float = 0.97
    mode: str = "select"            # select | bucket | kernel
    store: str = "device"           # serving store: device | host
    device_fast_path: Optional[bool] = None   # None → auto by mode/store
    device_quanta: int = 1          # fused-path bucket granularity
    bucket_quantum: int = 4         # host-path hit-bucket padding quantum
    max_layers: Optional[int] = None
    interpret: Optional[bool] = None    # None → auto-detect backend
    # kernel-mode implementation: "pallas" (tiled kernel; compiled on
    # TPU/GPU, interpreter on CPU) | "xla" (one-matmul formulation —
    # what CPU serving wants) | None → auto by backend
    kernel_impl: Optional[str] = None
    device_slack: float = 1.0       # device-arena slack for delta sync
    # fault injection (DESIGN.md §2.9): None = production (no injector is
    # ever constructed — zero cost); {} = injector enabled for post-build
    # arm(); {"store.sync_fail": {"p": 0.5}, ...} arms points up front
    faults: Optional[Dict[str, Dict]] = None

    def __post_init__(self):
        _require(math.isfinite(float(self.threshold)),
                 f"threshold must be finite: {self.threshold}")
        _require(self.mode in ("select", "bucket", "kernel"),
                 f"mode must be select|bucket|kernel: {self.mode!r}")
        _require(self.store in ("device", "host"),
                 f"store must be device|host: {self.store!r}")
        _require(int(self.device_quanta) >= 1,
                 f"device_quanta must be >= 1: {self.device_quanta}")
        _require(int(self.bucket_quantum) >= 1,
                 f"bucket_quantum must be >= 1: {self.bucket_quantum}")
        _require(self.max_layers is None or int(self.max_layers) >= 1,
                 f"max_layers must be None or >= 1: {self.max_layers}")
        _require(self.kernel_impl in (None, "pallas", "xla"),
                 f"kernel_impl must be None|pallas|xla: {self.kernel_impl!r}")
        _require(float(self.device_slack) >= 0,
                 f"device_slack must be >= 0: {self.device_slack}")
        if self.faults is not None:
            from repro.core.faults import FAULT_POINTS
            _require(isinstance(self.faults, dict),
                     f"faults must be None or a dict: {self.faults!r}")
            for point in self.faults:
                _require(point in FAULT_POINTS,
                         f"unknown fault point {point!r}; registered: "
                         f"{sorted(FAULT_POINTS)}")


@dataclass
class CapacitySpec:
    """The big-memory capacity tier (DESIGN.md §2.11): an mmap-backed,
    crash-consistent third storage tier under the host arena. ``dir``
    is the opt-in — ``None`` (the default) attaches no disk tier and
    every other field is inert."""
    dir: Optional[str] = None       # tier directory (None = no disk tier)
    budget_mb: Optional[float] = None   # disk byte budget (None = ∞)
    promote: bool = True            # serve misses from disk when similar
    promote_max: int = 64           # promotions per maintenance flush
    checkpoint_every: int = 8       # WAL→manifest every N applied payloads
    stall_s: float = 5.0            # disk-op watchdog → DISK_DEGRADED
    fsync: bool = True              # fsync WAL frames + checkpoints (off:
                                    # survive crashes, not power loss)
    # background re-compaction: when the retired fraction of the arenas
    # exceeds this ratio, the maintenance worker rewrites them dense
    # (returning bytes to the filesystem). None = never compact.
    compact_ratio: Optional[float] = None

    def __post_init__(self):
        _require(self.budget_mb is None or float(self.budget_mb) > 0,
                 f"capacity budget_mb must be None or > 0: {self.budget_mb}")
        _require(int(self.promote_max) >= 1,
                 f"capacity promote_max must be >= 1: {self.promote_max}")
        _require(int(self.checkpoint_every) >= 1,
                 f"capacity checkpoint_every must be >= 1: "
                 f"{self.checkpoint_every}")
        _require(float(self.stall_s) > 0,
                 f"capacity stall_s must be > 0: {self.stall_s}")
        _require(self.compact_ratio is None
                 or 0 < float(self.compact_ratio) <= 1,
                 f"capacity compact_ratio must be None or in (0, 1]: "
                 f"{self.compact_ratio}")


@dataclass
class ShardSpec:
    """The sharded device tier (DESIGN.md §2.12): partition the memo
    store's device arenas + index rows over a mesh axis, routed by
    nearest centroid. ``shards=0`` (the default) keeps the single-host
    store and every other field inert; ``shards=N`` requests an N-way
    1-D mesh over the local devices (clamped to ``jax.device_count()``).
    """
    shards: int = 0                 # 0 = single-host store (no mesh)
    axis: str = "store"             # mesh axis name for the store
    hot: int = 32                   # replicated hot-set size (rows)
    route_nprobe: Optional[int] = None  # centroids probed per query
    #                                     (None = IndexSpec.nprobe)
    # drift repair between full syncs: when a delta sync has spilled
    # this many rows off their routed shard since the last centroid
    # (re)fit, the maintenance worker refits the routing centroids from
    # the current embedding table (rows do NOT move — ownership follows
    # where they actually live). 0 = wait for the next full sync.
    refresh_spills: int = 0

    def __post_init__(self):
        _require(int(self.shards) >= 0,
                 f"shards must be >= 0: {self.shards}")
        _require(bool(self.axis), "shard axis must be a non-empty name")
        _require(int(self.hot) >= 0,
                 f"shard hot-set size must be >= 0: {self.hot}")
        _require(self.route_nprobe is None or int(self.route_nprobe) >= 1,
                 f"route_nprobe must be None or >= 1: {self.route_nprobe}")
        _require(int(self.refresh_spills) >= 0,
                 f"refresh_spills must be >= 0: {self.refresh_spills}")


@dataclass
class PrefillSpec:
    """Memoized causal prefill (AttnCache; DESIGN.md §2.13): extend each
    memo entry from "APM only" to "APM + per-layer K/V", so a prefill
    hit skips the layer's attention AND materializes that layer's decode
    cache from the stored entry. ``enabled=False`` (the default) keeps
    the classic APM-only entry layout and every other field inert."""
    enabled: bool = False
    # decode-cache length handed back by a memoized prefill (None =
    # 2x the prompt length; set it explicitly when you intend to
    # decode further than the prompt's own length)
    cache_len: Optional[int] = None
    # stored-KV wire format: "auto" follows the APM codec (f16 → f16,
    # int8/lowrank → int8 per-row symmetric), or force f16|int8|lowrank
    kv_codec: str = "auto"
    # lowrank KV rank (None = max(4, S//8), mirroring the APM codec)
    kv_rank: Optional[int] = None

    def __post_init__(self):
        _require(self.cache_len is None or int(self.cache_len) >= 1,
                 f"prefill cache_len must be None or >= 1: {self.cache_len}")
        _require(self.kv_codec in ("auto", "f16", "int8", "lowrank"),
                 f"prefill kv_codec must be auto|f16|int8|lowrank: "
                 f"{self.kv_codec!r}")
        _require(self.kv_rank is None or int(self.kv_rank) >= 1,
                 f"prefill kv_rank must be None or >= 1: {self.kv_rank}")


# old flat MemoConfig field → (component, field) — the single source of
# truth for the flat view, the MemoConfig shim and MIGRATION.md
FLAT_FIELDS: Dict[str, Tuple[str, str]] = {
    "threshold": ("runtime", "threshold"),
    "mode": ("runtime", "mode"),
    "store": ("runtime", "store"),
    "device_fast_path": ("runtime", "device_fast_path"),
    "device_quanta": ("runtime", "device_quanta"),
    "bucket_quantum": ("runtime", "bucket_quantum"),
    "max_layers": ("runtime", "max_layers"),
    "interpret": ("runtime", "interpret"),
    "kernel_impl": ("runtime", "kernel_impl"),
    "device_slack": ("runtime", "device_slack"),
    "index_kind": ("index", "host"),
    "device_index": ("index", "device"),
    "cluster_crossover": ("index", "cluster_crossover"),
    "nprobe": ("index", "nprobe"),
    "n_clusters": ("index", "n_clusters"),
    "apm_codec": ("codec", "name"),
    "apm_rank": ("codec", "rank"),
    "embed_dim": ("embed", "dim"),
    "embed_pool": ("embed", "pool"),
    "embed_act": ("embed", "act"),
    "embed_steps": ("embed", "steps"),
    "admit": ("admission", "enabled"),
    "budget_mb": ("admission", "budget_mb"),
    "admit_every": ("admission", "every"),
    "recal_every": ("admission", "recal_every"),
    # new in v1 (no legacy MemoConfig field); named *_kind so the flat
    # property cannot shadow the ``eviction`` component attribute
    "eviction_kind": ("eviction", "kind"),
    # new in the fault-tolerance layer (DESIGN.md §2.9)
    "faults": ("runtime", "faults"),
    # new in the capacity tier (DESIGN.md §2.11)
    "capacity_dir": ("capacity", "dir"),
    "capacity_budget_mb": ("capacity", "budget_mb"),
    "capacity_promote": ("capacity", "promote"),
    "capacity_promote_max": ("capacity", "promote_max"),
    "capacity_checkpoint_every": ("capacity", "checkpoint_every"),
    "capacity_stall_s": ("capacity", "stall_s"),
    "capacity_fsync": ("capacity", "fsync"),
    "capacity_compact_ratio": ("capacity", "compact_ratio"),
    # new in the sharded store (DESIGN.md §2.12)
    "shards": ("shard", "shards"),
    "shard_axis": ("shard", "axis"),
    "shard_hot": ("shard", "hot"),
    "shard_route_nprobe": ("shard", "route_nprobe"),
    "shard_refresh_spills": ("shard", "refresh_spills"),
    # new in prefill memoization (DESIGN.md §2.13)
    "prefill_enabled": ("prefill", "enabled"),
    "prefill_cache_len": ("prefill", "cache_len"),
    "prefill_kv_codec": ("prefill", "kv_codec"),
    "prefill_kv_rank": ("prefill", "kv_rank"),
}


@dataclass(eq=False)
class MemoSpec:
    """The composed memoization spec: six policy objects, one view.

    Component access (``spec.runtime.mode``) is the canonical API; the
    old flat names remain available as properties (``spec.mode``) with
    write-through + re-validation, so incremental call sites (threshold
    autotuning, A/B mode flips) stay one-liners."""
    embed: EmbedSpec = field(default_factory=EmbedSpec)
    index: IndexSpec = field(default_factory=IndexSpec)
    codec: CodecSpec = field(default_factory=CodecSpec)
    admission: AdmissionPolicy = field(default_factory=AdmissionPolicy)
    eviction: EvictionPolicy = field(default_factory=EvictionPolicy)
    runtime: RuntimeSpec = field(default_factory=RuntimeSpec)
    capacity: CapacitySpec = field(default_factory=CapacitySpec)
    shard: ShardSpec = field(default_factory=ShardSpec)
    prefill: PrefillSpec = field(default_factory=PrefillSpec)

    _COMPONENTS = ("embed", "index", "codec", "admission", "eviction",
                   "runtime", "capacity", "shard", "prefill")
    _COMPONENT_TYPES = {"embed": EmbedSpec, "index": IndexSpec,
                        "codec": CodecSpec, "admission": AdmissionPolicy,
                        "eviction": EvictionPolicy, "runtime": RuntimeSpec,
                        "capacity": CapacitySpec, "shard": ShardSpec,
                        "prefill": PrefillSpec}

    def __post_init__(self):
        # fail-fast on the likeliest migration mistake: passing a string
        # (or any non-spec) where a component belongs —
        # MemoSpec(codec="int8") would otherwise construct silently and
        # crash much later as `'str' object has no attribute 'name'`
        for c, t in self._COMPONENT_TYPES.items():
            v = getattr(self, c)
            if not isinstance(v, t):
                flat = [n for n, (comp, _) in FLAT_FIELDS.items()
                        if comp == c]
                raise TypeError(
                    f"MemoSpec.{c} must be a {t.__name__}, got "
                    f"{type(v).__name__}: {v!r} — construct the "
                    f"component (e.g. {t.__name__}(...)) or use the "
                    f"flat field names {flat} via MemoSpec.flat()")

    def __eq__(self, other) -> bool:
        # component-wise, class-agnostic: a MemoConfig shim instance
        # equals the MemoSpec it maps to (the compat contract)
        if not isinstance(other, MemoSpec):
            return NotImplemented
        return all(getattr(self, c) == getattr(other, c)
                   for c in self._COMPONENTS)

    __hash__ = None     # mutable

    # ------------------------------------------------- flat construction
    @classmethod
    def flat(cls, **kwargs) -> "MemoSpec":
        """Build a composed spec from old flat ``MemoConfig`` field names
        (``MemoSpec.flat(threshold=0.9, mode="bucket")``). The sanctioned
        kwargs bridge — no deprecation warning; unknown names raise."""
        per_comp: Dict[str, Dict] = {c: {} for c in cls._COMPONENTS}
        for name, value in kwargs.items():
            try:
                comp, attr = FLAT_FIELDS[name]
            except KeyError:
                raise TypeError(
                    f"unknown memo config field {name!r}; valid flat "
                    f"fields: {sorted(FLAT_FIELDS)}") from None
            per_comp[comp][attr] = value
        return cls(**{c: cls._COMPONENT_TYPES[c](**kw)
                      for c, kw in per_comp.items()})

    def to_flat(self) -> Dict[str, object]:
        """The spec as a flat old-name dict (MIGRATION.md helper)."""
        return {name: getattr(getattr(self, comp), attr)
                for name, (comp, attr) in FLAT_FIELDS.items()}

    def copy(self) -> "MemoSpec":
        """Deep-enough copy: fresh component instances, shared nothing."""
        return MemoSpec(**{c: replace(getattr(self, c))
                           for c in self._COMPONENTS})

    # ------------------------------------------------------ serialization
    def to_dict(self) -> Dict[str, Dict]:
        return {c: asdict(getattr(self, c)) for c in self._COMPONENTS}

    @classmethod
    def from_dict(cls, d: Dict[str, Dict]) -> "MemoSpec":
        out = {}
        for c in cls._COMPONENTS:
            comp_cls = cls._COMPONENT_TYPES[c]
            known = {f.name for f in fields(comp_cls)}
            kw = {k: v for k, v in (d.get(c) or {}).items() if k in known}
            out[c] = comp_cls(**kw)
        return cls(**out)


def _flat_property(comp: str, attr: str) -> property:
    def getter(self):
        return getattr(getattr(self, comp), attr)

    def setter(self, value):
        component = getattr(self, comp)
        old = getattr(component, attr)
        setattr(component, attr, value)
        try:
            component.__post_init__()     # writes re-validate
        except Exception:
            setattr(component, attr, old)    # reject atomically
            raise
    return property(getter, setter)


for _name, (_comp, _attr) in FLAT_FIELDS.items():
    setattr(MemoSpec, _name, _flat_property(_comp, _attr))
del _name, _comp, _attr


_flat_config_warned = False


def _reset_flat_config_warning() -> None:
    """Test hook: re-arm the once-per-process deprecation warning."""
    global _flat_config_warned
    _flat_config_warned = False


class MemoConfig(MemoSpec):
    """Deprecated flat-kwargs shim: ``MemoConfig(threshold=0.9, ...)``
    maps the old 25-field surface onto the composed ``MemoSpec`` (the
    result compares equal to ``MemoSpec.flat(**same_kwargs)``) and emits
    a ``DeprecationWarning`` once per process. New code: compose specs,
    or use ``MemoSpec.flat`` for the kwargs convenience."""

    def __init__(self, **kwargs):
        # component-kwargs form: how dataclasses.replace() and the
        # inherited flat()/from_dict() classmethods construct — pass
        # straight through (no warning; the caller already has a spec)
        if kwargs and all(k in self._COMPONENTS for k in kwargs):
            super().__init__(**kwargs)
            return
        global _flat_config_warned
        if not _flat_config_warned:
            _flat_config_warned = True
            warnings.warn(
                "MemoConfig(flat kwargs) is deprecated: compose "
                "repro.memo specs (EmbedSpec/IndexSpec/CodecSpec/"
                "AdmissionPolicy/EvictionPolicy/RuntimeSpec) or use "
                "MemoSpec.flat(**kwargs); see MIGRATION.md",
                DeprecationWarning, stacklevel=2)
        spec = MemoSpec.flat(**kwargs)
        super().__init__(**{c: getattr(spec, c)
                            for c in self._COMPONENTS})

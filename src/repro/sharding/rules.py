"""Logical-axis → mesh-axis sharding rules.

Params carry logical names (see each module's ``*_specs``); a rules dict
maps them to mesh axes. Defaults implement TP over ``model`` (ff, heads,
vocab), expert-parallel over ``data``, FSDP over ``data`` for ≥8B params,
and pure DP over ``pod``. Per-arch overrides and the hillclimb variants
live here so a sharding experiment is a one-dict change.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP_THRESHOLD = 8e9


def make_rules(cfg, mesh: Mesh, *, fsdp: Optional[bool] = None,
               overrides: Optional[Dict] = None) -> Dict[str, object]:
    model_size = mesh.shape.get("model", 1)
    if fsdp is None:
        fsdp = cfg.param_count() >= FSDP_THRESHOLD
    rules: Dict[str, object] = {
        "layers": None,
        "vocab": "model",
        "embed": "data" if fsdp else None,
        "heads": "model",
        "kv_heads": ("model" if cfg.n_kv_heads % model_size == 0 else None),
        "head_dim": None,
        "q_lora": None,
        "kv_lora": None,
        "ff": "model",
        "experts": "data",
        "router": None,
        "lora": None,
        "proj5": None,
        "heads_embed": "model",      # rwkv square projections
        "rec": "model",
        "rec_in": None,
        "conv": None,
        "frames": None,
        "seq": None,
    }
    if cfg.n_heads % model_size != 0:
        # uneven head sharding pads in GSPMD; for small head counts the
        # waste exceeds the win — fall back to replicated heads (the ff
        # dim still gives the model axis plenty to do).
        if cfg.n_heads < 2 * model_size:
            rules["heads"] = None
    if overrides:
        rules.update(overrides)
    return rules


def _axis_size(mesh: Mesh, ax) -> int:
    if isinstance(ax, (list, tuple)):
        n = 1
        for a in ax:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(ax, 1)


def _spec_for(names: Tuple, rules: Dict[str, object], mesh: Mesh,
              shape: Tuple[int, ...] = None) -> P:
    used = set()
    axes = []
    for i, nm in enumerate(names):
        ax = rules.get(nm) if nm is not None else None
        # pjit input shardings require exact divisibility (no padding for
        # arguments) — drop the axis when the dim does not divide
        if ax is not None and shape is not None:
            if shape[i] % _axis_size(mesh, ax) != 0:
                ax = None
        # a mesh axis may appear at most once per spec
        key = tuple(ax) if isinstance(ax, (list, tuple)) else (ax,)
        if ax is not None and not any(k in used for k in key):
            axes.append(ax)
            used.update(key)
        else:
            axes.append(None)
    while axes and axes[-1] is None:
        axes.pop()
    return P(*axes)


def logical_to_shardings(specs_tree, rules: Dict[str, object], mesh: Mesh,
                         abs_tree=None):
    """Map a tree of logical-name tuples to NamedShardings. With
    ``abs_tree`` (matching ShapeDtypeStructs) the specs are legalized
    against actual dims."""
    is_tuple = lambda t: isinstance(t, tuple)
    if abs_tree is None:
        return jax.tree.map(
            lambda names: NamedSharding(mesh, _spec_for(names, rules, mesh)),
            specs_tree, is_leaf=is_tuple)
    return jax.tree.map(
        lambda names, ab: NamedSharding(
            mesh, _spec_for(names, rules, mesh, tuple(ab.shape))),
        specs_tree, abs_tree, is_leaf=is_tuple)


def batch_shardings(batch_tree, mesh: Mesh, dp_axes=("data",)):
    """Shard every batch leaf's leading dim over dp (replicate if B < dp)."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape.get(a, 1)

    def one(x):
        b = x.shape[0] if getattr(x, "ndim", 0) > 0 else 0
        if b and b % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, batch_tree)


# --- memo-store rules (DESIGN.md §2.12) ---------------------------------
# The sharded memo tier partitions ROWS (positions) of every device-
# resident leaf — embedding table, slot map, codec-part arenas — over one
# mesh axis; routing state (centroids, owners) and the hot set replicate.
# Expressed as logical rules so they go through the same `_spec_for`
# legalization as model params (an indivisible row count falls back to
# replicated instead of failing pjit).

def memo_store_rules(axis: str = "store") -> Dict[str, object]:
    """Logical-name → mesh-axis rules for the sharded memo store."""
    return {
        "memo_rows": axis,        # table/arena row (position) dim
        "memo_part": None,        # trailing per-entry dims
        "memo_repl": None,        # centroids / owners / hot set
    }


def memo_row_spec(mesh: Mesh, ndim: int, *, axis: str = "store",
                  shape: Optional[Tuple[int, ...]] = None) -> P:
    """PartitionSpec for one row-sharded memo leaf of rank ``ndim``:
    dim 0 over ``axis`` (legalized against ``shape`` when given),
    trailing dims replicated."""
    names = ("memo_rows",) + ("memo_part",) * (ndim - 1)
    return _spec_for(names, memo_store_rules(axis), mesh, shape)


def memo_store_shardings(mesh: Mesh, abs_tree, *, axis: str = "store"):
    """Row-sharded NamedShardings for a pytree of memo-store leaves
    (arrays or ShapeDtypeStructs): the leading dim partitions over
    ``axis``, everything else replicates. Leaves whose row count does
    not divide the axis size legalize to replicated."""
    def one(ab):
        shape = tuple(ab.shape)
        ndim = max(1, len(shape))
        return NamedSharding(mesh, memo_row_spec(mesh, ndim, axis=axis,
                                                 shape=shape))
    return jax.tree.map(one, abs_tree)


def cache_shardings(cache_tree, mesh: Mesh, dp_axes=("data",),
                    seq_axis="model"):
    """Decode-cache shardings: batch over dp when divisible, the long axis
    (cache sequence / rwkv heads) over ``model``; for B==1 long-context the
    sequence spreads over (data, model)."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape.get(a, 1)
    model_size = mesh.shape.get(seq_axis, 1)

    def one(x):
        if x.ndim < 2:
            return NamedSharding(mesh, P())
        B, S = x.shape[0], x.shape[1]
        b_ax = dp if (B % dp_size == 0 and B >= dp_size) else None
        if b_ax is None and x.ndim >= 2:
            # B=1 long-context: shard the big axis over everything
            total = dp_axes + (seq_axis,)
            tsz = dp_size * model_size
            if S % tsz == 0:
                return NamedSharding(
                    mesh, P(None, total, *([None] * (x.ndim - 2))))
            if S % model_size == 0:
                return NamedSharding(
                    mesh, P(None, seq_axis, *([None] * (x.ndim - 2))))
            return NamedSharding(mesh, P())
        s_ax = seq_axis if S % model_size == 0 and S >= model_size else None
        return NamedSharding(mesh, P(b_ax, s_ax, *([None] * (x.ndim - 2))))
    return jax.tree.map(one, cache_tree)

from repro.sharding.rules import (  # noqa: F401
    make_rules, logical_to_shardings, batch_shardings, cache_shardings,
)

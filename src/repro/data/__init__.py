from repro.data.synthetic import TemplateCorpus, lm_batches  # noqa: F401

"""Synthetic template-grammar corpus (DESIGN.md §8, data note).

GLUE/SST-2 and WikiText are unavailable offline; this generator reproduces
the *property AttMemo exploits*: inputs sharing clause structure ("I like
apple." / "I like banana.") produce similar attention probability matrices.
Each sample instantiates a template — a fixed token skeleton with variable
slots — so cross-input APM similarity is controlled by ``slot_fraction``
(the knob the paper's natural corpora fix implicitly; we can sweep it).

Tasks:
* classification — label = template family (the accuracy experiments);
* language modelling — batched next-token streams for the trainer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class TemplateCorpus:
    vocab: int
    seq_len: int
    n_templates: int = 8
    slot_fraction: float = 0.25      # fraction of positions that vary
    n_classes: int = 4
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # reserve the low vocab range for skeleton tokens, high for slots
        skel_hi = max(2, int(self.vocab * 0.6))
        self._skeletons = rng.integers(
            1, skel_hi, (self.n_templates, self.seq_len))
        n_slots = max(1, int(self.seq_len * self.slot_fraction))
        self._slot_pos = np.stack([
            rng.choice(self.seq_len, n_slots, replace=False)
            for _ in range(self.n_templates)])
        self._slot_lo = skel_hi
        self._rng = rng

    def sample(self, n: int, rng=None) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (n, seq_len) int32, labels (n,) int32)."""
        rng = rng or self._rng
        t_ids = rng.integers(0, self.n_templates, n)
        toks = self._skeletons[t_ids].copy()
        fills = rng.integers(self._slot_lo, self.vocab,
                             (n, self._slot_pos.shape[1]))
        rows = np.arange(n)[:, None]
        toks[rows, self._slot_pos[t_ids]] = fills
        labels = (t_ids % self.n_classes).astype(np.int32)
        return toks.astype(np.int32), labels

    def batches(self, n_batches: int, batch_size: int,
                rng=None) -> Iterator[dict]:
        rng = rng or self._rng
        for _ in range(n_batches):
            toks, labels = self.sample(batch_size, rng)
            yield {"tokens": toks, "labels": labels}


def lm_batches(vocab: int, seq_len: int, batch_size: int, n_batches: int,
               *, seed: int = 0, corpus: TemplateCorpus = None
               ) -> Iterator[dict]:
    """Next-token LM batches. With a TemplateCorpus the stream is learnable
    (skeletons are deterministic given the prefix); otherwise a Zipfian
    stream with a k-order Markov backbone is used so perplexity can drop."""
    rng = np.random.default_rng(seed)
    if corpus is not None:
        for _ in range(n_batches):
            toks, _ = corpus.sample(batch_size, rng)
            yield {"tokens": toks}
        return
    # Markov backbone: token_t = f(token_{t-1}) with noise
    table = rng.integers(0, vocab, vocab)
    for _ in range(n_batches):
        toks = np.zeros((batch_size, seq_len), np.int64)
        toks[:, 0] = rng.integers(0, vocab, batch_size)
        for t in range(1, seq_len):
            follow = table[toks[:, t - 1]]
            noise = rng.integers(0, vocab, batch_size)
            use_noise = rng.random(batch_size) < 0.15
            toks[:, t] = np.where(use_noise, noise, follow)
        yield {"tokens": toks.astype(np.int32)}

"""RG-LRU recurrent block (Griffin / RecurrentGemma).

[arXiv:2402.19427]. Gated linear recurrence with input-dependent gates:
    r_t = σ(W_a y_t + b_a);  i_t = σ(W_x y_t + b_x)
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ y_t)
preceded by a width-4 causal temporal conv and wrapped in a GeGLU-style
output gate. Constant-size state → natively sub-quadratic (long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def rglru_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    dr = d                                # recurrent width = d_model
    ks = jax.random.split(key, 6)
    lam = jnp.linspace(0.9, 0.999, dr)    # init decays spread in (0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(lam) / cfg.rglru_c))  # inv softplus
    return {
        "w_in": dense_init(ks[0], (d, dr), dtype=dtype),
        "w_gate": dense_init(ks[1], (d, dr), dtype=dtype),
        "conv_w": dense_init(ks[2], (cfg.conv_width, dr),
                             scale=cfg.conv_width ** -0.5, dtype=dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": dense_init(ks[3], (dr, dr), dtype=dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_x": dense_init(ks[4], (dr, dr), dtype=dtype),
        "b_x": jnp.zeros((dr,), dtype),
        "lam": lam.astype(dtype),
        "w_out": dense_init(ks[5], (dr, d), dtype=dtype),
    }


def rglru_specs(cfg):
    return {"w_in": ("embed", "rec"), "w_gate": ("embed", "rec"),
            "conv_w": ("conv", "rec"), "conv_b": ("rec",),
            "w_a": ("rec", "rec_in"), "b_a": ("rec",),
            "w_x": ("rec", "rec_in"), "b_x": ("rec",),
            "lam": ("rec",), "w_out": ("rec", "embed")}


def _conv(params, y, cfg, conv_state=None):
    """Causal depthwise temporal conv. y: (B,S,dr)."""
    W = cfg.conv_width
    hist = (jnp.zeros((y.shape[0], W - 1, y.shape[2]), y.dtype)
            if conv_state is None else conv_state)
    ypad = jnp.concatenate([hist, y], axis=1)
    out = sum(ypad[:, i:i + y.shape[1]] * params["conv_w"][i]
              for i in range(W))
    return out + params["conv_b"], ypad[:, -(W - 1):]


def _rglru_scan(params, y, cfg, h0):
    c = cfg.rglru_c
    log_lam = -c * jax.nn.softplus(params["lam"].astype(jnp.float32))
    r = jax.nn.sigmoid((y @ params["w_a"] + params["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((y @ params["w_x"] + params["b_x"]).astype(jnp.float32))
    log_a = log_lam * r                                   # (B,S,dr) fp32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * y.astype(jnp.float32))

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated, 1, 0))
    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(y.dtype), hT.astype(y.dtype)


def rglru_apply(params, x, cfg, state=None):
    """Full-sequence recurrent block. x: (B,S,D) → (y, new_state)."""
    B = x.shape[0]
    gate = jax.nn.gelu(x @ params["w_gate"])
    y = x @ params["w_in"]
    conv_state = None if state is None else state["conv"]
    y, conv_state = _conv(params, y, cfg, conv_state)
    h0 = (jnp.zeros((B, y.shape[-1]), x.dtype) if state is None
          else state["h"])
    h, hT = _rglru_scan(params, y, cfg, h0)
    out = (h * gate) @ params["w_out"]
    return out, {"h": hT, "conv": conv_state}


def rglru_decode(params, x, cfg, state):
    return rglru_apply(params, x, cfg, state)


def rglru_init_state(cfg, batch, dtype=jnp.float32):
    dr = cfg.d_model
    return {"h": jnp.zeros((batch, dr), dtype),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype)}

"""Whisper-style encoder-decoder backbone (conv/mel frontend stubbed).

The encoder consumes precomputed frame embeddings (B, n_frames, d_enc) —
the assignment's one allowed stub. Decoder: causal self-attention +
cross-attention + MLP, pre-LayerNorm, learned absolute positions (no RoPE),
as in Whisper. Encoder self-attention APMs are the AttMemo target.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import (
    dense_init, embed_init, mlp_apply, mlp_init, mlp_specs, norm_apply,
    norm_init, norm_specs,
)


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------

def cross_init(key, d, d_kv, n_heads, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (d, n_heads, dh), scale=d ** -0.5,
                             dtype=dtype),
            "wk": dense_init(ks[1], (d_kv, n_heads, dh), scale=d_kv ** -0.5,
                             dtype=dtype),
            "wv": dense_init(ks[2], (d_kv, n_heads, dh), scale=d_kv ** -0.5,
                             dtype=dtype),
            "wo": dense_init(ks[3], (n_heads, dh, d),
                             scale=(n_heads * dh) ** -0.5, dtype=dtype)}


def cross_specs():
    return {"wq": ("embed", "heads", "head_dim"),
            "wk": ("embed", "heads", "head_dim"),
            "wv": ("embed", "heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed")}


def cross_kv(params, enc_h):
    k = jnp.einsum("bsd,dhe->bshe", enc_h, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", enc_h, params["wv"])
    return {"ck": k, "cv": v}


def cross_apply(params, x, kv):
    B, S, _ = x.shape
    H, dh = params["wq"].shape[1], params["wq"].shape[2]
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    scores = jnp.einsum("bqhe,bshe->bhqs", q, kv["ck"]).astype(jnp.float32)
    apm = jax.nn.softmax(scores * dh ** -0.5, -1)
    out = jnp.einsum("bhqs,bshe->bqhe", apm.astype(x.dtype), kv["cv"])
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def encdec_init(key, cfg, max_seq=4096, dtype=jnp.float32):
    e = cfg.encoder
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    # encoder layers are homogeneous (scan-stacked)
    ecfg = cfg.replace(d_model=e.d_model, n_heads=e.n_heads,
                       n_kv_heads=e.n_heads, d_head=e.d_model // e.n_heads,
                       qkv_bias=False, qk_norm=False)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"norm1": norm_init(e.d_model, cfg.norm, dtype),
                "attn": attn.gqa_init(k1, ecfg, dtype),
                "norm2": norm_init(e.d_model, cfg.norm, dtype),
                "mlp": mlp_init(k2, e.d_model, e.d_ff, cfg.glu, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"norm1": norm_init(d, cfg.norm, dtype),
                "attn": attn.gqa_init(k1, cfg, dtype),
                "norm_x": norm_init(d, cfg.norm, dtype),
                "cross": cross_init(k2, d, e.d_model, cfg.n_heads,
                                    cfg.head_dim, dtype),
                "norm2": norm_init(d, cfg.norm, dtype),
                "mlp": mlp_init(k3, d, cfg.d_ff, cfg.glu, dtype)}

    return {
        "enc_pos": (jax.random.normal(ks[0], (e.n_frames, e.d_model))
                    * 0.02).astype(dtype),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[1], e.n_layers)),
        "enc_norm": norm_init(e.d_model, cfg.norm, dtype),
        "embed": embed_init(ks[2], cfg.vocab, d, dtype),
        "dec_pos": (jax.random.normal(ks[3], (max_seq, d)) * 0.02
                    ).astype(dtype),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[4],
                                                           cfg.n_layers)),
        "final_norm": norm_init(d, cfg.norm, dtype),
    }, ecfg


def encdec_specs(cfg):
    enc = {"norm1": norm_specs(cfg.norm),
           "attn": attn.gqa_specs(cfg.replace(qkv_bias=False,
                                              qk_norm=False)),
           "norm2": norm_specs(cfg.norm),
           "mlp": mlp_specs(cfg.glu)}
    enc_layers = jax.tree.map(lambda t: ("layers",) + t, enc,
                              is_leaf=lambda t: isinstance(t, tuple))
    dec = {"norm1": norm_specs(cfg.norm),
           "attn": attn.gqa_specs(cfg),
           "norm_x": norm_specs(cfg.norm),
           "cross": cross_specs(),
           "norm2": norm_specs(cfg.norm),
           "mlp": mlp_specs(cfg.glu)}
    dec_layers = jax.tree.map(lambda t: ("layers",) + t, dec,
                              is_leaf=lambda t: isinstance(t, tuple))
    return {"enc_pos": ("frames", "embed"), "enc_layers": enc_layers,
            "enc_norm": norm_specs(cfg.norm), "embed": ("vocab", "embed"),
            "dec_pos": ("seq", "embed"), "dec_layers": dec_layers,
            "final_norm": norm_specs(cfg.norm)}


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(params, frames, cfg, ecfg, *, capture=False, memo_plan=None,
           layer_loop="scan", attn_impl="xla"):
    """frames: (B, n_frames, d_enc) stub embeddings → (enc_h, apms)."""
    B, S, _ = frames.shape
    h = frames.astype(params["enc_pos"].dtype) + params["enc_pos"][None, :S]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    apms: Dict[int, Any] = {}

    def one(lp, hh, li=None, cap=False, memo=None):
        x = norm_apply(lp["norm1"], hh, cfg.norm)
        y, apm = attn.gqa_apply(lp["attn"], x, ecfg, positions=positions,
                                mask_kind="bidir", memo=memo,
                                return_apm=cap, use_rope=False,
                                attn_impl=attn_impl)
        hh = hh + y
        x = norm_apply(lp["norm2"], hh, cfg.norm)
        return hh + mlp_apply(lp["mlp"], x, cfg.act, cfg.glu), apm

    if layer_loop == "unroll":
        for li in range(cfg.encoder.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["enc_layers"])
            memo = memo_plan.get(li) if memo_plan else None
            x_in = norm_apply(lp["norm1"], h, cfg.norm)
            h, apm = one(lp, h, li, cap=capture, memo=memo)
            if apm is not None:
                apms[li] = {"apm": apm, "hidden": x_in}
    else:
        def body(hh, lp):
            hh2, _ = one(lp, hh)
            return hh2, ()
        h, _ = jax.lax.scan(body, h, params["enc_layers"],
                            unroll=(layer_loop == "scan_unroll"))
    return norm_apply(params["enc_norm"], h, cfg.norm), apms


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def dec_layer_apply(lp, h, cfg, kv, *, mode, positions, pos, cache,
                    window=None):
    x = norm_apply(lp["norm1"], h, cfg.norm)
    if mode == "decode":
        y, cache_sa = attn.gqa_decode(lp["attn"], x, cfg, cache["sa"], pos,
                                      window=window, use_rope=False)
    else:
        y, _ = attn.gqa_apply(lp["attn"], x, cfg, positions=positions,
                              mask_kind="causal", window=window,
                              use_rope=False)
        cache_sa = (attn.gqa_prefill_cache(
            lp["attn"], x, cfg, positions,
            cache["sa"]["k"].shape[1], use_rope=False)
            if mode == "prefill" else None)
    h = h + y
    x = norm_apply(lp["norm_x"], h, cfg.norm)
    h = h + cross_apply(lp["cross"], x, kv)
    x = norm_apply(lp["norm2"], h, cfg.norm)
    h = h + mlp_apply(lp["mlp"], x, cfg.act, cfg.glu)
    new_cache = {"sa": cache_sa, "kv": kv} if mode != "full" else None
    return h, new_cache


def decode_tokens(params, tokens, enc_h, cfg, *, mode="full", caches=None,
                  pos=None, window=None, remat=False, unroll=False):
    """tokens: (B,S) ids. enc_h: (B,F,d_enc) or None (decode mode uses cached
    cross-kv). Returns (h, new_caches)."""
    B, S = tokens.shape
    if mode == "decode":
        positions = None
        pidx = jnp.asarray(pos, jnp.int32)
        pos_emb = jax.lax.dynamic_slice(
            params["dec_pos"], (jnp.minimum(pidx,
                                            params["dec_pos"].shape[0] - 1), 0),
            (1, params["dec_pos"].shape[1]))[None]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        pos_emb = params["dec_pos"][None, :S]
    h = params["embed"][tokens] + pos_emb

    if mode == "decode":
        def body(hh, xs):
            lp, gc = xs
            hh2, c = dec_layer_apply(lp, hh, cfg, gc["kv"], mode=mode,
                                     positions=positions, pos=pos, cache=gc,
                                     window=window)
            return hh2, c
        h, cs = jax.lax.scan(body, h, (params["dec_layers"], caches),
                             unroll=unroll)
        return h, cs

    def body(hh, xs):
        lp, gc = xs
        kv = cross_kv(lp["cross"], enc_h)
        hh2, c = dec_layer_apply(lp, hh, cfg, kv, mode=mode,
                                 positions=positions, pos=pos, cache=gc,
                                 window=window)
        return hh2, c
    bodyf = jax.checkpoint(body) if remat else body
    if mode == "full":
        def body_nc(hh, lp):
            kv = cross_kv(lp["cross"], enc_h)
            hh2, _ = dec_layer_apply(lp, hh, cfg, kv, mode="full",
                                     positions=positions, pos=pos, cache=None,
                                     window=window)
            return hh2, ()
        bodyf2 = jax.checkpoint(body_nc) if remat else body_nc
        h, _ = jax.lax.scan(bodyf2, h, params["dec_layers"],
                            unroll=unroll)
        return h, None
    h, cs = jax.lax.scan(bodyf, h, (params["dec_layers"], caches),
                         unroll=unroll)
    return h, cs


def encdec_init_caches(cfg, batch, seq, dtype=jnp.float32):
    e = cfg.encoder
    L, Hkv, dh, H = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    return {
        "sa": {"k": jnp.zeros((L, batch, seq, Hkv, dh), dtype),
               "v": jnp.zeros((L, batch, seq, Hkv, dh), dtype)},
        "kv": {"ck": jnp.zeros((L, batch, e.n_frames, H, dh), dtype),
               "cv": jnp.zeros((L, batch, e.n_frames, H, dh), dtype)},
    }

"""Token-choice top-k MoE.

Two implementations with identical math (tests assert equivalence when the
capacity factor is generous):

* ``moe_ref``    — single-device reference: computes every expert densely and
                   combines with the top-k weights. O(E) FLOPs; fine for the
                   reduced (<=4 expert) smoke configs only.
* ``moe_apply_ep`` — production expert-parallel path under ``shard_map``:
                   experts sharded over the ``data`` mesh axis, expert ffn dim
                   over ``model``. Tokens are capacity-bucketed, exchanged with
                   ``lax.all_to_all``, run through blocked per-expert matmuls,
                   and returned. Token-chunked with ``lax.scan`` to bound the
                   top_k× dispatch inflation (DESIGN.md §5).

Router aux loss is the standard load-balance term E·Σ_e f_e·P_e.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


def _shard_map(body, mesh, in_specs, out_specs):
    """Version-compat shard_map (jax>=0.5 top-level vs experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _axis_size(name):
    """Version-compat mapped-axis size (``lax.axis_size`` is newer jax;
    ``psum(1, axis)`` folds to the same constant everywhere)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def moe_init(key, cfg, dtype=jnp.float32):
    d, m = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    return {
        "w_router": dense_init(ks[0], (d, m.n_experts), dtype=dtype),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff),
                             scale=d ** -0.5, dtype=dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_ff),
                           scale=d ** -0.5, dtype=dtype),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_ff, d),
                             scale=m.d_ff ** -0.5, dtype=dtype),
    }


def moe_specs(cfg):
    return {"w_router": ("embed", "router"),
            "w_gate": ("experts", "embed", "ff"),
            "w_up": ("experts", "embed", "ff"),
            "w_down": ("experts", "ff", "embed")}


def _router(x, w_router, top_k):
    """x: (T,D) → probs (T,E), weights (T,k), ids (T,k), aux scalar."""
    logits = (x @ w_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    weights, ids = lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, -1, keepdims=True)
    E = probs.shape[-1]
    assign = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None], ids].set(1.0)
    f = jnp.mean(assign, 0) / top_k
    p = jnp.mean(probs, 0)
    aux = E * jnp.sum(f * p)
    return probs, weights.astype(x.dtype), ids, aux


# ---------------------------------------------------------------------------
# reference
# ---------------------------------------------------------------------------

def moe_ref(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D). Returns (y, aux_loss)."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    m = cfg.moe
    _, weights, ids, aux = _router(xf, params["w_router"], m.top_k)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, params["w_gate"])) \
        * jnp.einsum("td,edf->tef", xf, params["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])     # (T,E,D)
    T = xf.shape[0]
    sel = y_all[jnp.arange(T)[:, None], ids]                     # (T,k,D)
    y = jnp.sum(sel * weights[..., None], axis=1)
    return y.reshape(shape), aux


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------

def _bucketize(keys, n_buckets, cap):
    """Stable-sort rows by bucket key; per-bucket slot positions with a
    capacity limit. Returns (order, key_sorted, pos_clipped, keep_sorted):
    rows beyond ``cap`` in their bucket get pos == cap (overflow slot)."""
    order = jnp.argsort(keys, stable=True)
    ks = keys[order]
    start = jnp.searchsorted(ks, ks, side="left")
    pos = jnp.arange(keys.shape[0]) - start
    keep = pos < cap
    return order, ks, jnp.where(keep, pos, cap), keep


def _moe_chunk(x_c, wr, w_gate, w_up, w_down, *, cfg, ep_axis, tp_axis):
    """One token chunk on one data shard inside shard_map.
    x_c: (t, D) local tokens; expert weights are local shards
    (E_loc, D, F_loc) / (E_loc, F_loc, D)."""
    m = cfg.moe
    t, D = x_c.shape
    ep = _axis_size(ep_axis)
    E_loc = w_gate.shape[0]
    _, weights, ids, aux = _router(x_c, wr, m.top_k)
    R = t * m.top_k
    eid = ids.reshape(R)
    dst = eid // E_loc                                   # owning data shard
    C = max(1, math.ceil(R / ep * m.capacity_factor))

    order, dst_s, pos_cl, keep = _bucketize(dst, ep, C)
    rows = x_c[order // m.top_k]
    send_x = jnp.zeros((ep, C + 1, D), x_c.dtype).at[dst_s, pos_cl].set(rows)
    send_le = jnp.zeros((ep, C + 1), jnp.int32).at[dst_s, pos_cl].set(
        (eid % E_loc)[order])
    send_ok = jnp.zeros((ep, C + 1), bool).at[dst_s, pos_cl].set(keep)
    send_x, send_le, send_ok = (a[:, :C] for a in (send_x, send_le, send_ok))

    recv_x = lax.all_to_all(send_x, ep_axis, 0, 0, tiled=True)
    recv_le = lax.all_to_all(send_le, ep_axis, 0, 0, tiled=True)
    recv_ok = lax.all_to_all(send_ok, ep_axis, 0, 0, tiled=True)

    # local per-expert capacity buckets
    R2 = ep * C
    rows2 = recv_x.reshape(R2, D)
    le = jnp.where(recv_ok.reshape(R2), recv_le.reshape(R2), E_loc)
    Ce = max(1, math.ceil(R2 / E_loc * m.capacity_factor))
    order2, le_s, pos2_cl, keep2 = _bucketize(le, E_loc + 1, Ce)
    xb = jnp.zeros((E_loc + 1, Ce + 1, D), x_c.dtype).at[
        le_s, pos2_cl].set(rows2[order2])
    xe = xb[:E_loc, :Ce]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)
    ye = lax.psum(ye, tp_axis)                          # combine ff shards

    # invert local bucketing
    yb = jnp.zeros((E_loc + 1, Ce + 1, D), ye.dtype).at[:E_loc, :Ce].set(ye)
    y_sorted = yb[le_s, pos2_cl] * keep2[:, None].astype(ye.dtype)
    y_rows2 = jnp.zeros((R2, D), ye.dtype).at[order2].set(y_sorted)
    recv_y = y_rows2.reshape(ep, C, D)

    send_y = lax.all_to_all(recv_y, ep_axis, 0, 0, tiled=True)

    # invert dispatch bucketing
    send_y = jnp.pad(send_y, ((0, 0), (0, 1), (0, 0)))
    y_sorted_src = send_y[dst_s, pos_cl] * keep[:, None].astype(ye.dtype)
    y_flat = jnp.zeros((R, D), ye.dtype).at[order].set(y_sorted_src)
    y = jnp.sum(y_flat.reshape(t, m.top_k, D) * weights[..., None], axis=1)
    return y, aux


def _moe_body(wr, w_gate, w_up, w_down, x_loc, *, cfg, ep_axis, tp_axis,
              dp_axes):
    T_loc, D = x_loc.shape
    n_chunks = 1
    for c in range(min(cfg.moe.dispatch_chunks, T_loc), 0, -1):
        if T_loc % c == 0:
            n_chunks = c
            break
    chunks = x_loc.reshape(n_chunks, T_loc // n_chunks, D)
    fn = partial(_moe_chunk, wr=wr, w_gate=w_gate, w_up=w_up, w_down=w_down,
                 cfg=cfg, ep_axis=ep_axis, tp_axis=tp_axis)
    if n_chunks == 1:
        y, aux = fn(chunks[0])
        y, aux = y[None], aux[None]
    else:
        _, (y, aux) = lax.scan(lambda c, x_c: (c, fn(x_c)), 0, chunks)
    aux = lax.pmean(jnp.mean(aux), dp_axes)
    return y.reshape(T_loc, D), aux


def _moe_small_body(wr, w_gate, w_up, w_down, x, *, cfg, ep_axis, tp_axis):
    """Decode-time path: token count too small to shard — tokens are
    replicated; each shard runs only its LOCAL experts densely and the
    outputs combine with one psum. Exact (no capacity drops)."""
    E_loc = w_gate.shape[0]
    eidx = lax.axis_index(ep_axis)
    _, weights, ids, aux = _router(x, wr, cfg.moe.top_k)
    local = (ids >= eidx * E_loc) & (ids < (eidx + 1) * E_loc)
    w_loc = jnp.where(local, weights, 0.0)
    onehot = jax.nn.one_hot(ids - eidx * E_loc, E_loc, dtype=x.dtype)
    w_te = jnp.sum(onehot * w_loc[..., None], axis=1)          # (T, E_loc)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, w_gate)) \
        * jnp.einsum("td,edf->tef", x, w_up)
    y_e = jnp.einsum("tef,efd->ted", h, w_down)
    y = jnp.einsum("ted,te->td", y_e, w_te.astype(y_e.dtype))
    y = lax.psum(y, (ep_axis, tp_axis))
    return y, aux


def moe_apply_ep(params, x, cfg, mesh, dp_axes=("data",), ep_axis="data",
                 tp_axis="model"):
    """x: (..., D) with leading dims sharded over ``dp_axes``."""
    shape = x.shape
    xf = x.reshape(-1, shape[-1])
    T = xf.shape[0]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape.get(a, 1)
    w_specs = (P(), P(ep_axis, None, tp_axis), P(ep_axis, None, tp_axis),
               P(ep_axis, tp_axis, None))
    if T % dp_size != 0 or T < 4 * dp_size:
        body = partial(_moe_small_body, cfg=cfg, ep_axis=ep_axis,
                       tp_axis=tp_axis)
        y, aux = _shard_map(
            body, mesh, in_specs=w_specs + (P(),),
            out_specs=(P(), P()),
        )(params["w_router"], params["w_gate"], params["w_up"],
          params["w_down"], xf)
        return y.reshape(shape), jnp.mean(aux)
    body = partial(_moe_body, cfg=cfg, ep_axis=ep_axis, tp_axis=tp_axis,
                   dp_axes=dp_axes)
    y, aux = _shard_map(
        body, mesh, in_specs=w_specs + (P(dp_axes, None),),
        out_specs=(P(dp_axes, None), P()),
    )(params["w_router"], params["w_gate"], params["w_up"],
      params["w_down"], xf)
    return y.reshape(shape), aux


def moe_apply(params, x, cfg, mesh=None, dp_axes=("data",)):
    if mesh is None:
        return moe_ref(params, x, cfg)
    return moe_apply_ep(params, x, cfg, mesh, dp_axes=dp_axes)

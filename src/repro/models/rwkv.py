"""RWKV-6 "Finch" mixer — attention-free, data-dependent decay.

[arXiv:2404.05892]. Per head (dim N): state S ∈ R^{N×N},
    o_t = (S_t + diag(u)·k_tᵀv_t)ᵀ r_t,    S_{t+1} = diag(w_t)·S_t + k_tᵀ v_t
with per-channel decay w_t = exp(-exp(w0 + lora_w(x̃_t))) ∈ (0,1) and
ddlerp token-shift mixing (low-rank data-dependent interpolation with the
previous token). Output gating g and per-head GroupNorm as in the paper.

AttMemo is inapplicable here (no attention-probability matrix); see
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

_LORA = 64          # ddlerp / decay low-rank dim
_MIX_NAMES = ("r", "k", "v", "w", "g")


def rwkv_time_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    nh = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 16)
    p = {
        "mu_x": jnp.full((d,), 0.5, dtype),
        # one fused ddlerp lora: d -> 5*_LORA -> 5*d
        "ddlerp_a": dense_init(ks[0], (d, 5 * _LORA), dtype=dtype),
        "ddlerp_b": dense_init(ks[1], (5, _LORA, d), scale=_LORA ** -0.5,
                               dtype=dtype),
        "mu": jnp.full((5, d), 0.5, dtype),            # per-proj base mix
        "w0": jnp.full((d,), -6.0, dtype),              # decay bias (slow)
        "decay_a": dense_init(ks[2], (d, _LORA), dtype=dtype),
        "decay_b": dense_init(ks[3], (_LORA, d), scale=_LORA ** -0.5,
                              dtype=dtype),
        "u": jnp.zeros((d,), dtype),                    # bonus
        "wr": dense_init(ks[4], (d, d), dtype=dtype),
        "wk": dense_init(ks[5], (d, d), dtype=dtype),
        "wv": dense_init(ks[6], (d, d), dtype=dtype),
        "wg": dense_init(ks[7], (d, d), dtype=dtype),
        "wo": dense_init(ks[8], (d, d), dtype=dtype),
        "ln_scale": jnp.ones((nh, cfg.rwkv_head_dim), dtype),
    }
    return p


def rwkv_time_specs(cfg):
    return {"mu_x": ("embed",), "ddlerp_a": ("embed", "lora"),
            "ddlerp_b": ("proj5", "lora", "embed"), "mu": ("proj5", "embed"),
            "w0": ("embed",), "decay_a": ("embed", "lora"),
            "decay_b": ("lora", "embed"), "u": ("embed",),
            "wr": ("embed", "heads_embed"), "wk": ("embed", "heads_embed"),
            "wv": ("embed", "heads_embed"), "wg": ("embed", "heads_embed"),
            "wo": ("heads_embed", "embed"),
            "ln_scale": ("heads", "head_dim")}


def _ddlerp(params, x, x_prev):
    """Returns the 5 mixed inputs (r,k,v,w,g): each (B,S,D)."""
    xx = x_prev - x
    xxx = x + xx * params["mu_x"]
    a = jnp.tanh(xxx @ params["ddlerp_a"])               # (B,S,5*LORA)
    B, S, _ = a.shape
    a = a.reshape(B, S, 5, _LORA)
    lora = jnp.einsum("bspl,pld->bspd", a, params["ddlerp_b"])
    mix = params["mu"][None, None] + lora                # (B,S,5,D)
    return x[:, :, None] + xx[:, :, None] * mix          # (B,S,5,D)


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B,S,nh,N); u: (nh,N); s0: (B,nh,N,N) → o (B,S,nh,N), sT."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                          # (B,nh,N)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)        # outer product
        o_t = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o_t
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), sT


def _groupnorm(x, scale, eps=1e-5):
    """x: (B,S,nh,N) — normalize per head."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def rwkv_time_apply(params, x, cfg, state=None, impl="scan"):
    """Full-sequence time-mix. x: (B,S,D). state: {'s','x_prev'} or None.
    ``impl='pallas_interpret'`` uses the chunked wkv kernel (fresh-state
    sequences only — the chunked form starts from S=0). Returns
    (y, new_state)."""
    B, S, d = x.shape
    nh, N = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    x_prev = (jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1] if state is None
              else jnp.concatenate([state["x_prev"][:, None], x[:, :-1]], 1))
    mixed = _ddlerp(params, x, x_prev)                    # (B,S,5,D)
    xr, xk, xv, xw, xg = (mixed[:, :, i] for i in range(5))
    r = (xr @ params["wr"]).reshape(B, S, nh, N)
    k = (xk @ params["wk"]).reshape(B, S, nh, N)
    v = (xv @ params["wv"]).reshape(B, S, nh, N)
    g = jax.nn.silu(xg @ params["wg"])
    dec = params["w0"] + jnp.tanh(xw @ params["decay_a"]) @ params["decay_b"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).astype(x.dtype)
    w = w.reshape(B, S, nh, N)
    u = params["u"].reshape(nh, N)
    if impl == "pallas_interpret" and state is None:
        from repro.kernels.rwkv6.ops import wkv6_chunked
        o = wkv6_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w.astype(jnp.float32),
                         u.astype(jnp.float32),
                         chunk=min(32, max(8, S)), interpret=True)
        o = o.astype(x.dtype)
        o = _groupnorm(o, params["ln_scale"]).reshape(B, S, d) * g
        # state output: recompute final state only (cheap rank-1 updates)
        sT = None
        return o @ params["wo"], {"s": sT, "x_prev": x[:, -1]}
    s0 = (jnp.zeros((B, nh, N, N), x.dtype) if state is None else state["s"])
    if cfg.act_shard_batch:
        # pin the scan operands/state to batch-sharding over both mesh
        # axes: heads (40) don't divide model=16, the batch does, and a
        # batch-sharded state keeps the whole recurrence collective-free
        from jax.sharding import PartitionSpec as P
        spec4 = P(cfg.act_shard_batch, None, None, None)
        r, k, v, w = (jax.lax.with_sharding_constraint(t, spec4)
                      for t in (r, k, v, w))
        s0 = jax.lax.with_sharding_constraint(s0, spec4)
    o, sT = _wkv_scan(r, k, v, w, u, s0)
    o = _groupnorm(o, params["ln_scale"]).reshape(B, S, d) * g
    y = o @ params["wo"]
    return y, {"s": sT, "x_prev": x[:, -1]}


def rwkv_time_decode(params, x, cfg, state):
    """One-token step; x: (B,1,D)."""
    return rwkv_time_apply(params, x, cfg, state)


def rwkv_time_init_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    nh, N = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {"s": jnp.zeros((batch, nh, N, N), dtype),
            "x_prev": jnp.zeros((batch, d), dtype)}


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------

def rwkv_channel_init(key, cfg, dtype=jnp.float32):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"mu_k": jnp.full((d,), 0.5, dtype),
            "mu_r": jnp.full((d,), 0.5, dtype),
            "wk": dense_init(ks[0], (d, ff), dtype=dtype),
            "wv": dense_init(ks[1], (ff, d), dtype=dtype),
            "wr": dense_init(ks[2], (d, d), dtype=dtype)}


def rwkv_channel_specs(cfg):
    return {"mu_k": ("embed",), "mu_r": ("embed",), "wk": ("embed", "ff"),
            "wv": ("ff", "embed"), "wr": ("embed", "heads_embed")}


def rwkv_channel_apply(params, x, cfg, state=None):
    x_prev = (jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1] if state is None
              else jnp.concatenate([state["x_prev"][:, None], x[:, :-1]], 1))
    xx = x_prev - x
    xk = x + xx * params["mu_k"]
    xr = x + xx * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    y = jax.nn.sigmoid(xr @ params["wr"]) * (k @ params["wv"])
    return y, {"x_prev": x[:, -1]}


def rwkv_channel_init_state(cfg, batch, dtype=jnp.float32):
    return {"x_prev": jnp.zeros((batch, cfg.d_model), dtype)}

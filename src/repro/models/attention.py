"""Attention mixers: GQA/MQA/MHA and MLA (Multi-head Latent Attention).

All functions are pure; params are dicts. Each full-sequence apply can
  * capture the attention-probability matrix (APM) — AttMemo's memoized
    quantity — via ``return_apm=True``;
  * consume a memoized APM override via ``memo=(apm, hit)`` where
    ``apm: (B, H, S, S)`` and ``hit: (B,) bool``: sequences with hit=True skip
    QK^T + softmax entirely (engine-level bucketing makes that skip real; in
    the fused Pallas kernel the skip is per-sequence via pl.when).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init


class Memo(NamedTuple):
    apm: jnp.ndarray          # (B, H, Sq, Sk) memoized probabilities
    hit: jnp.ndarray          # (B,) bool
    idx: jnp.ndarray = None   # (B,) DB indices (device-DB kernel path)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def make_mask(sq: int, sk: int, kind: str, window: Optional[int] = None,
              offset: int = 0):
    """(sq, sk) boolean mask. kind: causal | bidir. ``offset`` is the absolute
    position of query 0 (prefill chunking / decode)."""
    if kind == "bidir" and window is None:
        return jnp.ones((sq, sk), bool)
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if kind == "causal":
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _sdpa(q, k, v, mask, scale, memo: Optional[Memo] = None,
          return_apm: bool = False):
    """q: (B,Sq,Hkv,G,dh)  k,v: (B,Sk,Hkv,dh)  mask: (Sq,Sk) or (B,Sq,Sk)."""
    B, Sq, Hkv, G, dh = q.shape
    scores = jnp.einsum("bqhgd,bshd->bhgqs", q, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, None, None], scores, neg)
    apm = jax.nn.softmax(scores, axis=-1)
    if memo is not None:
        memo_apm = memo.apm.reshape(B, Hkv, G, Sq, -1).astype(jnp.float32)
        apm = jnp.where(memo.hit[:, None, None, None, None], memo_apm, apm)
    out = jnp.einsum("bhgqs,bshd->bqhgd", apm.astype(v.dtype), v)
    apm_full = apm.reshape(B, Hkv * G, Sq, -1) if return_apm else None
    return out, apm_full


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype=jnp.float32):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, H, dh), scale=d ** -0.5, dtype=dtype),
         "wk": dense_init(ks[1], (d, Hkv, dh), scale=d ** -0.5, dtype=dtype),
         "wv": dense_init(ks[2], (d, Hkv, dh), scale=d ** -0.5, dtype=dtype),
         "wo": dense_init(ks[3], (H, dh, d), scale=(H * dh) ** -0.5, dtype=dtype)}
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((H, dh), dtype), bk=jnp.zeros((Hkv, dh), dtype),
                 bv=jnp.zeros((Hkv, dh), dtype))
    if cfg.qk_norm:
        p.update(q_norm=jnp.ones((dh,), dtype), k_norm=jnp.ones((dh,), dtype))
    return p


def gqa_specs(cfg):
    s = {"wq": ("embed", "heads", "head_dim"),
         "wk": ("embed", "kv_heads", "head_dim"),
         "wv": ("embed", "kv_heads", "head_dim"),
         "wo": ("heads", "head_dim", "embed")}
    if cfg.qkv_bias:
        s.update(bq=("heads", "head_dim"), bk=("kv_heads", "head_dim"),
                 bv=("kv_heads", "head_dim"))
    if cfg.qk_norm:
        s.update(q_norm=("head_dim",), k_norm=("head_dim",))
    return s


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def _qkv(params, x, cfg, positions, use_rope=True):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q, k = _rms(q, params["q_norm"]), _rms(k, params["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(params, x, cfg, *, positions, mask_kind="causal",
              window=None, memo: Optional[Memo] = None, return_apm=False,
              use_rope=True, attn_impl="xla", kpad=None):
    """Full-sequence GQA. x: (B,S,D) → (B,S,D).

    ``kpad``: optional (B, S) bool key-validity mask for padded
    variable-length batches — False keys are excluded from the softmax,
    so a sequence padded to a bucket length produces the same APM rows
    (and zero probability mass on pad columns) as its unpadded run."""
    B, S, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(params, x, cfg, positions, use_rope)
    qg = q.reshape(B, S, Hkv, H // Hkv, dh)
    mask = make_mask(S, S, mask_kind, window)
    if kpad is not None:
        mask = mask[None] & kpad[:, None, :]
    if attn_impl == "pallas_interpret" and memo is None and not return_apm \
            and kpad is None:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, causal=(mask_kind == "causal"), window=window,
            interpret=True)
        apm = None
    else:
        out, apm = _sdpa(qg, k, v, mask, dh ** -0.5, memo, return_apm)
        out = out.reshape(B, S, H, dh)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, apm


def gqa_decode(params, x, cfg, cache, pos, *, window=None, use_rope=True):
    """One-token decode. x: (B,1,D); cache: {'k','v'}: (B,Sc,Hkv,dh).
    ``pos``: scalar absolute position. Rolling buffer iff Sc < pos allowed:
    writes at pos % Sc and masks by recency window == Sc."""
    B, _, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions, use_rope)
    Sc = cache["k"].shape[1]
    slot = jnp.mod(pos, Sc)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    # absolute position of each cache slot under rolling writes
    idx = jnp.arange(Sc)
    wrap = (pos // Sc) * Sc
    abs_pos = jnp.where(idx <= slot, wrap + idx, wrap - Sc + idx)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        valid &= abs_pos > pos - window
    qg = q.reshape(B, 1, Hkv, H // Hkv, dh)
    out, _ = _sdpa(qg, ck, cv, valid[None, :][None], dh ** -0.5)
    out = out.reshape(B, 1, H, dh)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, {"k": ck, "v": cv}


def gqa_init_cache(cfg, batch, seq, dtype=jnp.float32):
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    z = jnp.zeros((batch, seq, Hkv, dh), dtype)
    return {"k": z, "v": z}


def gqa_prefill_cache(params, x, cfg, positions, seq_total, use_rope=True):
    """Build the decode cache from a full prompt (cheaper than re-decode)."""
    _, k, v = _qkv(params, x, cfg, positions, use_rope)
    pad = seq_total - k.shape[1]
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k, "v": v}


def gqa_apply_memo(params, x, cfg, apm):
    """Memo-only fast path: the APM is fully known, so Q/K projections,
    QKᵀ and softmax are all skipped — only V and the APM·V matmul run.
    This is the compute the paper's memoization actually saves.
    x: (B,S,D); apm: (B,H,S,S) → (B,S,D)."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if cfg.qkv_bias:
        v = v + params["bv"]
    Hkv = cfg.n_kv_heads
    apm_g = apm.reshape(B, Hkv, H // Hkv, S, S).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", apm_g, v).reshape(B, S, H, dh)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def mla_apply_memo(params, x, cfg, apm):
    """Memo-only MLA fast path: skip q path, QKᵀ and softmax; compute the
    compressed kv and expand V only."""
    m = cfg.mla
    c_kv = _rms(x @ params["w_dkv"], params["kv_norm"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uv"])
    out = jnp.einsum("bhqs,bshe->bqhe", apm.astype(v.dtype), v)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype=jnp.float32):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, H, qk),
                           scale=m.q_lora_rank ** -0.5, dtype=dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype=dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_kr": dense_init(ks[3], (d, m.qk_rope_head_dim), dtype=dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                           scale=m.kv_lora_rank ** -0.5, dtype=dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, H, m.v_head_dim),
                           scale=m.kv_lora_rank ** -0.5, dtype=dtype),
        "wo": dense_init(ks[6], (H, m.v_head_dim, d),
                         scale=(H * m.v_head_dim) ** -0.5, dtype=dtype),
    }


def mla_specs(cfg):
    return {"w_dq": ("embed", "q_lora"), "q_norm": ("q_lora",),
            "w_uq": ("q_lora", "heads", "head_dim"),
            "w_dkv": ("embed", "kv_lora"), "kv_norm": ("kv_lora",),
            "w_kr": ("embed", "head_dim"),
            "w_uk": ("kv_lora", "heads", "head_dim"),
            "w_uv": ("kv_lora", "heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed")}


def _mla_qkr(params, x, cfg, positions):
    m = cfg.mla
    cq = _rms(x @ params["w_dq"], params["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, params["w_uq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    c_kv = _rms(x @ params["w_dkv"], params["kv_norm"])
    k_rope = apply_rope(x @ params["w_kr"], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params, x, cfg, *, positions, mask_kind="causal", window=None,
              memo: Optional[Memo] = None, return_apm=False, attn_impl="xla",
              kpad=None):
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.n_heads
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uv"])
    scores = (jnp.einsum("bqhe,bshe->bhqs", q_nope, k_nope)
              + jnp.einsum("bqhe,bse->bhqs", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    mask = make_mask(S, S, mask_kind, window)
    if kpad is not None:
        mask = mask[None] & kpad[:, None, :]
    scores = jnp.where(mask[None, None] if mask.ndim == 2
                       else mask[:, None], scores,
                       jnp.finfo(jnp.float32).min)
    apm = jax.nn.softmax(scores, -1)
    if memo is not None:
        apm = jnp.where(memo.hit[:, None, None, None],
                        memo.apm.astype(jnp.float32), apm)
    out = jnp.einsum("bhqs,bshe->bqhe", apm.astype(v.dtype), v)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, (apm if return_apm else None)


def mla_decode(params, x, cfg, cache, pos, *, window=None):
    """Absorbed-matmul MLA decode: attention runs in the kv_lora latent space,
    cache holds (c_kv, k_rope) only — the MLA serving advantage."""
    B = x.shape[0]
    m = cfg.mla
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkr(params, x, cfg, positions)
    Sc = cache["c_kv"].shape[1]
    slot = jnp.mod(pos, Sc)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new,
                                          (0, slot, 0))
    idx = jnp.arange(Sc)
    wrap = (pos // Sc) * Sc
    abs_pos = jnp.where(idx <= slot, wrap + idx, wrap - Sc + idx)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if window is not None:
        valid &= abs_pos > pos - window
    # absorbed: q ⋅ W_uk projected into latent space once per step
    q_abs = jnp.einsum("bqhe,rhe->bqhr", q_nope, params["w_uk"])
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, c_kv)
              + jnp.einsum("bqhe,bse->bhqs", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, None], scores,
                       jnp.finfo(jnp.float32).min)
    apm = jax.nn.softmax(scores, -1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", apm, c_kv)
    out = jnp.einsum("bqhr,rhe->bqhe", ctx, params["w_uv"])
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def mla_init_cache(cfg, batch, seq, dtype=jnp.float32):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype)}


def mla_prefill_cache(params, x, cfg, positions, seq_total):
    _, _, c_kv, k_rope = _mla_qkr(params, x, cfg, positions)
    pad = seq_total - c_kv.shape[1]
    if pad > 0:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return {"c_kv": c_kv, "k_rope": k_rope}

"""Shared neural building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (shape[0] or explicit scale)."""
    fan_in = shape[0] if scale is None else None
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh) or (..., S, dh); positions: (..., S)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                                    # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv           # (..., S, dh/2)
    if x.ndim == ang.ndim + 1:                                     # head axis
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def mlp_init(key, d, d_ff, glu: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if glu:
        return {"w_gate": dense_init(ks[0], (d, d_ff), dtype=dtype),
                "w_up": dense_init(ks[1], (d, d_ff), dtype=dtype),
                "w_down": dense_init(ks[2], (d_ff, d), dtype=dtype)}
    return {"w_up": dense_init(ks[0], (d, d_ff), dtype=dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(ks[1], (d_ff, d), dtype=dtype),
            "b_down": jnp.zeros((d,), dtype)}


def mlp_apply(params, x, act: str, glu: bool):
    f = _ACT[act]
    if glu:
        h = f(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    h = f(x @ params["w_up"] + params["b_up"])
    return h @ params["w_down"] + params["b_down"]


def mlp_specs(glu: bool):
    """Logical-axis names mirroring mlp_init."""
    if glu:
        return {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                "w_down": ("ff", "embed")}
    return {"w_up": ("embed", "ff"), "b_up": ("ff",),
            "w_down": ("ff", "embed"), "b_down": ("embed",)}


def norm_specs(kind: str):
    if kind == "rmsnorm":
        return {"scale": ("embed",)}
    return {"scale": ("embed",), "bias": ("embed",)}

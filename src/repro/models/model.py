"""Unified model interface over the backbone / enc-dec assemblies.

``build_model(cfg)`` → ``Model`` exposing:
    init, specs, forward, train_loss, classify, prefill, decode_step,
    init_caches
All methods are pure and jit-friendly; batch dicts use
{"tokens": (B,S) int32[, "frames": (B,F,d_enc) f32, "labels": (B,) int32]}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import backbone as bb
from repro.models import encdec as ed


class Model:
    def __init__(self, cfg, *, mesh=None, dp_axes=("data",),
                 attn_impl="xla", layer_loop="scan", remat=False,
                 max_seq=4096):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.attn_impl = attn_impl
        self.layer_loop = layer_loop
        self.remat = remat
        self.max_seq = max_seq
        self.is_encdec = cfg.encoder is not None
        if self.is_encdec:
            self._ecfg = cfg.replace(
                d_model=cfg.encoder.d_model, n_heads=cfg.encoder.n_heads,
                n_kv_heads=cfg.encoder.n_heads,
                d_head=cfg.encoder.d_model // cfg.encoder.n_heads,
                qkv_bias=False, qk_norm=False)

    # -- params ------------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        if self.is_encdec:
            params, _ = ed.encdec_init(key, self.cfg, self.max_seq, dtype)
            return params
        return bb.backbone_init(key, self.cfg, dtype)

    def specs(self):
        if self.is_encdec:
            return ed.encdec_specs(self.cfg)
        return bb.backbone_specs(self.cfg)

    # -- full-sequence forward ----------------------------------------------
    def forward(self, params, batch, *, capture=False, memo_plan=None,
                window=None):
        """Returns (logits, apms, aux)."""
        if self.is_encdec:
            enc_h, apms = ed.encode(
                params, batch["frames"], self.cfg, self._ecfg,
                capture=capture, memo_plan=memo_plan,
                layer_loop=self.layer_loop, attn_impl=self.attn_impl)
            h, _ = ed.decode_tokens(params, batch["tokens"], enc_h, self.cfg,
                                    mode="full", window=window,
                                    remat=self.remat,
                                    unroll=(self.layer_loop != "scan"))
            h = bb.norm_apply(params["final_norm"], h, self.cfg.norm)
            logits = h @ params["embed"].T
            return logits, apms, jnp.zeros((), jnp.float32)
        h = bb.embed_tokens(params, batch["tokens"], self.cfg)
        h, _, apms, aux = bb.forward_hidden(
            params, h, self.cfg, mode="full", memo_plan=memo_plan,
            capture=capture, layer_loop=self.layer_loop, mesh=self.mesh,
            dp_axes=self.dp_axes, window=window, attn_impl=self.attn_impl,
            remat=self.remat)
        return bb.logits_from_hidden(params, h, self.cfg), apms, aux

    # -- losses --------------------------------------------------------------
    def train_loss(self, params, batch):
        logits, _, aux = self.forward(params, batch)
        tok = batch["tokens"]
        lg = logits[:, :-1].astype(jnp.float32)
        tgt = tok[:, 1:]
        logp = jax.nn.log_softmax(lg, -1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], -1)[..., 0]
        loss = jnp.mean(nll)
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.aux_loss_coef * aux
        return loss

    def classify(self, params, batch, *, memo_plan=None, capture=False):
        """Mean-pool classification (AttMemo accuracy experiments)."""
        h = bb.embed_tokens(params, batch["tokens"], self.cfg)
        h, _, apms, _ = bb.forward_hidden(
            params, h, self.cfg, mode="full", memo_plan=memo_plan,
            capture=capture, layer_loop=self.layer_loop, mesh=self.mesh,
            dp_axes=self.dp_axes, attn_impl=self.attn_impl)
        logits = bb.classify_from_hidden(params, h, self.cfg)
        return (logits, apms) if capture else logits

    def classify_loss(self, params, batch):
        logits = self.classify(params, batch).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(
            jnp.take_along_axis(logp, batch["labels"][:, None], -1))

    # -- serving ---------------------------------------------------------------
    def init_caches(self, batch, cache_len, dtype=jnp.float32, window=None):
        if self.is_encdec:
            return ed.encdec_init_caches(self.cfg, batch,
                                         min(cache_len, window or cache_len),
                                         dtype)
        return bb.init_caches(self.cfg, batch, cache_len, dtype,
                              window=window)

    def prefill(self, params, batch, *, cache_len, window=None,
                dtype=jnp.float32):
        """Process the prompt; returns (last_token_logits, caches)."""
        tokens = batch["tokens"]
        B, S = tokens.shape[0], tokens.shape[1]
        caches = self.init_caches(B, cache_len, dtype, window=window)
        if self.is_encdec:
            enc_h, _ = ed.encode(params, batch["frames"], self.cfg,
                                 self._ecfg, attn_impl=self.attn_impl,
                                 layer_loop=self.layer_loop)
            h, caches = ed.decode_tokens(params, tokens, enc_h, self.cfg,
                                         mode="prefill", caches=caches,
                                         window=window,
                                         unroll=(self.layer_loop != "scan"))
            h = bb.norm_apply(params["final_norm"], h[:, -1:], self.cfg.norm)
            return (h @ params["embed"].T)[:, 0], caches
        h = bb.embed_tokens(params, tokens, self.cfg)
        h, caches, _, _ = bb.forward_hidden(
            params, h, self.cfg, mode="prefill", caches=caches,
            layer_loop=self.layer_loop, mesh=self.mesh,
            dp_axes=self.dp_axes, window=window, attn_impl=self.attn_impl)
        logits = bb.logits_from_hidden(params, h[:, -1:], self.cfg)
        return logits[:, 0], caches

    def decode_step(self, params, tokens, caches, pos, *, window=None):
        """tokens: (B,1). Returns (logits (B,V), new_caches)."""
        if self.is_encdec:
            h, caches = ed.decode_tokens(params, tokens, None, self.cfg,
                                         mode="decode", caches=caches,
                                         pos=pos, window=window,
                                         unroll=(self.layer_loop != "scan"))
            h = bb.norm_apply(params["final_norm"], h, self.cfg.norm)
            return (h @ params["embed"].T)[:, 0], caches
        h = bb.embed_tokens(params, tokens, self.cfg)
        h, caches, _, _ = bb.forward_hidden(
            params, h, self.cfg, mode="decode", caches=caches, pos=pos,
            layer_loop=self.layer_loop, mesh=self.mesh,
            dp_axes=self.dp_axes, window=window, attn_impl=self.attn_impl)
        logits = bb.logits_from_hidden(params, h, self.cfg)
        return logits[:, 0], caches


def build_model(cfg, **kw) -> Model:
    return Model(cfg, **kw)

"""Decoder-LM backbone: assembles mixers + channel mixers into a model.

Layers are grouped into *segments* for compile-time efficiency:
homogeneous runs are stacked and driven by ``lax.scan`` (keeps the HLO an
O(1) function of depth — essential for the 61-layer dry-runs); hybrid
patterns scan over repeating units; leading dense layers of MoE models are
single segments. ``layer_loop='unroll'`` switches to a python loop so the
AttMemo engine can capture / override per-layer APMs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (
    dense_init, embed_init, mlp_apply, mlp_init, mlp_specs, norm_apply,
    norm_init, norm_specs,
)


# ---------------------------------------------------------------------------
# segment plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    kind: str            # "single" | "scan"
    start: int           # first layer index
    unit: Tuple[str, ...]  # mixer kinds inside one step
    reps: int            # scan repeats (1 for single)


def scan_plan(cfg) -> List[Segment]:
    kinds = cfg.layer_kinds()
    n = cfg.n_layers
    segs: List[Segment] = []
    start = cfg.dense_first_n
    for i in range(start):
        segs.append(Segment("single", i, (kinds[i],), 1))
    unit = len(cfg.layer_pattern) if cfg.layer_pattern != ("mix",) else 1
    reps = (n - start) // unit
    if reps > 0:
        segs.append(Segment("scan", start, tuple(kinds[start:start + unit]),
                            reps))
    for i in range(start + reps * unit, n):
        segs.append(Segment("single", i, (kinds[i],), 1))
    return segs


def _chan_kind(cfg, layer_idx: int) -> str:
    if cfg.layer_kinds()[layer_idx] == "rwkv6":
        return "rwkvc"
    if cfg.moe is not None and layer_idx >= cfg.dense_first_n:
        return "moe"
    return "mlp"


def _dense_ff(cfg, layer_idx: int) -> int:
    if (cfg.moe is not None and layer_idx < cfg.dense_first_n
            and cfg.dense_d_ff):
        return cfg.dense_d_ff
    return cfg.d_ff


# ---------------------------------------------------------------------------
# per-layer init / specs / apply
# ---------------------------------------------------------------------------

_MIX_INIT = {"attn": attn.gqa_init, "mla": attn.mla_init,
             "rwkv6": rwkv_mod.rwkv_time_init, "rglru": rglru_mod.rglru_init}
_MIX_SPECS = {"attn": attn.gqa_specs, "mla": attn.mla_specs,
              "rwkv6": rwkv_mod.rwkv_time_specs, "rglru": rglru_mod.rglru_specs}


def _layer_init(key, cfg, layer_idx, kind, dtype):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {"norm1": norm_init(d, cfg.norm, dtype),
         "norm2": norm_init(d, cfg.norm, dtype),
         "mix": _MIX_INIT[kind](k1, cfg, dtype)}
    ck = _chan_kind(cfg, layer_idx)
    if ck == "rwkvc":
        p["chan"] = rwkv_mod.rwkv_channel_init(k2, cfg, dtype)
    elif ck == "moe":
        p["chan"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["chan"] = mlp_init(k2, d, _dense_ff(cfg, layer_idx), cfg.glu, dtype)
    return p


def _layer_specs(cfg, layer_idx, kind):
    s = {"norm1": norm_specs(cfg.norm), "norm2": norm_specs(cfg.norm),
         "mix": _MIX_SPECS[kind](cfg)}
    ck = _chan_kind(cfg, layer_idx)
    if ck == "rwkvc":
        s["chan"] = rwkv_mod.rwkv_channel_specs(cfg)
    elif ck == "moe":
        s["chan"] = moe_mod.moe_specs(cfg)
    else:
        s["chan"] = mlp_specs(cfg.glu)
    return s


def _layer_apply(lp, h, cfg, kind, layer_idx, *, mode, positions, pos, cache,
                 memo=None, capture=False, mesh=None, dp_axes=("data",),
                 window=None, attn_impl="xla", kpad=None):
    """Returns (h, new_cache, apm, aux_loss)."""
    mask_kind = "causal" if cfg.causal else "bidir"
    if cfg.act_shard_batch and mode == "full" and h.ndim == 3:
        from jax.sharding import PartitionSpec as P
        h = jax.lax.with_sharding_constraint(
            h, P(cfg.act_shard_batch, None, None))
    x = norm_apply(lp["norm1"], h, cfg.norm)
    apm = None
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        win = cfg.sliding_window if cfg.sliding_window else window
        if mode == "decode":
            y, cache = attn.gqa_decode(lp["mix"], x, cfg, cache, pos,
                                       window=win)
        else:
            y, apm = attn.gqa_apply(lp["mix"], x, cfg, positions=positions,
                                    mask_kind=mask_kind, window=win,
                                    memo=memo, return_apm=capture,
                                    attn_impl=attn_impl, kpad=kpad)
            if mode == "prefill":
                cache = attn.gqa_prefill_cache(
                    lp["mix"], x, cfg, positions, cache_len_from(cache))
    elif kind == "mla":
        win = window
        if mode == "decode":
            y, cache = attn.mla_decode(lp["mix"], x, cfg, cache, pos,
                                       window=win)
        else:
            y, apm = attn.mla_apply(lp["mix"], x, cfg, positions=positions,
                                    mask_kind=mask_kind, window=win,
                                    memo=memo, return_apm=capture,
                                    attn_impl=attn_impl, kpad=kpad)
            if mode == "prefill":
                cache = attn.mla_prefill_cache(
                    lp["mix"], x, cfg, positions, cache_len_from(cache))
    elif kind == "rwkv6":
        y, cache_t = rwkv_mod.rwkv_time_apply(
            lp["mix"], x, cfg, None if mode == "full" else cache and
            cache.get("time"),
            impl=(attn_impl if mode == "full" else "scan"))
        cache = dict(cache or {}, time=cache_t)
    elif kind == "rglru":
        y, cache_r = rglru_mod.rglru_apply(
            lp["mix"], x, cfg, None if mode == "full" else cache and
            cache.get("rec"))
        cache = dict(cache or {}, rec=cache_r)
    else:
        raise ValueError(kind)
    if apm is not None:
        # AttMemo capture: the memo key is the attention input hidden state
        apm = {"apm": apm, "hidden": x}
    h = h + y

    x = norm_apply(lp["norm2"], h, cfg.norm)
    ck = _chan_kind(cfg, layer_idx)
    if ck == "rwkvc":
        y, cache_c = rwkv_mod.rwkv_channel_apply(
            lp["chan"], x, cfg, None if mode == "full" else cache and
            cache.get("chan"))
        cache = dict(cache or {}, chan=cache_c)
    elif ck == "moe":
        y, aux = moe_mod.moe_apply(lp["chan"], x, cfg, mesh=mesh,
                                   dp_axes=dp_axes)
    else:
        y = mlp_apply(lp["chan"], x, cfg.act, cfg.glu)
    h = h + y
    return h, cache, apm, aux


def cache_len_from(cache) -> int:
    """Total cache slots from a cache template (prefill pads up to this)."""
    if cache is None:
        return 0
    for v in jax.tree.leaves(cache):
        return v.shape[1]
    return 0


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def layer_cache(cfg, kind, layer_idx, batch, seq, dtype):
    if kind == "attn":
        return attn.gqa_init_cache(cfg, batch, seq, dtype)
    if kind == "mla":
        return attn.mla_init_cache(cfg, batch, seq, dtype)
    if kind == "rwkv6":
        c = {"time": rwkv_mod.rwkv_time_init_state(cfg, batch, dtype),
             "chan": rwkv_mod.rwkv_channel_init_state(cfg, batch, dtype)}
        return c
    if kind == "rglru":
        return {"rec": rglru_mod.rglru_init_state(cfg, batch, dtype)}
    raise ValueError(kind)


def init_caches(cfg, batch, seq, dtype=jnp.float32, window=None):
    """Caches per segment. Attention caches sized min(seq, window)."""
    caches = {}
    attn_len = min(seq, window) if window else seq
    for si, seg in enumerate(scan_plan(cfg)):
        def one(kind, idx):
            s = attn_len if kind in ("attn", "mla") else seq
            if kind == "attn" and cfg.sliding_window:
                s = min(seq, cfg.sliding_window)
            return layer_cache(cfg, kind, idx, batch, s, dtype)
        group = {f"l{u}": one(kind, seg.start + u)
                 for u, kind in enumerate(seg.unit)}
        if seg.kind == "scan":
            group = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.reps,) + a.shape), group)
        caches[f"seg{si}"] = group
    return caches


# ---------------------------------------------------------------------------
# backbone init / specs
# ---------------------------------------------------------------------------

def backbone_init(key, cfg, dtype=jnp.float32):
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab),
                                  dtype=dtype)
    if cfg.n_classes:
        p["cls"] = dense_init(keys[2], (cfg.d_model, cfg.n_classes),
                              dtype=dtype)
    layers = {}
    lkey = keys[3]
    for si, seg in enumerate(scan_plan(cfg)):
        lkey, skey = jax.random.split(lkey)
        def group_init(k):
            ks = jax.random.split(k, len(seg.unit))
            return {f"l{u}": _layer_init(ks[u], cfg, seg.start + u, kind,
                                         dtype)
                    for u, kind in enumerate(seg.unit)}
        if seg.kind == "single":
            layers[f"seg{si}"] = group_init(skey)
        else:
            layers[f"seg{si}"] = jax.vmap(group_init)(
                jax.random.split(skey, seg.reps))
    p["layers"] = layers
    return p


def backbone_specs(cfg):
    s: Dict[str, Any] = {"embed": ("vocab", "embed"),
                         "final_norm": norm_specs(cfg.norm)}
    if not cfg.tie_embeddings:
        s["lm_head"] = ("embed", "vocab")
    if cfg.n_classes:
        s["cls"] = ("embed", None)
    layers = {}
    for si, seg in enumerate(scan_plan(cfg)):
        group = {f"l{u}": _layer_specs(cfg, seg.start + u, kind)
                 for u, kind in enumerate(seg.unit)}
        if seg.kind == "scan":
            group = jax.tree.map(lambda t: ("layers",) + t, group,
                                 is_leaf=lambda t: isinstance(t, tuple))
        layers[f"seg{si}"] = group
    s["layers"] = layers
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg):
    """tokens: int ids (B,S) or precomputed embeddings (B,S,D) (stub
    frontends feed embeddings directly)."""
    if tokens.ndim == 3:
        return tokens.astype(params["embed"].dtype)
    return params["embed"][tokens]


def forward_hidden(params, h, cfg, *, mode="full", positions=None, pos=None,
                   caches=None, memo_plan=None, capture=False,
                   layer_loop="scan", mesh=None, dp_axes=("data",),
                   window=None, attn_impl="xla", remat=False):
    """Run all layers. Returns (h, new_caches, apms{layer_idx: apm}, aux)."""
    apms: Dict[int, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    if positions is None and mode != "decode":
        B, S = h.shape[0], h.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    for si, seg in enumerate(scan_plan(cfg)):
        seg_params = params["layers"][f"seg{si}"]
        seg_caches = caches.get(f"seg{si}") if caches else None

        def group_apply(gp, hh, gcaches, rep_idx=0, allow_capture=False):
            out_caches = {}
            local_apms = {}
            aux_sum = jnp.zeros((), jnp.float32)
            for u, kind in enumerate(seg.unit):
                li = seg.start + rep_idx * len(seg.unit) + u
                memo = memo_plan.get(li) if memo_plan else None
                cap = capture and allow_capture and kind in ("attn", "mla")
                hh, c, apm, aux = _layer_apply(
                    gp[f"l{u}"], hh, cfg, kind, li, mode=mode,
                    positions=positions, pos=pos,
                    cache=gcaches.get(f"l{u}") if gcaches else None,
                    memo=memo, capture=cap, mesh=mesh, dp_axes=dp_axes,
                    window=window, attn_impl=attn_impl)
                out_caches[f"l{u}"] = c
                aux_sum = aux_sum + aux
                if apm is not None:
                    local_apms[li] = apm
            return hh, out_caches, aux_sum, local_apms

        if seg.kind == "single" or layer_loop == "unroll":
            if seg.kind == "single":
                h, c, aux, la = group_apply(seg_params, h, seg_caches,
                                            allow_capture=True)
                aux_total = aux_total + aux
                apms.update(la)
                new_caches[f"seg{si}"] = c
            else:
                cs = []
                for r in range(seg.reps):
                    gp = jax.tree.map(lambda a: a[r], seg_params)
                    gc = (jax.tree.map(lambda a: a[r], seg_caches)
                          if seg_caches else None)
                    h, c, aux, la = group_apply(gp, h, gc, rep_idx=r,
                                                allow_capture=True)
                    aux_total = aux_total + aux
                    apms.update(la)
                    cs.append(c)
                new_caches[f"seg{si}"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *cs)
        else:
            def scan_body(carry, xs):
                hh, aux_acc = carry
                gp, gc = xs
                hh2, c, aux, _ = group_apply(gp, hh, gc)
                return (hh2, aux_acc + aux), c
            body = jax.checkpoint(scan_body) if remat else scan_body
            if seg_caches is None:
                template = {f"l{u}": None for u in range(len(seg.unit))}

                def scan_body_nc(carry, gp):
                    hh, aux_acc = carry
                    hh2, _, aux, _ = group_apply(gp, hh, template)
                    return (hh2, aux_acc + aux), ()
                body_nc = (jax.checkpoint(scan_body_nc) if remat
                           else scan_body_nc)
                (h, aux_total), _ = jax.lax.scan(
                    body_nc, (h, aux_total), seg_params)
                new_caches[f"seg{si}"] = None
            else:
                (h, aux_total), cs = jax.lax.scan(
                    body, (h, aux_total), (seg_params, seg_caches))
                new_caches[f"seg{si}"] = cs
    return h, new_caches, apms, aux_total


def iter_layers(params, cfg):
    """Yield (layer_idx, kind, layer_params) in depth order — used by the
    AttMemo engine to run the network layer-by-layer with host round-trips
    to the index/attention databases."""
    for si, seg in enumerate(scan_plan(cfg)):
        sp = params["layers"][f"seg{si}"]
        if seg.kind == "single":
            for u, kind in enumerate(seg.unit):
                yield seg.start + u, kind, sp[f"l{u}"]
        else:
            for r in range(seg.reps):
                gp = jax.tree.map(lambda a: a[r], sp)
                for u, kind in enumerate(seg.unit):
                    yield (seg.start + r * len(seg.unit) + u, kind,
                           gp[f"l{u}"])


def logits_from_hidden(params, h, cfg):
    h = norm_apply(params["final_norm"], h, cfg.norm)
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def classify_from_hidden(params, h, cfg, kpad=None):
    """``kpad``: optional (B, S) bool validity mask — padded positions are
    excluded from the mean pool so a padded variable-length batch scores
    each sequence exactly like its unpadded run."""
    h = norm_apply(params["final_norm"], h, cfg.norm)
    if kpad is None:
        pooled = jnp.mean(h, axis=1)
    else:
        m = kpad.astype(h.dtype)[:, :, None]
        pooled = jnp.sum(h * m, axis=1) / jnp.maximum(
            jnp.sum(m, axis=1), 1.0)
    return pooled @ params["cls"]

from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.adafactor import adafactor_init, adafactor_update  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401


def make_optimizer(name: str):
    """Returns (init_fn, update_fn) for 'adamw' | 'adafactor'."""
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)

"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    s = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, (s + 1) / max(1, warmup))


def cosine_schedule(step, warmup: int, total: int, peak: float,
                    floor: float = 0.0):
    s = jnp.asarray(step, jnp.float32)
    warm = peak * jnp.minimum(1.0, (s + 1) / max(1, warmup))
    prog = jnp.clip((s - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)

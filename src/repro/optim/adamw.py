"""AdamW in pure JAX (pytree-native, shardable: states mirror params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0, grad_clip=None):
    t = state["t"] + 1
    if grad_clip is not None:
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)) + 1e-12)
        scale = jnp.minimum(1.0, grad_clip / gn)
        grads = jax.tree.map(lambda g: g * scale, grads)
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)
    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}

"""Adafactor (Shazeer & Stern 2018) — factored second moment, no first
moment: the optimizer-state footprint is ~(rows+cols)/(rows·cols) of Adam's,
which is what makes the ≥100B configs trainable within HBM (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params):
    def one(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"s": jax.tree.map(one, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "t": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, *, lr=1e-3, decay=0.8,
                     eps=1e-30, clip_threshold=1.0, weight_decay=0.0,
                     grad_clip=None):
    t = state["t"] + 1
    beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** -decay

    def upd(p, g, s):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p.shape):
            vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            r = (vr / jnp.maximum(denom, eps))[..., None]
            u = g * jax.lax.rsqrt(jnp.maximum(r * vc[..., None, :], eps))
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
            new_s = {"v": v}
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tree.flatten_up_to(state["s"])
    outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tree.unflatten([o[0] for o in outs])
    new_s = tree.unflatten([o[1] for o in outs])
    return new_params, {"s": new_s, "t": t}

from repro.kernels.rwkv6.ops import wkv6_chunked  # noqa: F401

"""Pure-jnp oracle: the sequential RWKV-6 recurrence (same math as
models/rwkv._wkv_scan, reshaped to kernel layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    """r,k,v,w: (BH, S, N); u: (BH, N) → o: (BH, S, N)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (BH, N)
        kv = jnp.einsum("bi,bj->bij", k_t, v_t)
        o_t = jnp.einsum("bi,bij->bj", r_t, s + u[..., None] * kv)
        return w_t[..., None] * s + kv, o_t
    BH, S, N = r.shape
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (r, k, v, w))
    s0 = jnp.zeros((BH, N, N), jnp.float32)
    _, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype)

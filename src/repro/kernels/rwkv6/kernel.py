"""Chunked RWKV-6 wkv kernel (TPU Pallas).

The recurrence
    o_t = r_t · (S_t + diag(u)·k_t v_tᵀ),   S_{t+1} = diag(w_t)·S_t + k_t v_tᵀ
is rewritten per chunk of C steps as three MXU matmuls (linear-attention
chunking with data-dependent per-channel decay):

    L_t   = Σ_{i≤t} log w_i            (in-chunk cumulative log-decay)
    r̃_t  = r_t ⊙ exp(L_{t-1})          k̃_s = k_s ⊙ exp(−L_s)
    o     = tril_strict(r̃ k̃ᵀ) V  +  (Σ r_t u k_t) ⊙ v_t  +  r̃ S
    S'    = diag(exp(L_C)) S + (k ⊙ exp(L_C − L))ᵀ V

The (N×N) state lives in VMEM scratch across the sequential chunk grid —
the whole sequence streams HBM→VMEM once. Numerics: exponents are taken
relative to in-chunk positions only, so magnitudes are bounded by
C·|log w|; RWKV-6's decay parameterization (w = exp(−exp(x)), x ≈ −6 at
init) keeps them small; use moderate C (16–64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)           # (C, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (1, N) head bonus

    lw = jnp.log(jnp.maximum(w, 1e-12))
    L = jnp.cumsum(lw, axis=0)                 # (C, N) inclusive
    r_t = r * jnp.exp(L - lw)                  # decay chunk-start → t-1
    k_t = k * jnp.exp(-L)

    A = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(spos < tpos, A, 0.0)         # strict causal (s < t)
    o = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus (current token, via diag(u))
    o += jnp.sum(r * u * k, axis=-1, keepdims=True) * v
    # inter-chunk state contribution
    o += jax.lax.dot_general(r_t, s_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)

    lc = L[-1]                                 # (N,)
    k_end = k * jnp.exp(lc[None, :] - L)
    s_scr[...] = (jnp.exp(lc)[:, None] * s_scr[...]
                  + jax.lax.dot_general(k_end, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


def wkv6_chunked_bhsn(r, k, v, w, u, *, chunk=32, interpret=False):
    """r,k,v,w: (BH, S, N); u: (BH, N). Returns o: (BH, S, N).
    S must be a multiple of ``chunk`` (pad upstream)."""
    BH, S, N = r.shape
    assert S % chunk == 0
    nc = S // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, N), lambda bh, ic: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, N), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, N), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)

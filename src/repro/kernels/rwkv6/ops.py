"""Jit'd wrapper: model layout (B, S, nh, N) → kernel layout + padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_chunked_bhsn


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(r, k, v, w, u, *, chunk=32, interpret=False):
    """r,k,v,w: (B, S, nh, N); u: (nh, N) → o: (B, S, nh, N)."""
    B, S, nh, N = r.shape
    pad = (-S) % chunk
    if pad:
        # pad with w=1 (no decay), k=0 (no writes) — exact
        ext = lambda t, fill: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)),
                                      constant_values=fill)
        r, k, v, w = ext(r, 0), ext(k, 0), ext(v, 0), ext(w, 1)
    def to_bh(t):
        return t.transpose(0, 2, 1, 3).reshape(B * nh, S + pad, N)
    ub = jnp.broadcast_to(u[None], (B, nh, N)).reshape(B * nh, N)
    o = wkv6_chunked_bhsn(to_bh(r), to_bh(k), to_bh(v), to_bh(w), ub,
                          chunk=chunk, interpret=interpret)
    o = o.reshape(B, nh, S + pad, N).transpose(0, 2, 1, 3)
    return o[:, :S]

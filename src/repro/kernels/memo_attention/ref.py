"""Pure-jnp oracle for memo_attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def memo_attention_q8_ref(q, k, v, db_codes, db_scales, hit_idx, hit, *,
                          causal=True, window=None):
    """Oracle for the fused-dequant (int8 codec) kernel variant: dequantize
    the whole DB up front, then run the f16 oracle — what the kernel must
    match while never materializing the dequantized DB itself."""
    db = (db_codes.astype(jnp.float32)
          * db_scales.astype(jnp.float32)[..., None])
    return memo_attention_ref(q, k, v, db, hit_idx, hit, causal=causal,
                              window=window)


def memo_attention_ref(q, k, v, db_apm, hit_idx, hit, *, causal=True,
                       window=None):
    """q: (B,H,S,d); k,v: (B,Hkv,S,d); db_apm: (N,H,S,S); hit_idx/hit: (B,)."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, S, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * d ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[..., None].T, p, 0.0)
    p = p.reshape(B, H, S, S)
    memo_p = jnp.take(db_apm, hit_idx, axis=0).astype(jnp.float32)
    p = jnp.where((hit == 1)[:, None, None, None], memo_p, p)
    vg = v.astype(jnp.float32)
    pg = p.reshape(B, Hkv, group, S, S)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", pg, vg)
    return out.reshape(B, H, S, d).astype(q.dtype)

"""Fused memoized attention (the paper's hot path, TPU-native).

ONE Pallas dispatch serves the whole mixed hit/miss batch. The grid is
(batch, head, q-tile, k-tile) with three scalar-prefetch operands — the
per-sequence gather index, hit flag and true length — and the hit flag
drives the BlockSpec *index maps*, not just ``pl.when``, so each
program only streams the tiles its path actually consumes:

* hit  — the APM tile is gathered straight out of the HBM-resident
  attention database by ``db_apm[hit_idx[b], h, iq, ik]`` in the
  BlockSpec index_map and consumed by the APM·V matmul in VMEM. The
  gathered APM never materializes in HBM — this is the TPU analogue of
  the paper's mmap zero-copy gathering (DESIGN.md §2). QKᵀ and softmax
  are skipped via ``pl.when`` AND the Q/K index maps alias to block
  (0, 0, 0, 0): Pallas skips a re-fetch when consecutive grid steps map
  to the same block, so a hit program re-uses whatever Q/K tile is
  already resident instead of streaming S·d bytes of keys it would
  ignore through every k-iteration. V still streams — APM·V consumes
  every V tile.
* miss — inline flash attention (online softmax). The APM (and int8
  scale-sliver) index maps alias to block 0 for misses, so a miss moves
  at most ONE boundary DB tile instead of speculatively streaming entry
  0's full tile row per program (the previous design clamped
  ``hit_idx`` to 0 in ops.py and paid that fetch on every miss).

Variable length rides the same dispatch: ``lengths`` (B,) bounds the
miss path's key mask per sequence. The hit path needs no mask — stored
APM rows/cols past an entry's length are hard zeros, and the engine's
length gate only admits hits whose entry length equals the query's.

Quantized DB (DESIGN.md §2.6): with ``db_scales`` the database holds
int8 codes + per-row f16 scales (the ``int8`` APM codec); the kernel
gathers the int8 tile (half the HBM→VMEM bytes) plus its (block_q,)
scale sliver and dequantizes IN VMEM immediately before the APM·V
matmul — the f16 APM never exists anywhere, on either memory level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _memo_kernel(hit_idx_ref, hit_ref, len_ref, q_ref, k_ref, v_ref,
                 apm_ref, *rest, scale, causal, window, block_q, block_k,
                 quantized=False):
    if quantized:      # static: the int8 variant carries a scale sliver
        sc_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
        sc_ref = None
    b = pl.program_id(0)
    iq, ik = pl.program_id(2), pl.program_id(3)
    hit = hit_ref[b] == 1

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    v = v_ref[0, 0].astype(jnp.float32)

    @pl.when(hit)
    def _memo_path():
        apm = apm_ref[0, 0].astype(jnp.float32)          # (block_q, block_k)
        if quantized:
            # fused dequant: int8 codes × per-row scale, in VMEM, right
            # before the APM·V matmul
            apm = apm * sc_ref[0, 0].astype(jnp.float32)[:, None]
        acc_scr[...] += jax.lax.dot_general(
            apm, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(hit))
    def _flash_path():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < len_ref[b]        # per-sequence true length (varlen)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new[:, None]))
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _fin():
        # hit: APM rows already sum to 1 — no normalization
        denom = jnp.where(hit, 1.0, jnp.maximum(l_scr[...], 1e-30))
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def memo_attention_bhsd(q, k, v, db_apm, hit_idx, hit, *, lengths=None,
                        db_scales=None, causal=True, window=None,
                        block_q=128, block_k=128, interpret=False):
    """q: (B, H, S, d); k, v: (B, Hkv, S, d); db_apm: (N, H, S, S) —
    the device-resident attention DB; hit_idx, hit: (B,) int32;
    ``lengths`` (B,) int32 bounds the miss path's key mask per sequence
    (None → every sequence is full-length S).

    ``db_scales`` (N, H, S) f16 switches the DB to the int8 codec:
    ``db_apm`` holds int8 codes and each gathered tile is dequantized in
    VMEM against its per-row scale sliver (fused-dequant gather).

    The hit flag conditions every index map (see module docstring): hit
    programs alias Q/K to one resident tile and stream only APM tiles;
    miss programs alias the APM (and scale sliver) and stream only Q/K/V.
    """
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, \
        "ragged S is padded by ops.memo_attention"
    assert db_apm.shape[-2] == S and db_apm.shape[-1] == S, \
        "DB tiles must cover the (padded) sequence: pad/slice in ops"
    nq, nk = S // block_q, S // block_k
    quantized = db_scales is not None
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)

    kernel = functools.partial(
        _memo_kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, quantized=quantized)

    # Index maps — the aliasing core. A Pallas program whose index map
    # resolves to the same block as the previous grid step re-uses the
    # resident tile; a CONSTANT block for the never-read operand of a
    # path therefore reduces that operand's HBM traffic to (at most) one
    # fetch per hit↔miss boundary in grid order, instead of one per
    # program.
    def q_map(b, h, iq, ik, hit_idx, hit, lens):
        m = hit[b] == 1          # hit never reads Q: alias to block 0
        return (jnp.where(m, 0, b), jnp.where(m, 0, h),
                jnp.where(m, 0, iq), 0)

    def k_map(b, h, iq, ik, hit_idx, hit, lens):
        m = hit[b] == 1          # hit never reads K: alias to block 0
        return (jnp.where(m, 0, b), jnp.where(m, 0, h // group),
                jnp.where(m, 0, ik), 0)

    def v_map(b, h, iq, ik, hit_idx, hit, lens):
        return (b, h // group, ik, 0)      # both paths consume V

    def apm_map(b, h, iq, ik, hit_idx, hit, lens):
        m = hit[b] == 1          # miss never reads the APM: alias to 0
        return (jnp.where(m, hit_idx[b], 0), jnp.where(m, h, 0),
                jnp.where(m, iq, 0), jnp.where(m, ik, 0))

    def sc_map(b, h, iq, ik, hit_idx, hit, lens):
        m = hit[b] == 1          # quantized misses move zero scale bytes
        return (jnp.where(m, hit_idx[b], 0), jnp.where(m, h, 0),
                jnp.where(m, iq, 0))

    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), q_map),
        pl.BlockSpec((1, 1, block_k, d), k_map),
        pl.BlockSpec((1, 1, block_k, d), v_map),
        # the DB gather: data-dependent entry via scalar prefetch
        pl.BlockSpec((1, 1, block_q, block_k), apm_map),
    ]
    operands = [q, k, v, db_apm]
    if quantized:
        in_specs.append(pl.BlockSpec((1, 1, block_q), sc_map))
        operands.append(db_scales)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ik, *_: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(hit_idx.astype(jnp.int32), hit.astype(jnp.int32),
      lengths.astype(jnp.int32), *operands)

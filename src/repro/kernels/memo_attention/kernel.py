"""Fused memoized attention (the paper's hot path, TPU-native).

Per (batch, head, q-tile, k-tile) with per-sequence hit flags scalar-
prefetched:

* hit  — the APM tile is gathered straight out of the HBM-resident
  attention database by ``db_apm[hit_idx[b], h, iq, ik]`` in the BlockSpec
  index_map and consumed by the APM·V matmul in VMEM. The gathered APM
  never materializes in HBM — this is the TPU analogue of the paper's
  mmap zero-copy gathering (DESIGN.md §2). QKᵀ and softmax are skipped
  via ``pl.when``.
* miss — inline flash attention (online softmax), and the (speculatively
  fetched) APM tile is ignored.

Scalar prefetch is what lets the gather index be data-dependent per
sequence while the grid stays static.

Quantized DB (DESIGN.md §2.6): with ``db_scales`` the database holds
int8 codes + per-row f16 scales (the ``int8`` APM codec); the kernel
gathers the int8 tile (half the HBM→VMEM bytes) plus its (block_q,)
scale sliver and dequantizes IN VMEM immediately before the APM·V
matmul — the f16 APM never exists anywhere, on either memory level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _memo_kernel(hit_idx_ref, hit_ref, q_ref, k_ref, v_ref, apm_ref, *rest,
                 scale, causal, window, block_q, block_k, seq_len,
                 quantized=False):
    if quantized:      # static: the int8 variant carries a scale sliver
        sc_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
        sc_ref = None
    b = pl.program_id(0)
    iq, ik = pl.program_id(2), pl.program_id(3)
    hit = hit_ref[b] == 1

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    v = v_ref[0, 0].astype(jnp.float32)

    @pl.when(hit)
    def _memo_path():
        apm = apm_ref[0, 0].astype(jnp.float32)          # (block_q, block_k)
        if quantized:
            # fused dequant: int8 codes × per-row scale, in VMEM, right
            # before the APM·V matmul
            apm = apm * sc_ref[0, 0].astype(jnp.float32)[:, None]
        acc_scr[...] += jax.lax.dot_general(
            apm, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(hit))
    def _flash_path():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new[:, None]))
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _fin():
        # hit: APM rows already sum to 1 — no normalization
        denom = jnp.where(hit, 1.0, jnp.maximum(l_scr[...], 1e-30))
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def memo_attention_bhsd(q, k, v, db_apm, hit_idx, hit, *, db_scales=None,
                        causal=True, window=None, block_q=128, block_k=128,
                        interpret=False):
    """q: (B, H, S, d); k, v: (B, Hkv, S, d); db_apm: (N, H, S, S) —
    the device-resident attention DB; hit_idx, hit: (B,) int32.

    ``db_scales`` (N, H, S) f16 switches the DB to the int8 codec:
    ``db_apm`` holds int8 codes and each gathered tile is dequantized in
    VMEM against its per-row scale sliver (fused-dequant gather)."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, "pad upstream"
    nq, nk = S // block_q, S // block_k
    quantized = db_scales is not None

    kernel = functools.partial(
        _memo_kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=S, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, block_q, d),
                     lambda b, h, iq, ik, *_: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_k, d),
                     lambda b, h, iq, ik, *_: (b, h // group, ik, 0)),
        pl.BlockSpec((1, 1, block_k, d),
                     lambda b, h, iq, ik, *_: (b, h // group, ik, 0)),
        # the DB gather: data-dependent entry via scalar prefetch
        pl.BlockSpec((1, 1, block_q, block_k),
                     lambda b, h, iq, ik, hit_idx, hit:
                     (hit_idx[b], h, iq, ik)),
    ]
    operands = [q, k, v, db_apm]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, iq, ik, hit_idx, hit:
                         (hit_idx[b], h, iq)))
        operands.append(db_scales)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, iq, ik, *_: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(hit_idx.astype(jnp.int32), hit.astype(jnp.int32), *operands)

"""Jit'd wrapper for the fused memo-attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.memo_attention.kernel import memo_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret", "has_scales"))
def _memo_attention_jit(q, k, v, db_apm, db_scales, hit_idx, hit, *, causal,
                        window, block_q, block_k, interpret, has_scales):
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    hit_idx = jnp.where(hit.astype(bool), hit_idx, 0)
    out = memo_attention_bhsd(qt, kt, vt, db_apm, hit_idx, hit,
                              db_scales=db_scales if has_scales else None,
                              causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def memo_attention(q, k, v, db_apm, hit_idx, hit, *, db_scales=None,
                   causal=True, window=None, block_q=128, block_k=128,
                   interpret=None):
    """Model layout: q (B,S,H,dh), k/v (B,S,Hkv,dh), db_apm (N,H,S,S),
    hit_idx/hit (B,). Misses clamp the gather index to 0 (the tile fetch is
    speculative; its result is ignored). With ``db_scales`` (N,H,S) the DB
    is int8-quantized (the ``int8`` APM codec) and tiles dequantize in
    VMEM — the fused-dequant gather (DESIGN.md §2.6). ``interpret=None``
    resolves per backend: Pallas interpreter on CPU, compiled on TPU."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    has_scales = db_scales is not None
    if db_scales is None:      # static placeholder keeps the jit signature
        db_scales = jnp.zeros((1, 1, 1), jnp.float16)
    return _memo_attention_jit(q, k, v, db_apm, db_scales, hit_idx, hit,
                               causal=causal, window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret,
                               has_scales=has_scales)

"""Jit'd wrappers for the fused memo-attention dispatch.

Two interchangeable implementations of one contract (q (B,S,H,dh), k/v
(B,S,Hkv,dh), db (N,H,L,L), hit_idx/hit (B,) → (B,S,H,dh)):

* ``impl="pallas"`` — the tiled kernel (kernel.py): hit-conditioned
  index maps, scalar-prefetched gather, in-VMEM int8 dequant. The
  compile target for TPU/GPU serving and the parity-test subject
  (interpret mode on CPU).
* ``impl="xla"``    — the one-formulation XLA form: full masked probs,
  a ``where(hit)`` combine against the gathered (dequantized) APM rows,
  and ONE AV matmul shared by hits and misses. Semantically identical
  to the kernel; on CPU the Pallas interpreter is ~30x slower than
  XLA's fused ops, so serving uses this form there — the same backend
  split DeviceIndex documents for ``nn_search``.

``impl=None`` resolves per backend ("xla" on CPU, "pallas" otherwise)
unless ``interpret`` was passed explicitly, which pins the Pallas path
(that is how the kernel tests keep testing the kernel).

Ragged sequence lengths are handled HERE (the kernel asserts tile
alignment): q/k/v and the DB tiles are zero-padded up to the block
grid, and the padded key positions are masked through the per-sequence
``lengths`` operand — seq lengths like 96 from varlen buckets no longer
crash kernel mode. Misses never fetch DB tiles at all (the hit flag
aliases the gather index map), so no clamp of ``hit_idx`` is needed.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.memo_attention.kernel import NEG_INF, memo_attention_bhsd


def _pad_axis(x, axis, pad):
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fit_db(db, target, n_tail_dims):
    """Slice or zero-pad the trailing ``n_tail_dims`` sequence dims of a
    DB part to ``target``. Stored APMs are hard zeros past their entry's
    true length (and the engine's length gate only admits exact-length
    matches), so zero padding is exact."""
    L = db.shape[-1]
    if L == target:
        return db
    if L > target:
        sl = (Ellipsis,) + (slice(0, target),) * n_tail_dims
        return db[sl]
    widths = ([(0, 0)] * (db.ndim - n_tail_dims)
              + [(0, target - L)] * n_tail_dims)
    return jnp.pad(db, widths)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret", "has_scales", "has_lengths"))
def _memo_attention_pallas(q, k, v, db_apm, db_scales, hit_idx, hit, lengths,
                           *, causal, window, block_q, block_k, interpret,
                           has_scales, has_lengths):
    B, S, H, dh = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    Sp = -(-S // math.lcm(bq, bk)) * math.lcm(bq, bk)   # ragged → pad up
    q = _pad_axis(q, 1, Sp - S)
    k = _pad_axis(k, 1, Sp - S)
    v = _pad_axis(v, 1, Sp - S)
    db_apm = _fit_db(db_apm, Sp, 2)
    if has_scales:
        db_scales = _fit_db(db_scales, Sp, 1)
    if not has_lengths:        # fixed length: mask exactly the padding
        lengths = jnp.full((B,), S, jnp.int32)
    out = memo_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), db_apm, hit_idx, hit, lengths=lengths,
        db_scales=db_scales if has_scales else None, causal=causal,
        window=window, block_q=bq, block_k=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :S]


@partial(jax.jit, static_argnames=("causal", "window", "has_scales",
                                   "has_lengths"))
def _memo_attention_xla(q, k, v, db_apm, db_scales, hit_idx, hit, lengths, *,
                        causal, window, has_scales, has_lengths):
    """The kernel's math in one XLA dispatch. Numerics mirror the kernel:
    f32 compute, NEG_INF masking with explicit zeroing of fully-masked
    rows, hits consume the raw APM rows (already row-stochastic — no
    renormalization), and ONE probs·V matmul serves both paths."""
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qf = (q.astype(jnp.float32).transpose(0, 2, 1, 3)
          .reshape(B, Hkv, group, S, dh))
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * dh ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    mask = jnp.broadcast_to(mask[None, None, None], (B, 1, 1, S, S))
    if has_lengths:
        mask = mask & (jnp.arange(S)[None, :]
                       < lengths[:, None])[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, -1, keepdims=True)
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    apm = jnp.take(db_apm, hit_idx, axis=0).astype(jnp.float32)
    if has_scales:
        apm = apm * jnp.take(db_scales, hit_idx,
                             axis=0).astype(jnp.float32)[..., None]
    apm = _fit_db(apm, S, 2)
    p = jnp.where((hit == 1)[:, None, None, None],
                  apm, p.reshape(B, H, S, S))
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p.reshape(B, Hkv, group, S, S), vf)
    return (out.reshape(B, H, S, dh).transpose(0, 2, 1, 3).astype(q.dtype))


def memo_attention(q, k, v, db_apm, hit_idx, hit, *, db_scales=None,
                   lengths=None, causal=True, window=None, block_q=128,
                   block_k=128, interpret=None, impl=None):
    """Model layout: q (B,S,H,dh), k/v (B,S,Hkv,dh), db_apm (N,H,L,L),
    hit_idx/hit (B,). With ``db_scales`` (N,H,L) the DB is int8-quantized
    (the ``int8`` APM codec) and tiles dequantize in VMEM — the
    fused-dequant gather (DESIGN.md §2.6). ``lengths`` (B,) serves
    variable-length batches: padded key positions are masked out of the
    miss path per sequence (hit APMs are already zero past their length).

    ``impl`` picks the implementation ("pallas" | "xla", see module
    docstring); None auto-resolves by backend, except that an explicit
    ``interpret`` pins the Pallas path. ``interpret=None`` resolves per
    backend: Pallas interpreter on CPU, compiled on TPU."""
    if impl is None:
        impl = ("pallas" if interpret is not None
                else ("xla" if jax.default_backend() == "cpu" else "pallas"))
    has_scales = db_scales is not None
    has_lengths = lengths is not None
    if db_scales is None:      # static placeholder keeps the jit signature
        db_scales = jnp.zeros((1, 1, 1), jnp.float16)
    if lengths is None:
        lengths = jnp.zeros((q.shape[0],), jnp.int32)
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
    hit_idx = jnp.asarray(hit_idx, jnp.int32)
    hit = jnp.asarray(hit, jnp.int32)
    if impl == "xla":
        return _memo_attention_xla(q, k, v, db_apm, db_scales, hit_idx, hit,
                                   lengths, causal=causal, window=window,
                                   has_scales=has_scales,
                                   has_lengths=has_lengths)
    if impl != "pallas":
        raise ValueError(f"impl must be None|'pallas'|'xla': {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _memo_attention_pallas(q, k, v, db_apm, db_scales, hit_idx, hit,
                                  lengths, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret, has_scales=has_scales,
                                  has_lengths=has_lengths)

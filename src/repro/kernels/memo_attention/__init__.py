from repro.kernels.memo_attention.ops import memo_attention  # noqa: F401

"""Jit'd wrapper: model-layout (B,S,H,dh) → kernel layout, GQA, padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=False):
    """q: (B, S, H, dh); k, v: (B, S, Hkv, dh) → (B, S, H, dh)."""
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)

"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (BH, S, d); k, v: (BHkv, S, d). GQA broadcast by head grouping."""
    BH, S, d = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    qg = q.reshape(BHkv, group, S, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("hgqd,hkd->hgqk", qg, kf) * d ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[None, None, :, None], p, 0.0)
    out = jnp.einsum("hgqk,hkd->hgqd", p, v.astype(jnp.float32))
    return out.reshape(BH, S, d).astype(q.dtype)

"""Blocked online-softmax attention kernel (TPU Pallas).

Forward flash attention with causal / sliding-window masking and GQA via
kv-head index mapping. BlockSpec tiling: (block_q × d) and (block_k × d)
tiles stream HBM→VMEM; the (block_q × block_k) score tile lives only in
VMEM/VREGs; running max / sum / accumulator persist in VMEM scratch across
the sequential k-grid dimension. MXU-aligned default blocks (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, block_q, block_k, seq_len):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # fully-masked rows keep m == NEG_INF; zero their probabilities
    p = jnp.where(s <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new[:, None]))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(jnp.float32), v.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None,
                         block_q=128, block_k=128, interpret=False):
    """q: (BH, S, d); k, v: (BHkv, S, d) with BH = B·H, BHkv = B·Hkv.
    GQA handled by the kv index map. Returns (BH, S, d)."""
    BH, S, d = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    seq_len = S
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=seq_len)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik: (bh // group, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]

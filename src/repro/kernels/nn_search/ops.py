"""Jit'd wrapper for the streaming nn_search kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.nn_search.kernel import nn_search_kernel


@partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def nn_search(q, db, *, block_q=128, block_n=512, interpret=False):
    """Top-1 L2 over the DB. Returns (squared_dists (B,), idx (B,))."""
    return nn_search_kernel(q, db, block_q=block_q, block_n=block_n,
                            interpret=interpret)

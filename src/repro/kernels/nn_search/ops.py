"""Jit'd wrapper for the streaming nn_search kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.nn_search.kernel import nn_search_kernel


@partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def _nn_search_jit(q, db, *, block_q, block_n, interpret):
    return nn_search_kernel(q, db, block_q=block_q, block_n=block_n,
                            interpret=interpret)


def nn_search(q, db, *, block_q=128, block_n=512, interpret=None):
    """Top-1 L2 over the DB. Returns (squared_dists (B,), idx (B,)).

    ``interpret=None`` resolves per backend: the Pallas interpreter on CPU
    (CI), compiled on TPU. Traceable inside an outer jit."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _nn_search_jit(q, db, block_q=block_q, block_n=block_n,
                          interpret=interpret)

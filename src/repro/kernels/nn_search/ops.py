"""Jit'd wrapper for the streaming nn_search kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.nn_search.kernel import nn_search_kernel


@partial(jax.jit, static_argnames=("block_q", "block_n", "interpret",
                                   "has_norms"))
def _nn_search_jit(q, db, db_norms, *, block_q, block_n, interpret,
                   has_norms):
    return nn_search_kernel(q, db,
                            db_norms=db_norms if has_norms else None,
                            block_q=block_q, block_n=block_n,
                            interpret=interpret)


def nn_search(q, db, *, db_norms=None, block_q=128, block_n=512,
              interpret=None):
    """Top-1 L2 over the DB. Returns (squared_dists (B,), idx (B,)).

    ``db_norms`` (N,) f32 optionally carries precomputed per-row ‖d‖²
    (the DeviceIndex caches them per generation) so the kernel streams
    a sliver instead of recomputing the reduction per query tile.

    ``interpret=None`` resolves per backend: the Pallas interpreter on CPU
    (CI), compiled on TPU. Traceable inside an outer jit."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    has_norms = db_norms is not None
    if db_norms is None:       # static placeholder keeps the jit signature
        db_norms = jnp.zeros((1,), jnp.float32)
    return _nn_search_jit(q, db, db_norms, block_q=block_q, block_n=block_n,
                          interpret=interpret, has_norms=has_norms)

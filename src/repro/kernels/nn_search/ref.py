"""Pure-jnp oracle for nn_search."""
from __future__ import annotations

import jax.numpy as jnp


def nn_search_ref(q, db):
    """q: (B, dim), db: (N, dim) → (sq_dists (B,), idx (B,))."""
    qf, df = q.astype(jnp.float32), db.astype(jnp.float32)
    d2 = (jnp.sum(qf * qf, -1, keepdims=True) - 2.0 * qf @ df.T
          + jnp.sum(df * df, -1)[None, :])
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return jnp.take_along_axis(d2, idx[:, None], 1)[:, 0], idx

from repro.kernels.nn_search.ops import nn_search  # noqa: F401

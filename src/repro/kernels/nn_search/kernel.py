"""Streaming L2 top-1 search over a big HBM-resident embedding DB.

Flash-attention-style streaming: the query tile (block_q × dim) stays in
VMEM while DB tiles (block_n × dim) stream HBM→VMEM; squared distances are
one MXU matmul (‖q‖² − 2·q·Dᵀ + ‖d‖²) and the running (min, argmin) lives
in VMEM scratch across the sequential N-grid dimension. This is the index
database's TPU-native search primitive (paper §5.3 uses Faiss HNSW; see
DESIGN.md §2 for why HNSW does not transfer).

``db_norms`` optionally carries precomputed per-row ‖d‖² (the DeviceIndex
caches them per mutation generation): the kernel then streams a (block_n,)
sliver instead of recomputing the reduction over every (block_n, dim) tile
for every query block — the norms are O(N) work total but the naive form
pays O(nb·N·dim) per search.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e30


def _nn_kernel(q_ref, db_ref, *rest, block_q, block_n, n_total, has_norms):
    if has_norms:      # static: precomputed ‖d‖² rides as a sliver
        dn_ref, od_ref, oi_ref, bd_scr, bi_scr = rest
    else:
        od_ref, oi_ref, bd_scr, bi_scr = rest
        dn_ref = None
    iN = pl.program_id(1)

    @pl.when(iN == 0)
    def _init():
        bd_scr[...] = jnp.full_like(bd_scr, BIG)
        bi_scr[...] = jnp.zeros_like(bi_scr)

    q = q_ref[...].astype(jnp.float32)               # (block_q, dim)
    d = db_ref[...].astype(jnp.float32)              # (block_n, dim)
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    dn = (dn_ref[...].astype(jnp.float32) if has_norms
          else jnp.sum(d * d, axis=-1))
    d2 = qn - 2.0 * jax.lax.dot_general(
        q, d, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dn[None, :]
    npos = iN * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_n), 1)
    d2 = jnp.where(npos < n_total, d2, BIG)

    local_min = jnp.min(d2, axis=-1)
    local_arg = (iN * block_n + jnp.argmin(d2, axis=-1)).astype(jnp.int32)
    upd = local_min < bd_scr[...]
    bd_scr[...] = jnp.where(upd, local_min, bd_scr[...])
    bi_scr[...] = jnp.where(upd, local_arg, bi_scr[...])

    @pl.when(iN == pl.num_programs(1) - 1)
    def _fin():
        od_ref[...] = bd_scr[...]
        oi_ref[...] = bi_scr[...]


def nn_search_kernel(q, db, *, db_norms=None, block_q=128, block_n=512,
                     interpret=False):
    """q: (B, dim), db: (N, dim) → (sq_dists (B,), idx (B,)).
    ``db_norms`` (N,) f32: precomputed per-row squared norms (padded rows
    are masked by ``n_total``, so their norm values never matter)."""
    B, dim = q.shape
    N = db.shape[0]
    block_q = min(block_q, B)
    block_n = min(block_n, N)
    pad_b = (-B) % block_q
    pad_n = (-N) % block_n
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0)))
    if pad_n:
        db = jnp.pad(db, ((0, pad_n), (0, 0)))
        if db_norms is not None:
            db_norms = jnp.pad(db_norms, ((0, pad_n),))
    nb = q.shape[0] // block_q
    nN = db.shape[0] // block_n
    has_norms = db_norms is not None

    kernel = functools.partial(_nn_kernel, block_q=block_q, block_n=block_n,
                               n_total=N, has_norms=has_norms)
    in_specs = [
        pl.BlockSpec((block_q, dim), lambda ib, iN: (ib, 0)),
        pl.BlockSpec((block_n, dim), lambda ib, iN: (iN, 0)),
    ]
    operands = [q, db]
    if has_norms:
        in_specs.append(pl.BlockSpec((block_n,), lambda ib, iN: (iN,)))
        operands.append(db_norms.astype(jnp.float32))
    od, oi = pl.pallas_call(
        kernel,
        grid=(nb, nN),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_q,), lambda ib, iN: (ib,)),
            pl.BlockSpec((block_q,), lambda ib, iN: (ib,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((q.shape[0],), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return od[:B], oi[:B]

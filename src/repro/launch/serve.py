"""Serving launcher: batched request loop with optional AttMemo memoization.

    python -m repro.launch.serve --arch bert_base --reduced --requests 64
    python -m repro.launch.serve --arch gpt2_small --reduced --no-memo
    python -m repro.launch.serve --arch bert_base --reduced --online
    python -m repro.launch.serve --arch gpt2_small --reduced --prefill

``--online`` demonstrates the MemoStore lifecycle (DESIGN.md §2.5) under
drifting traffic: the request stream switches template corpus mid-run
(a new phase seed = new clause skeletons), which collapses the hit rate
of a frozen store; with online admission enabled, captured misses are
admitted under the byte budget and delta-synced to the device tier, and
the hit rate recovers. Both passes (frozen first — it does not mutate
the store — then adaptive) run the same phase schedule for an A/B.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import TemplateCorpus
from repro.memo import LEVELS, MemoSession, MemoSpec, MemoStats
from repro.models import build_model
from repro.train.checkpoint import load_checkpoint


def _autotune_threshold(eng, corpus, args, tag):
    """Paper Table 2 levels are per-model: autotune from a FRESH sample
    of the calibration distribution (percentiles of predicted top-1
    similarity). Querying with the calibration batches themselves would
    give degenerate zero-distance percentiles, and the stock 0.97
    threshold can sit above every predicted sim (α = 0 at every layer,
    starving both serving and the selective perf model)."""
    levels = eng.suggest_levels(
        [{"tokens": jnp.asarray(corpus.sample(args.batch)[0])}])
    eng.mc.threshold = levels.get(args.level, eng.mc.threshold)
    print(f"[{tag}] autotuned threshold ({args.level}): "
          f"{eng.mc.threshold:.3f}")


def _run_phase(eng, corpus, n_batches, batch_size, st):
    """Serve one phase; returns (per-batch hit rates, ms/batch list)."""
    rates, times = [], []
    for _ in range(n_batches):
        toks = jnp.asarray(corpus.sample(batch_size)[0])
        h0, a0 = st.n_hits, st.n_layer_attempts
        t0 = time.perf_counter()
        logits, st = eng.infer({"tokens": toks}, stats=st)
        jax.block_until_ready(logits)
        times.append((time.perf_counter() - t0) * 1e3)
        rates.append((st.n_hits - h0) / max(1, st.n_layer_attempts - a0))
    return rates, times, st


def _serve_prefill(eng, model, corpus, args, calib):
    """Prefill-memoization A/B (DESIGN.md §2.13): per batch, time exact
    prefill vs memoized prefill, then decode greedily from BOTH cache
    sets and report parity — a hit must hand back a decode cache the
    backbone cannot tell apart from the one exact prefill built."""
    st = MemoStats()
    lat_memo, lat_exact = [], []
    n_batches = max(1, args.requests // args.batch)
    for _ in range(n_batches):
        batch = {"tokens": jnp.asarray(corpus.sample(args.batch)[0])}
        t0 = time.perf_counter()
        logits_e, _ = eng.prefill_exact(batch)
        jax.block_until_ready(logits_e)
        lat_exact.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        logits_m, _, st = eng.prefill(batch, stats=st)
        jax.block_until_ready(logits_m)
        lat_memo.append(time.perf_counter() - t0)

    p = np.median(lat_exact[1:] or lat_exact) * 1e3
    m = np.median(lat_memo[1:] or lat_memo) * 1e3
    print(f"[prefill] exact        {p:8.1f} ms/batch")
    print(f"[prefill] memoized     {m:8.1f} ms/batch  "
          f"({(1 - m / p) * 100:+.1f}% latency)")
    print(f"[prefill] memo rate    {st.memo_rate*100:8.1f}%  "
          f"(hits {st.n_hits}/{st.n_layer_attempts})")

    # decode parity on a REPLAY of an admitted calibration batch
    # (self-hits): on a hit the decode cache comes from the stored KV
    # entry, so the gap below is pure codec quantization — parity on the
    # novel traffic above would fold in input drift and say nothing
    # about KV fidelity. Both legs are fed the exact leg's tokens
    # (teacher forcing) so one divergent step can't snowball the logits
    # gap; agreement counts how often the memoized leg would have
    # picked the same token anyway.
    replay = calib[0]
    h0, a0 = st.n_hits, st.n_layer_attempts
    le, ce = eng.prefill_exact(replay)
    lm, cm, st = eng.prefill(replay, stats=st)
    print(f"[prefill] replay hits  {st.n_hits - h0}"
          f"/{st.n_layer_attempts - a0}")
    dmax, agree, total = 0.0, 0, 0
    t0 = time.perf_counter()
    for step in range(args.decode_steps):
        tm = jnp.argmax(lm, -1).reshape(-1)
        te = jnp.argmax(le, -1).reshape(-1)
        agree += int((tm == te).sum())
        total += int(te.shape[0])
        pos = jnp.int32(args.seq + step)
        lm, cm = model.decode_step(eng.params, te[:, None], cm, pos)
        le, ce = model.decode_step(eng.params, te[:, None], ce, pos)
        dmax = max(dmax, float(jnp.max(jnp.abs(lm - le))))
    jax.block_until_ready(lm)
    dt = time.perf_counter() - t0
    print(f"[prefill] decode       {args.decode_steps} steps x "
          f"{args.batch} rows in {dt*1e3:.1f} ms "
          f"({args.decode_steps * args.batch / dt:.0f} tok/s)")
    print(f"[prefill] parity       max|Δlogits| {dmax:.2e}, greedy "
          f"agreement {agree}/{total}")


def _serve_online(eng, corpus, args):
    """Drift-phase schedule: phase 0 = the calibration distribution, later
    phases = drifted corpora. Frozen pass first (store untouched), then
    the adaptive pass with admission + delta sync."""
    mk = lambda seed: TemplateCorpus(vocab=eng.cfg.vocab, seq_len=args.seq,
                                     seed=seed, n_templates=corpus.n_templates,
                                     slot_fraction=corpus.slot_fraction)
    phases = [corpus] + [mk(100 + 17 * i) for i in range(1, args.phases)]
    results = {}
    counts0 = eng.db.reuse_counts.copy()
    for label, admit in (("frozen", False), ("adaptive", True)):
        eng.mc.admit = admit
        # identical starting state for both passes: the frozen pass does
        # not admit/evict, but serving still warms reuse_counts (the
        # eviction clock's input) — restore them
        eng.db.reuse_counts[:] = counts0
        st = MemoStats()
        per_phase = []
        for pi, ph in enumerate(phases):
            # fresh sampling stream per pass so both passes see the same
            # requests: re-seed the phase corpus RNG
            ph._rng = np.random.default_rng(1000 + pi)
            rates, times, st = _run_phase(eng, ph, args.phase_batches,
                                          args.batch, st)
            per_phase.append((rates, times))
            tail = np.mean(rates[len(rates) // 2:])
            print(f"[online] {label:8s} phase {pi}: hit-rate "
                  f"{' '.join(f'{r:.2f}' for r in rates)}  "
                  f"(steady {tail:.2f})  {np.median(times):6.1f} ms/batch")
        results[label] = (per_phase, st)
    eng.mc.admit = False

    froz = results["frozen"][0][-1][0]
    adap = results["adaptive"][0][-1][0]
    froz_ss = float(np.mean(froz[len(froz) // 2:]))
    adap_ss = float(np.mean(adap[len(adap) // 2:]))
    s = eng.store.stats
    print(f"[online] post-drift steady-state hit rate: "
          f"adaptive {adap_ss:.2f} vs frozen {froz_ss:.2f} "
          f"({'∞' if froz_ss == 0 else f'{adap_ss / froz_ss:.1f}'}× recovery)")
    print(f"[online] store: {s.n_admitted} admitted, {s.n_evicted} evicted, "
          f"live {eng.store.live_count} "
          f"({eng.store.live_count * eng.store.entry_nbytes / 1e6:.1f} MB"
          + (f" / budget {eng.mc.budget_mb:.0f} MB" if eng.mc.budget_mb
             else "") + ")")
    print(f"[online] sync: {s.n_delta_syncs} delta ({s.bytes_delta/1e6:.2f} "
          f"MB) + {s.n_full_syncs} full ({s.bytes_full/1e6:.2f} MB) + "
          f"{s.n_noop_syncs} no-op; full-resync-per-batch would have moved "
          f"{(s.n_delta_syncs * len(eng.db) * eng.store.entry_nbytes)/1e6:.1f}"
          " MB")
    # logits parity vs the select reference on the final drifted batch
    # (admission paused so the comparison doesn't mutate the store), plus
    # prediction agreement vs the UNmemoized model — the quality check
    # that recovered hits substitute faithfully
    toks = jnp.asarray(phases[-1].sample(args.batch)[0])
    out_fast, _ = eng.infer({"tokens": toks})
    out_plain, _ = eng.infer({"tokens": toks}, use_memo=False)
    mode = eng.mc.mode
    eng.mc.mode = "select"
    out_sel, _ = eng.infer({"tokens": toks})
    eng.mc.mode = mode
    ok = np.allclose(np.asarray(out_fast), np.asarray(out_sel),
                     rtol=2e-3, atol=2e-3)
    agree = float((np.argmax(np.asarray(out_fast), -1)
                   == np.argmax(np.asarray(out_plain), -1)).mean())
    print(f"[online] logits match select: {ok}; "
          f"prediction agreement vs no-memo: {agree:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert_base")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--level", default="moderate",
                    choices=list(LEVELS) + ["custom"])
    ap.add_argument("--threshold", type=float, default=None)
    ap.add_argument("--mode", default="bucket",
                    choices=["select", "bucket", "kernel"])
    ap.add_argument("--index", default="exact",
                    choices=["exact", "ivf", "device"])
    ap.add_argument("--codec", default="int8",
                    choices=["f16", "int8", "lowrank"],
                    help="APM storage codec for both memo tiers "
                         "(DESIGN.md §2.6)")
    ap.add_argument("--apm-rank", type=int, default=None,
                    help="lowrank codec rank (default L//8)")
    ap.add_argument("--device-index", default="auto",
                    choices=["auto", "flat", "clustered"],
                    help="device-tier search: exhaustive matmul vs "
                         "two-stage clustered (IVF); auto flips at "
                         "--cluster-crossover entries")
    ap.add_argument("--cluster-crossover", type=int, default=4096)
    ap.add_argument("--nprobe", type=int, default=16)
    ap.add_argument("--shards", type=int, default=0,
                    help="partition the device memo store over N mesh "
                         "shards (0 = single-device store); run with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N to shard a CPU host")
    ap.add_argument("--shard-hot", type=int, default=32,
                    help="replicated hot-entry set size per shard")
    ap.add_argument("--shard-nprobe", type=int, default=None,
                    help="centroid probes per query when routing to "
                         "shards (default: the store picks)")
    ap.add_argument("--prefill", action="store_true",
                    help="memoized causal prefill (DESIGN.md §2.13): "
                         "serve prefill requests whose hits replay the "
                         "stored KV entry into a decode cache, and A/B "
                         "latency + decode parity vs exact prefill "
                         "(needs a causal arch, e.g. --arch gpt2_small)")
    ap.add_argument("--decode-steps", type=int, default=8,
                    help="--prefill: greedy decode continuation length "
                         "for the parity check")
    ap.add_argument("--kv-codec", default="auto",
                    choices=["auto", "f16", "int8", "lowrank"],
                    help="--prefill: stored-KV codec (auto follows the "
                         "APM codec: f16 base -> f16 KV, else int8)")
    ap.add_argument("--kv-rank", type=int, default=None,
                    help="--prefill: lowrank KV codec rank")
    ap.add_argument("--no-memo", action="store_true")
    ap.add_argument("--no-fast-path", action="store_true",
                    help="force the host-synchronous serving path "
                         "(per-layer lookup round-trips; A/B baseline)")
    ap.add_argument("--varlen", action="store_true",
                    help="serve variable-length padded batches (lengths "
                         "drawn per request; masks flow through memo "
                         "lookup — DESIGN.md §2.7) and check select "
                         "parity on the last batch")
    ap.add_argument("--calib-batches", type=int, default=6)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--selective", action="store_true")
    ap.add_argument("--online", action="store_true",
                    help="drift-phase schedule with online admission "
                         "(MemoStore lifecycle A/B: frozen vs adaptive)")
    ap.add_argument("--phases", type=int, default=2,
                    help="--online: number of corpus phases (first = "
                         "calibration distribution)")
    ap.add_argument("--phase-batches", type=int, default=8,
                    help="--online: batches served per phase")
    ap.add_argument("--budget-mb", type=float, default=256.0,
                    help="--online: store byte budget for admission")
    ap.add_argument("--admit-every", type=int, default=1,
                    help="--online: capture misses every Nth batch")
    ap.add_argument("--save-store", default=None, metavar="PATH",
                    help="persist the built session (store + embedder + "
                         "spec) after calibration/autotune — the "
                         "offline-database leg of warm-start serving")
    ap.add_argument("--load-store", default=None, metavar="PATH",
                    help="warm-start from a saved session instead of "
                         "calibrating (skips build + embedder training)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if args.prefill:
        if args.online or args.varlen:
            raise SystemExit("--prefill is its own serving leg; drop "
                             "--online/--varlen")
        if not cfg.causal:
            raise SystemExit(
                f"--prefill needs a causal (decoder-only) arch; "
                f"{args.arch!r} is bidirectional — try --arch gpt2_small")
    if args.online and not cfg.n_classes:
        cfg = cfg.replace(n_classes=4)
    model = build_model(cfg, layer_loop="unroll")
    if args.ckpt:
        params, _, _ = load_checkpoint(args.ckpt)
    else:
        params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=args.seq, seed=1)
    if args.online and not args.ckpt and cfg.n_classes:
        # a briefly-trained classifier (the paper's BERT/SST-2 analogue):
        # random-init hiddens embed poorly, which understates adaptation
        from repro.optim import adamw_init, adamw_update
        opt = adamw_init(params)

        @jax.jit
        def _step(p, o, b):
            loss, g = jax.value_and_grad(model.classify_loss)(p, b)
            p, o = adamw_update(p, g, o, lr=3e-4)
            return loss, p, o
        for b in corpus.batches(50, 32):
            b = {k: jnp.asarray(v) for k, v in b.items()}
            _, params, opt = _step(params, opt, b)
        print("[online] trained classifier head (50 steps)")

    thr = args.threshold if args.threshold is not None else LEVELS.get(
        args.level, 0.97)
    spec = MemoSpec.flat(
        threshold=thr, mode=args.mode, index_kind=args.index,
        apm_codec=args.codec, apm_rank=args.apm_rank,
        device_index=args.device_index,
        cluster_crossover=args.cluster_crossover, nprobe=args.nprobe,
        device_fast_path=False if args.no_fast_path else None,
        budget_mb=args.budget_mb if args.online else None,
        admit_every=args.admit_every,
        recal_every=2 if args.online else None,
        shards=args.shards, shard_hot=args.shard_hot,
        shard_route_nprobe=args.shard_nprobe,
        **({"prefill_enabled": True, "prefill_kv_codec": args.kv_codec,
            "prefill_kv_rank": args.kv_rank} if args.prefill else {}))
    calib = [{"tokens": jnp.asarray(corpus.sample(args.batch)[0])}
             for _ in range(args.calib_batches)]
    t0 = time.perf_counter()
    if args.load_store:
        sess = MemoSession.load(args.load_store, model, params)
        # STORAGE spec (codec/index/embed shapes) is baked into the
        # file and cannot be overridden; the saved mode supersedes
        # --mode and is re-synced into args so the branches below
        # cannot diverge from the loaded engine. SERVING-POLICY knobs
        # remain the CLI's: threshold (when given) and the online
        # admission settings are applied to the loaded spec exactly as
        # a cold build would have set them.
        print("[serve] note: storage spec (codec/index/embed) comes "
              "from the store file; --codec/--index/--device-index/"
              "--apm-rank are ignored on warm start")
        if sess.spec.runtime.mode != args.mode:
            print(f"[serve] note: saved spec mode "
                  f"{sess.spec.runtime.mode!r} supersedes --mode "
                  f"{args.mode!r}")
            args.mode = sess.spec.runtime.mode
        if args.threshold is not None:
            sess.spec.threshold = args.threshold
        if args.online:
            sess.spec.budget_mb = args.budget_mb
            sess.spec.admit_every = args.admit_every
            sess.spec.recal_every = 2
        print(f"[serve] warm start from {args.load_store} in "
              f"{time.perf_counter()-t0:.2f}s (no calibration)")
    else:
        sess = MemoSession.build(model, params, spec, batches=calib,
                                 key=jax.random.PRNGKey(1))
    eng = sess.engine
    store = sess.store
    print(f"[serve] db: {len(store.db)} entries, "
          f"{store.db.nbytes/1e6:.1f} MB ({store.codec.name}: "
          f"{store.entry_nbytes/store.logical_entry_nbytes:.2f}x f16 "
          f"bytes/entry), ready {time.perf_counter()-t0:.1f}s")
    if args.save_store and not args.online:
        if args.threshold is None:
            _autotune_threshold(eng, corpus, args, "serve")
        sess.save(args.save_store)
        print(f"[serve] session saved -> {args.save_store}")

    if args.prefill:
        if args.threshold is None:
            _autotune_threshold(eng, corpus, args, "prefill")
        _serve_prefill(eng, model, corpus, args, calib)
        return

    if args.online:
        if args.threshold is None:
            _autotune_threshold(eng, corpus, args, "online")
        if args.mode == "select":
            print("[online] note: select mode is the host reference path; "
                  "admission still works but the fast path is bucket/kernel")
        _serve_online(eng, corpus, args)
        if args.save_store:
            # the post-drift ADAPTED store is the artifact worth keeping
            sess.save(args.save_store)
            print(f"[serve] adapted session saved -> {args.save_store}")
        return

    active = None
    if args.selective:
        if args.threshold is None:
            _autotune_threshold(eng, corpus, args, "serve")
        # profiles t_overhead on the path that will serve (the fused-jit
        # lookup on the fast path); infer() below restricts memoization
        # to the layers whose predicted benefit is positive
        pm = eng.profile(calib[0])
        active = pm.active_layers()
        print(pm.summary())
        print("[serve] selective memo active layers:", active)

    if args.varlen and args.no_fast_path:
        raise SystemExit("--varlen is served by the device fast path "
                         "(or --mode select); drop --no-fast-path")
    vl_rng = np.random.default_rng(11)

    def sample_batch():
        toks = np.asarray(corpus.sample(args.batch)[0])
        if not args.varlen:
            return {"tokens": jnp.asarray(toks)}
        # a few distinct lengths per batch: pad tokens past each length
        lens = np.asarray(vl_rng.choice(
            [args.seq, args.seq - 4, args.seq // 2], args.batch), np.int32)
        for i, ln in enumerate(lens):
            toks[i, ln:] = 0
        return {"tokens": jnp.asarray(toks), "lengths": lens}

    lat_memo, lat_plain = [], []
    st = MemoStats()
    n_batches = max(1, args.requests // args.batch)
    batch = None
    for i in range(n_batches):
        batch = sample_batch()
        t0 = time.perf_counter()
        logits, _ = eng.infer(batch, use_memo=False)
        jax.block_until_ready(logits)
        lat_plain.append(time.perf_counter() - t0)
        if not args.no_memo:
            t0 = time.perf_counter()
            logits_m, st = eng.infer(batch, stats=st,
                                     active_layers=active)
            jax.block_until_ready(logits_m)
            lat_memo.append(time.perf_counter() - t0)
    if args.varlen and not args.no_memo and args.mode == "bucket":
        # padded-row parity: the fast path's mask-aware lookup + gather
        # must match the select reference on the same padded batch
        out_fast, _ = eng.infer(batch, active_layers=active)
        mode0, eng.mc.mode = eng.mc.mode, "select"
        out_sel, _ = eng.infer(batch, active_layers=active)
        eng.mc.mode = mode0
        diff = float(np.abs(np.asarray(out_fast)
                            - np.asarray(out_sel)).max())
        print(f"[serve] varlen parity vs select: max|Δlogits| = "
              f"{diff:.2e}")
    # drop warmup batch from stats
    p = np.median(lat_plain[1:] or lat_plain) * 1e3
    print(f"[serve] baseline     {p:8.1f} ms/batch")
    if not args.no_memo:
        m = np.median(lat_memo[1:] or lat_memo) * 1e3
        fast = eng._use_fast_path()
        print(f"[serve] memoized     {m:8.1f} ms/batch  "
              f"({(1 - m / p) * 100:+.1f}% latency)"
              + ("  [device fast path]" if fast else "  [host-sync path]"))
        print(f"[serve] memo rate    {st.memo_rate*100:8.1f}%  "
              f"(hits {st.n_hits}/{st.n_layer_attempts})")
        if fast:
            # fused path: no per-phase timers by design (zero per-layer
            # sync); see benchmarks/serve_fastpath.py for the breakdown
            print(f"[serve] fused serve  {st.t_total:.2f}s total "
                  f"(event-based stats, one barrier/batch)")
        else:
            print(f"[serve] overhead     embed {st.t_embed:.2f}s "
                  f"search {st.t_search:.2f}s fetch {st.t_fetch:.2f}s")
    if getattr(store, "shard_stats", None) is not None:
        ss = store.shard_stats()
        print(f"[serve] shards       {ss['n_shards']} x "
              f"{ss['positions_per_shard']} positions, occupancy "
              f"{ss['occupancy']} (imbalance {ss['imbalance']:.2f}x), "
              f"evictions {ss['n_shard_evictions']}, "
              f"spills {ss['n_spills']}")


if __name__ == "__main__":
    main()

"""Serving launcher: batched request loop with optional AttMemo memoization.

    python -m repro.launch.serve --arch bert_base --reduced --requests 64
    python -m repro.launch.serve --arch gpt2_small --reduced --no-memo

Loads (or trains briefly) a reduced model, builds the attention/index
databases from a calibration stream, then serves batches and reports
latency with/without memoization plus the memo-rate breakdown.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core.engine import LEVELS, MemoConfig, MemoEngine
from repro.data import TemplateCorpus
from repro.models import build_model
from repro.train.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert_base")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--level", default="moderate",
                    choices=list(LEVELS) + ["custom"])
    ap.add_argument("--threshold", type=float, default=None)
    ap.add_argument("--mode", default="bucket",
                    choices=["select", "bucket", "kernel"])
    ap.add_argument("--index", default="exact",
                    choices=["exact", "ivf", "device"])
    ap.add_argument("--no-memo", action="store_true")
    ap.add_argument("--no-fast-path", action="store_true",
                    help="force the host-synchronous serving path "
                         "(per-layer lookup round-trips; A/B baseline)")
    ap.add_argument("--calib-batches", type=int, default=6)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--selective", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg, layer_loop="unroll")
    if args.ckpt:
        params, _, _ = load_checkpoint(args.ckpt)
    else:
        params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=args.seq, seed=1)

    thr = args.threshold if args.threshold is not None else LEVELS.get(
        args.level, 0.97)
    eng = MemoEngine(model, params, MemoConfig(
        threshold=thr, mode=args.mode, index_kind=args.index,
        device_fast_path=False if args.no_fast_path else None))
    calib = [{"tokens": jnp.asarray(corpus.sample(args.batch)[0])}
             for _ in range(args.calib_batches)]
    t0 = time.perf_counter()
    eng.build(jax.random.PRNGKey(1), calib)
    print(f"[serve] db: {len(eng.db)} entries, "
          f"{eng.db.nbytes/1e6:.1f} MB, build {time.perf_counter()-t0:.1f}s")

    active = None
    if args.selective:
        pm = eng.profile(calib[0])
        active = pm.active_layers()
        print("[serve] selective memo active layers:", active)

    lat_memo, lat_plain = [], []
    from repro.core.engine import MemoStats
    st = MemoStats()
    n_batches = max(1, args.requests // args.batch)
    for i in range(n_batches):
        toks = jnp.asarray(corpus.sample(args.batch)[0])
        t0 = time.perf_counter()
        logits, _ = eng.infer({"tokens": toks}, use_memo=False)
        jax.block_until_ready(logits)
        lat_plain.append(time.perf_counter() - t0)
        if not args.no_memo:
            t0 = time.perf_counter()
            logits_m, st = eng.infer({"tokens": toks}, stats=st,
                                     active_layers=active)
            jax.block_until_ready(logits_m)
            lat_memo.append(time.perf_counter() - t0)
    # drop warmup batch from stats
    p = np.median(lat_plain[1:] or lat_plain) * 1e3
    print(f"[serve] baseline     {p:8.1f} ms/batch")
    if not args.no_memo:
        m = np.median(lat_memo[1:] or lat_memo) * 1e3
        fast = eng._use_fast_path()
        print(f"[serve] memoized     {m:8.1f} ms/batch  "
              f"({(1 - m / p) * 100:+.1f}% latency)"
              + ("  [device fast path]" if fast else "  [host-sync path]"))
        print(f"[serve] memo rate    {st.memo_rate*100:8.1f}%  "
              f"(hits {st.n_hits}/{st.n_layer_attempts})")
        if fast:
            # fused path: no per-phase timers by design (zero per-layer
            # sync); see benchmarks/serve_fastpath.py for the breakdown
            print(f"[serve] fused serve  {st.t_total:.2f}s total "
                  f"(event-based stats, one barrier/batch)")
        else:
            print(f"[serve] overhead     embed {st.t_embed:.2f}s "
                  f"search {st.t_search:.2f}s fetch {st.t_fetch:.2f}s")


if __name__ == "__main__":
    main()

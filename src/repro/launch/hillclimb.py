import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Performance hillclimbing (§Perf) — the three selected pairs.

Each iteration is hypothesis → change → re-lower → re-analyse, recorded as
a tagged dry-run JSON next to the baselines:

1. minicpm3-4b × train_4k      (worst useful ratio, 0.09; peak > HBM)
   - it1 vocab padding to a 256 multiple (shardable lm_head/embedding)
   - it2 MLA latent-dim sharding (q_lora/kv_lora → model)
   - it3 activation sharding constraint in the layer scan (peak memory)
2. rwkv6-3b × train_4k         (most collective-bound)
   - it1 replicate time-mix square projections (kill mid-head resharding)
   - it2 + FSDP embeddings over data (vocab 65536 divides cleanly)
3. deepseek-7b × prefill (paper-representative, attention-heavy)
   - it1 memo-bucketed prefill at paper-scale S=2048: the AttMemo
     technique itself, expressed at pod scale — hit sub-batch runs
     APM·V only (device-sharded DB gather), miss sub-batch full attention
   - it2 hit-rate sweep (roofline vs memo rate)

Run:  python -m repro.launch.hillclimb [--pair 1|2|3]
"""
import argparse
import json
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.dryrun import run_one
from repro.launch.mesh import use_mesh
from repro.launch.hlo_utils import collective_bytes, cost_summary
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import abstract_params
from repro.models import attention as attn_mod
from repro.models import backbone as bb
from repro.models import build_model
from repro.sharding.rules import (batch_shardings, logical_to_shardings,
                                  make_rules)

OUT = "experiments/hillclimb"


def _round_up(x, m):
    return (x + m - 1) // m * m


def _save(rec, name):
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    c = rec.get("corrected", {})
    print(f"  {name}: status={rec['status']} "
          f"flops={c.get('flops', 0):.3e} bytes={c.get('bytes', 0):.3e} "
          f"coll={c.get('collective_bytes', 0):.3e} "
          f"peak={rec.get('full', {}).get('peak_bytes', 0)/1e9:.2f}GB")
    return rec


# ---------------------------------------------------------------- pair 1

def pair1():
    print("[pair1] minicpm3-4b x train_4k")
    cfg = get_config("minicpm3_4b")

    # it1: pad vocab so lm_head/embedding shard over model
    cfg_pad = cfg.replace(vocab=_round_up(cfg.vocab, 256))
    _save(run_one("minicpm3_4b", "train_4k", False, tag="it1_pad_vocab",
                  cfg_override=cfg_pad), "minicpm3_train_it1_pad_vocab")

    # it2: + shard the MLA latent dims over model (heads 40 can't shard
    # over 16; the latent contraction dims can: 768/16, 256/16)
    _save(run_one("minicpm3_4b", "train_4k", False,
                  tag="it2_latent_shard", cfg_override=cfg_pad,
                  rules_overrides={"q_lora": "model", "kv_lora": "model"}),
          "minicpm3_train_it2_latent_shard")

    # it3: + FSDP (embed over data) — pulls saved-activation + opt memory
    _save(run_one("minicpm3_4b", "train_4k", False,
                  tag="it3_fsdp", cfg_override=cfg_pad,
                  rules_overrides={"q_lora": "model", "kv_lora": "model",
                                   "embed": "data"}),
          "minicpm3_train_it3_fsdp")


# ---------------------------------------------------------------- pair 2

def pair2():
    print("[pair2] rwkv6-3b x train_4k")
    # it1: replicate time-mix square projections — their model-axis shards
    # (2560/16 = 160) split the 64-wide wkv heads mid-state, forcing
    # resharding collectives around every scan step
    _save(run_one("rwkv6_3b", "train_4k", False, tag="it1_replicate_timemix",
                  rules_overrides={"heads_embed": None}),
          "rwkv6_train_it1_replicate_timemix")

    # it2: + FSDP embeddings (vocab 65536 divides 16 cleanly); grads for
    # the now-replicated time-mix weights all-reduce over data only
    _save(run_one("rwkv6_3b", "train_4k", False, tag="it2_fsdp",
                  rules_overrides={"heads_embed": None, "embed": "data"}),
          "rwkv6_train_it2_fsdp")

    # it3: shard time-mix output dim over data instead (weight-gathered
    # FSDP-style) — tests whether collectives stay gone with less
    # replicated weight memory
    _save(run_one("rwkv6_3b", "train_4k", False, tag="it3_timemix_data",
                  rules_overrides={"heads_embed": "data", "embed": "data"}),
          "rwkv6_train_it3_timemix_data")

    # it4: it1 (replicated time-mix, collective-free recurrence) + shard
    # the scan batch/state over BOTH axes — the 21.5 GB of saved wkv
    # states (4096 steps x (B,40,64,64) bf16) was it1's peak-memory cost;
    # batch 256 divides 256 chips exactly
    cfg4 = get_config("rwkv6_3b").replace(
        act_shard_batch=("data", "model"))
    _save(run_one("rwkv6_3b", "train_4k", False, tag="it4_state_batch_shard",
                  cfg_override=cfg4,
                  rules_overrides={"heads_embed": None}),
          "rwkv6_train_it4_state_batch_shard")


# ---------------------------------------------------------------- pair 3

def _prefill_memo_step(mesh, seq, batch, hit_frac, n_db=64):
    """AttMemo at pod scale: the batch is pre-bucketed (engine-level
    bucketing, DESIGN.md §2) into ``B_hit`` sequences whose APMs come from
    the device-sharded DB (APM·V only — no QKᵀ, no softmax) and ``B_miss``
    running full attention."""
    cfg = get_config("deepseek_7b")
    dp = ("data",)
    model = build_model(cfg, mesh=mesh, dp_axes=dp, layer_loop="unroll")
    rules = make_rules(cfg, mesh)
    params_abs = abstract_params(model)
    params_sh = logical_to_shardings(model.specs(), rules, mesh, params_abs)
    B_hit = _round_up(int(batch * hit_frac), 16) if hit_frac else 0
    B_hit = min(B_hit, batch - 16) if hit_frac < 1.0 else batch
    B_miss = batch - B_hit
    L = cfg.n_layers

    def memo_forward(params, toks_hit, apm_idx, db, toks_miss):
        outs = []
        if toks_hit.shape[0]:
            h = bb.embed_tokens(params, toks_hit, cfg)
            for li, kind, lp in bb.iter_layers(params, cfg):
                x = bb.norm_apply(lp["norm1"], h, cfg.norm)
                apm = jnp.take(db, apm_idx[:, li], axis=0)
                h = h + attn_mod.gqa_apply_memo(lp["mix"], x, cfg, apm)
                x = bb.norm_apply(lp["norm2"], h, cfg.norm)
                from repro.models.layers import mlp_apply
                h = h + mlp_apply(lp["chan"], x, cfg.act, cfg.glu)
            outs.append(bb.logits_from_hidden(params, h[:, -1:], cfg)[:, 0])
        if toks_miss.shape[0]:
            logits, _, _ = model.forward(params, {"tokens": toks_miss})
            outs.append(logits[:, -1])
        return jnp.concatenate(outs, 0)

    db_abs = jax.ShapeDtypeStruct((n_db, cfg.n_heads, seq, seq),
                                  jnp.bfloat16)
    args = (params_abs,
            jax.ShapeDtypeStruct((B_hit, seq), jnp.int32),
            jax.ShapeDtypeStruct((B_hit, L), jnp.int32),
            db_abs,
            jax.ShapeDtypeStruct((B_miss, seq), jnp.int32))
    tok_sh = lambda b: NamedSharding(
        mesh, P("data", None) if b % 16 == 0 and b else P())
    in_sh = (params_sh, tok_sh(B_hit),
             NamedSharding(mesh, P()),
             NamedSharding(mesh, P("data")),       # DB sharded over entries
             tok_sh(B_miss))
    return memo_forward, args, in_sh, {"B_hit": B_hit, "B_miss": B_miss,
                                       "n_db": n_db, "seq": seq}


def pair3():
    print("[pair3] deepseek-7b x prefill (paper-representative)")
    mesh = make_production_mesh()
    seq, batch = 2048, 256          # paper-scale sequence; APM DB feasible
    for tag, hit in (("it0_baseline", 0.0), ("it1_hit50", 0.5),
                     ("it2_hit94", 0.94)):
        fn, args, in_sh, meta = _prefill_memo_step(mesh, seq, batch, hit)
        rec = {"arch": "deepseek_7b", "shape": f"prefill_{seq}",
               "mesh": "pod256", "devices": 256, "tag": tag, "meta": meta,
               "status": "ok"}
        try:
            with use_mesh(mesh):
                compiled = jax.jit(fn, in_shardings=in_sh).lower(
                    *args).compile()
            m = cost_summary(compiled)
            m["collectives"] = collective_bytes(compiled.as_text())
            rec["full"] = m
            rec["corrected"] = {"flops": m["flops"], "bytes": m["bytes"],
                                "collective_bytes": m["collectives"]["total"]}
        except Exception as e:  # noqa: BLE001
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
        _save(rec, f"deepseek_prefill2k_{tag}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", type=int, default=0)
    args = ap.parse_args()
    if args.pair in (0, 1):
        pair1()
    if args.pair in (0, 2):
        pair2()
    if args.pair in (0, 3):
        pair3()


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the production meshes; print
memory_analysis() and cost_analysis(); extract roofline terms.

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count at first init. Do NOT set it globally: smoke tests and
benchmarks should see 1 device.

Scan correction (DESIGN.md §7): HLO cost analysis counts a while body once,
so per-unit costs come from python-unrolled 1-unit vs 2-unit variants of the
same config at full width; the reported totals are
    corrected = unroll(1 unit) + (reps − 1) · [unroll(2 units) − unroll(1)]
The full scanned compile still proves lowering + provides memory_analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only] [--out DIR]
"""
import argparse
import json
import time
import traceback
from dataclasses import replace

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import EncoderConfig
from repro.launch.hlo_utils import collective_bytes, cost_summary
from repro.launch.mesh import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

ASSIGNED = [a for a in ARCH_IDS if a not in ("bert_base", "gpt2_small")]


def unit_info(cfg):
    unit = len(cfg.layer_pattern) if cfg.layer_pattern != ("mix",) else 1
    start = cfg.dense_first_n
    reps = (cfg.n_layers - start) // unit
    tail = cfg.n_layers - start - reps * unit
    return unit, start, reps, tail


def small_variant(cfg, n_units: int):
    """Same config at full width with ``n_units`` scan repeats (leading
    dense layers and hybrid tails preserved)."""
    unit, start, reps, tail = unit_info(cfg)
    cfg2 = cfg.replace(n_layers=start + unit * n_units + tail)
    if cfg.encoder is not None:
        cfg2 = cfg2.replace(encoder=replace(cfg.encoder, n_layers=n_units))
    return cfg2


def lower_and_compile(arch, shape_name, mesh, *, cfg=None, layer_loop="scan",
                      rules_overrides=None, verbose=False, donate=False):
    built = build_step(arch, shape_name, mesh, rules_overrides=rules_overrides,
                       cfg=cfg)
    if built is None:
        return None, None
    built["model"].layer_loop = layer_loop
    # donate params/opt (train) or caches (decode) — the launchers'
    # production configuration; halves the resident footprint
    donate_argnums = ()
    if donate:
        kind = built["meta"]["kind"]
        donate_argnums = (0, 1) if kind == "train" else (
            (2,) if kind == "decode" else ())
    with use_mesh(mesh):
        jit_fn = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                         out_shardings=built["out_shardings"],
                         donate_argnums=donate_argnums)
        lowered = jit_fn.lower(*built["args"])
        compiled = lowered.compile()
    metrics = cost_summary(compiled)
    metrics["collectives"] = collective_bytes(compiled.as_text())
    if verbose:
        print("  memory_analysis:", compiled.memory_analysis())
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {}
        print("  cost_analysis: flops=%.3e bytes=%.3e" % (
            ca.get("flops", 0), ca.get("bytes accessed", 0)))
    return built, metrics


def run_one(arch, shape_name, multi_pod, *, correct_scan=True,
            rules_overrides=None, verbose=True, tag="", cfg_override=None,
            donate=False):
    mesh_name = "pod512" if multi_pod else "pod256"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "devices": 512 if multi_pod else 256, "tag": tag}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override or get_config(arch)
    try:
        built, metrics = lower_and_compile(
            arch, shape_name, mesh, cfg=cfg_override,
            rules_overrides=rules_overrides, verbose=verbose,
            donate=donate)
        if built is None:
            rec["status"] = "skipped"
            rec["reason"] = ("long_500k needs a sub-quadratic variant; "
                             "this arch has none configured")
            return rec
        rec["meta"] = built["meta"]
        rec["full"] = metrics
        unit, start, reps, tail = unit_info(cfg)
        rec["scan_reps"] = reps
        if correct_scan and reps > 1:
            _, m1 = lower_and_compile(arch, shape_name, mesh,
                                      cfg=small_variant(cfg, 1),
                                      layer_loop="unroll",
                                      rules_overrides=rules_overrides)
            _, m2 = lower_and_compile(arch, shape_name, mesh,
                                      cfg=small_variant(cfg, 2),
                                      layer_loop="unroll",
                                      rules_overrides=rules_overrides)
            corr = {}
            for k in ("flops", "bytes", "transcendentals"):
                d = m2[k] - m1[k]
                corr[k] = m1[k] + (reps - 1) * d
            dcoll = (m2["collectives"]["total"]
                     - m1["collectives"]["total"])
            corr["collective_bytes"] = (m1["collectives"]["total"]
                                        + (reps - 1) * dcoll)
            rec["unit1"] = {k: m1[k] for k in ("flops", "bytes")}
            rec["unit1"]["collective_bytes"] = m1["collectives"]["total"]
            rec["unit2"] = {k: m2[k] for k in ("flops", "bytes")}
            rec["unit2"]["collective_bytes"] = m2["collectives"]["total"]
            rec["corrected"] = corr
        else:
            rec["corrected"] = {
                "flops": metrics["flops"], "bytes": metrics["bytes"],
                "collective_bytes": metrics["collectives"]["total"]}
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report compile failures as data
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--no-correct", action="store_true")
    ap.add_argument("--donate", action="store_true",
                    help="donate input buffers (production default; the "
                         "committed baselines are conservative non-donated)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True]
    if args.multi_pod or args.multi_pod_only:
        meshes = [True]
    elif args.single_pod_only:
        meshes = [False]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}_{shape}_{'pod512' if mp else 'pod256'}"
                path = os.path.join(args.out, key + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip existing] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                # multi-pod pass proves lowering only; corrections are for
                # the single-pod roofline table
                rec = run_one(arch, shape, mp, donate=args.donate,
                              correct_scan=(not args.no_correct) and not mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"   -> {rec['status']} ({rec['elapsed_s']}s)"
                      + (f"  {rec.get('error', '')}"
                         if rec["status"] == "error" else ""), flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n{n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed / {len(results)}")


if __name__ == "__main__":
    main()

"""Step-function builders for the dry-run / launchers.

For every (arch, input-shape, mesh) this produces:
    fn            — train_step | prefill | serve_step (one token)
    args          — ShapeDtypeStruct stand-ins (no allocation)
    in_shardings  — NamedShardings for every arg
    out_shardings — for the step outputs
so ``jax.jit(fn, in_shardings=...).lower(*args).compile()`` is the whole
multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import dp_axes_of
from repro.models import build_model
from repro.optim import make_optimizer
from repro.sharding.rules import (
    batch_shardings, logical_to_shardings, make_rules)

PARAM_DTYPE = jnp.bfloat16


def sub_quadratic_window(cfg, shape) -> Tuple[Optional[int], bool]:
    """(window, supported) for the given input shape. long_500k requires a
    sub-quadratic configuration: native for ssm/hybrid, sliding-window for
    the rest (DESIGN.md §4)."""
    if shape.name != "long_500k":
        return None, True
    if cfg.mixer == "rwkv6" or cfg.mixer == "rglru_hybrid":
        return None, True                   # natively sub-quadratic
    if cfg.decode_window:
        return cfg.decode_window, True
    return None, False


def abstract_params(model, dtype=PARAM_DTYPE):
    return jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype=dtype))


def opt_spec_tree(opt_name: str, param_specs):
    is_tuple = lambda t: isinstance(t, tuple)
    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs, "t": ()}

    def leafspec(names):
        if len(names) >= 2:
            return {"vr": names[:-1], "vc": names[:-2] + names[-1:]}
        return {"v": names}
    return {"s": jax.tree.map(leafspec, param_specs, is_leaf=is_tuple),
            "t": ()}


def _cache_pspec(path, leaf, mesh, dp, model_axis="model") -> P:
    """Sharding spec for one decode-cache leaf, by key name + rank."""
    key = None
    for p in reversed(path):
        if hasattr(p, "key"):
            key = p.key
            break
    nd = leaf.ndim
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape.get(a, 1)
    msz = mesh.shape.get(model_axis, 1)
    dp_ax = dp if len(dp) > 1 else dp[0]

    def b_ax(b):
        return dp_ax if (b % dp_size == 0 and b >= dp_size) else None

    spec = [None] * nd
    if key in ("k", "v", "ck", "cv"):
        b, s = leaf.shape[nd - 4], leaf.shape[nd - 3]
        spec[nd - 4] = b_ax(b)
        if spec[nd - 4] is None and s % (dp_size * msz) == 0:
            spec[nd - 3] = dp + (model_axis,)  # B=1 long-context
        elif s % msz == 0 and s >= msz:
            spec[nd - 3] = model_axis
    elif key in ("c_kv", "k_rope"):
        b, s = leaf.shape[nd - 3], leaf.shape[nd - 2]
        spec[nd - 3] = b_ax(b)
        if spec[nd - 3] is None and s % (dp_size * msz) == 0:
            spec[nd - 2] = dp + (model_axis,)
        elif s % msz == 0 and s >= msz:
            spec[nd - 2] = model_axis
    elif key == "s":                        # rwkv state (..,B,nh,N,N)
        b, nh = leaf.shape[nd - 4], leaf.shape[nd - 3]
        spec[nd - 4] = b_ax(b)
        if nh % msz == 0:
            spec[nd - 3] = model_axis
    elif key in ("x_prev", "h"):            # (..,B,D)
        spec[nd - 2] = b_ax(leaf.shape[nd - 2])
        if leaf.shape[nd - 1] % msz == 0:
            spec[nd - 1] = model_axis
    elif key == "conv":                     # (..,B,W-1,dr)
        spec[nd - 3] = b_ax(leaf.shape[nd - 3])
        if leaf.shape[nd - 1] % msz == 0:
            spec[nd - 1] = model_axis
    return P(*spec)


def cache_shardings_for(caches_abs, mesh, dp):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _cache_pspec(path, leaf,
                                                            mesh, dp)),
        caches_abs)


def batch_abstract(cfg, shape, kind: str):
    B, S = shape.global_batch, shape.seq_len
    if kind == "decode":
        S = 1
    batch: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.encoder is not None and kind != "decode":
        e = cfg.encoder
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, e.n_frames, e.d_model), PARAM_DTYPE)
    return batch


def build_step(arch: str, shape_name: str, mesh, *, rules_overrides=None,
               lr: float = 1e-4, cfg=None):
    """Returns dict(fn, args, in_shardings, out_shardings, cfg, meta) or
    None if the (arch, shape) pair is skipped (documented in DESIGN.md)."""
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    dp = dp_axes_of(mesh)
    window, ok = sub_quadratic_window(cfg, shape)
    if not ok:
        return None
    kind = shape.kind
    max_seq = min(shape.seq_len, 32_768) if cfg.encoder is not None else 4096
    if window:
        max_seq = min(max_seq, window)
    model = build_model(cfg, mesh=mesh, dp_axes=dp, remat=(kind == "train"),
                        max_seq=max_seq)
    rules = make_rules(cfg, mesh, overrides=rules_overrides)
    params_abs = abstract_params(model)
    param_specs = model.specs()
    params_sh = logical_to_shardings(param_specs, rules, mesh,
                                    params_abs)
    meta = {"arch": arch, "shape": shape_name, "kind": kind,
            "window": window, "n_layers": cfg.n_layers}

    if kind == "train":
        opt_init, opt_update = make_optimizer(cfg.optimizer)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        opt_sh = logical_to_shardings(
            opt_spec_tree(cfg.optimizer, param_specs), rules, mesh, opt_abs)
        batch_abs = batch_abstract(cfg, shape, kind)
        batch_sh = batch_shardings(batch_abs, mesh, dp)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
            params, opt_state = opt_update(params, grads, opt_state, lr=lr,
                                           grad_clip=1.0)
            return params, opt_state, loss

        return dict(
            fn=train_step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, NamedSharding(mesh, P())),
            cfg=cfg, model=model, meta=meta)

    if kind == "prefill":
        batch_abs = batch_abstract(cfg, shape, kind)
        batch_sh = batch_shardings(batch_abs, mesh, dp)
        caches_abs = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len,
                                      dtype=PARAM_DTYPE, window=window))
        caches_sh = cache_shardings_for(caches_abs, mesh, dp)

        def prefill(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len,
                                 window=window, dtype=PARAM_DTYPE)

        return dict(
            fn=prefill,
            args=(params_abs, batch_abs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=(batch_shardings(
                jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab),
                                     jnp.float32), mesh, dp), caches_sh),
            cfg=cfg, model=model, meta=meta)

    # decode
    B = shape.global_batch
    cache_len = min(shape.seq_len, window) if window else shape.seq_len
    tokens_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    caches_abs = jax.eval_shape(
        lambda: model.init_caches(B, cache_len, dtype=PARAM_DTYPE,
                                  window=window))
    caches_sh = cache_shardings_for(caches_abs, mesh, dp)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    meta["cache_len"] = cache_len

    def serve_step(params, tokens, caches, pos):
        return model.decode_step(params, tokens, caches, pos, window=window)

    return dict(
        fn=serve_step,
        args=(params_abs, tokens_abs, caches_abs, pos_abs),
        in_shardings=(params_sh,
                      batch_shardings(tokens_abs, mesh, dp),
                      caches_sh, NamedSharding(mesh, P())),
        out_shardings=(batch_shardings(
            jax.ShapeDtypeStruct((B, cfg.vocab), jnp.float32), mesh, dp),
            caches_sh),
        cfg=cfg, model=model, meta=meta)

"""Open-loop serving launcher — the MemoServer runtime (DESIGN.md §2.7).

    python -m repro.launch.server --arch bert_base --reduced --requests 96
    python -m repro.launch.server --maintenance sync      # baseline A/B leg

Generates a Poisson-arrival request stream with variable lengths and a
mid-run corpus drift (new clause skeletons), serves it through the
length-bucketed continuous-batching runtime, and reports open-loop
throughput + p50/p99 latency. With ``--maintenance both`` (default) the
same trace is served twice — synchronous batch-boundary maintenance vs
the off-thread worker — on identically rebuilt engines, isolating the
compute/maintenance overlap that the async runtime buys.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.memo import CHAOS_PRESETS, LEVELS, MemoSession, MemoSpec
from repro.models import build_model


def make_workload(corpora, n_requests: int, rate: float, buckets,
                  seed: int = 0):
    """Poisson arrivals at ``rate`` req/s; each request picks a bucket,
    draws a length just under it (several distinct lengths per bucket, so
    the length-gated store must adapt per length), and takes its tokens
    from the corpus phase active at that point in the stream — the drift
    that keeps admission/eviction/recal busy."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
    arrivals = np.cumsum(gaps)
    per_phase = max(1, n_requests // len(corpora))
    wl = []
    for i in range(n_requests):
        corpus = corpora[min(i // per_phase, len(corpora) - 1)]
        bucket = int(rng.choice(buckets))
        length = bucket - int(rng.integers(0, max(1, bucket // 8)))
        toks = corpus.sample(1, rng)[0][0, :length]
        wl.append((float(arrivals[i]), toks))
    return wl


def build_session(args, seed: int = 0):
    """A freshly built session per A/B leg: both legs must start from the
    identical calibration store (serving mutates it)."""
    cfg = get_reduced(args.arch)
    if not cfg.n_classes:
        cfg = cfg.replace(n_classes=4)
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(seed))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=args.seq, seed=1)
    thr = args.threshold if args.threshold is not None else LEVELS.get(
        args.level, 0.97)
    fault = getattr(args, "fault", None)
    # Disk chaos classes need a capacity tier attached or their
    # capacity.* fault points have nothing to fire in (DESIGN.md §2.11).
    cap_dir = None
    if fault and any(p.startswith("capacity.")
                     for p in CHAOS_PRESETS.get(fault, {})):
        cap_dir = tempfile.mkdtemp(prefix="memo_fault_capacity_")
    spec = MemoSpec.flat(
        threshold=thr, mode="bucket", apm_codec=args.codec,
        admit=True, budget_mb=args.budget_mb,
        admit_every=args.admit_every, recal_every=2,
        device_slack=8.0, embed_steps=args.embed_steps,
        capacity_dir=cap_dir, capacity_checkpoint_every=1,
        faults=({} if fault else None))
    calib = [{"tokens": jnp.asarray(corpus.sample(args.batch)[0])}
             for _ in range(args.calib_batches)]
    sess = MemoSession.build(model, params, spec, batches=calib,
                             key=jax.random.PRNGKey(1))
    if args.threshold is None and args.level in LEVELS:
        sess.autotune(
            [{"tokens": jnp.asarray(corpus.sample(args.batch)[0])}],
            level=args.level)
    return sess, corpus


def probe_rate(sess: MemoSession, *, buckets, max_batch: int, seq: int,
               utilization: float = 0.7) -> float:
    """Size the open loop near (below) capacity by timing one warm
    batch at the REAL sync-mode serving cost — miss capture + inline
    admission + delta sync included (excluding maintenance overstates
    capacity ~3x and the trace saturates the queue), so the loaded-but-
    stable regime surfaces maintenance stalls in the latency tail.

    The probe therefore MUTATES the store (its misses are admitted):
    callers comparing A/B legs must probe a throwaway session or rebuild
    after probing."""
    eng = sess.engine
    server = sess.serve(buckets=tuple(buckets),
                        max_batch=max_batch, async_maintenance=False)
    server.warmup()
    # two all-miss batches (fresh random junk each round, so round 2
    # cannot hit round 1's admissions): the first pays the
    # maintenance-path XLA compiles (delta-sync scatters, index assign)
    # warmup() doesn't cover; only the second reflects steady-state
    # serve + maintenance cost
    rng = np.random.default_rng(0)
    dt = 0.0
    for _ in range(2):
        toks = rng.integers(1, eng.cfg.vocab,
                            (max_batch, seq)).astype(np.int32)
        t0 = time.perf_counter()
        for i in range(max_batch):
            server.submit(toks[i, : seq - 1])
        server.step(flush=True)
        dt = time.perf_counter() - t0
    server.close()
    return utilization * max_batch / max(dt, 1e-6)


def serve_trace(sess: MemoSession, workload, *, buckets, max_batch: int,
                max_delay: float, async_maintenance: bool):
    """Serve one open-loop trace and summarize it — the shared A/B leg
    (CLI launcher and benchmarks/serve_runtime.py)."""
    server = sess.serve(buckets=tuple(buckets), max_batch=max_batch,
                        max_delay=max_delay,
                        async_maintenance=async_maintenance)
    server.warmup()
    t0 = time.perf_counter()
    with server:
        comps = server.run(workload)
    wall = time.perf_counter() - t0
    lats = np.asarray([c.latency for c in comps]) * 1e3
    st = server.stats
    return {
        "n_requests": len(comps),
        "throughput_rps": float(len(comps) / wall),
        "p50_ms": float(np.percentile(lats, 50)),
        "p99_ms": float(np.percentile(lats, 99)),
        "mean_ms": float(lats.mean()),
        "hit_rate": float(st.memo_rate),
        "n_admitted": int(st.n_admitted),
        "n_batches": int(server.n_batches),
        "filler_rows": int(server.n_filler_rows),
    }


def run_fault_demo(args):
    """``--fault <class>``: one warm → fault → recover trace through the
    supervised runtime, narrating the health ladder (DESIGN.md §2.9)."""
    sess, corpus = build_session(args)
    rate = args.rate
    if rate is None:
        rate = probe_rate(sess, buckets=args.bucket_list,
                          max_batch=args.batch, seq=args.seq)
        stale = sess.spec.capacity.dir
        sess, corpus = build_session(args)   # the probe mutated the store
        if stale:                            # the probe leg's tier dir
            shutil.rmtree(stale, ignore_errors=True)
    inj = sess.engine.faults
    try:
        preset = CHAOS_PRESETS[args.fault]
    except KeyError:
        raise SystemExit(
            f"unknown chaos class {args.fault!r}; known classes: "
            f"{sorted(CHAOS_PRESETS)}") from None
    n = max(3, args.requests // 3)
    server = sess.serve(buckets=args.bucket_list, max_batch=args.batch,
                        max_delay=args.max_delay_ms * 1e-3,
                        async_maintenance=True)
    server.warmup()
    print(f"[server] chaos class {args.fault!r}: arming {preset} "
          f"for the middle third of {3 * n} requests "
          f"(Poisson {rate:.1f} req/s)")
    logged = 0

    def flush_health():
        # health_log is a BOUNDED ring: diff against the transition
        # counter, not the log length, so narration survives wraparound
        nonlocal logged
        log = list(server.health_log)
        fresh = server.n_health_transitions - logged
        if fresh > len(log):
            print(f"[health] ... {fresh - len(log)} transition(s) "
                  f"aged out of the ring ...")
        for t, health, why in log[max(0, len(log) - fresh):]:
            print(f"[health] t={t:7.3f}s  -> {health}: {why}")
        logged = server.n_health_transitions

    completed = 0
    with server:
        for phase, armed in (("warm", False), ("fault", True),
                             ("recovered", False)):
            if armed:
                for point, kw in preset.items():
                    inj.arm(point, **kw)
            elif phase == "recovered":
                inj.disarm()
                try:
                    server.drain_maintenance(timeout=10,
                                             raise_errors=False)
                except Exception:  # noqa: BLE001 — timeout/dead worker
                    pass
                info = server.recover()
                print(f"[server] recover(): {info}")
            comps = server.run(make_workload([corpus], n, rate,
                                             args.bucket_list, seed=7))
            completed += len(comps)
            flush_health()
            print(f"[server] {phase:9s}: {len(comps)}/{n} completed, "
                  f"health {server.health.value}, "
                  f"hit {server.stats.memo_rate * 100:.1f}% (cumulative)")
        server.drain_maintenance(timeout=30, raise_errors=False)
        flush_health()
    print(f"[server] chaos done: {completed}/{3 * n} requests served, "
          f"shed {server.n_maint_shed}, retries {server.n_maint_retries}, "
          f"exact batches {server.n_exact_batches}, "
          f"quarantined {sess.store.stats.n_quarantined}, "
          f"final health {server.health.value}")
    tail = list(server.health_log)[-5:]
    print(f"[server] last {len(tail)} of {server.n_health_transitions} "
          f"health transition(s):")
    for t, health, why in tail:
        print(f"[server]   t={t:7.3f}s  -> {health}: {why}")
    if sess.spec.capacity.dir:
        shutil.rmtree(sess.spec.capacity.dir, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert_base")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="(always on — this launcher only serves reduced "
                         "configs; kept for arg parity with launch.serve)")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate, req/s (default: sized to "
                         "~70%% of measured serve capacity)")
    ap.add_argument("--batch", type=int, default=8,
                    help="max batch per bucket (also calibration batch)")
    ap.add_argument("--seq", type=int, default=48,
                    help="max sequence length (largest bucket)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated length buckets (default: "
                         "seq/2, seq)")
    ap.add_argument("--max-delay-ms", type=float, default=4.0)
    ap.add_argument("--level", default="aggressive", choices=list(LEVELS))
    ap.add_argument("--threshold", type=float, default=None)
    ap.add_argument("--codec", default="int8",
                    choices=["f16", "int8", "lowrank"])
    ap.add_argument("--budget-mb", type=float, default=256.0)
    ap.add_argument("--admit-every", type=int, default=1)
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--embed-steps", type=int, default=120)
    ap.add_argument("--phases", type=int, default=2,
                    help="corpus drift phases across the trace")
    ap.add_argument("--maintenance", default="both",
                    choices=["both", "sync", "async"])
    ap.add_argument("--fault", default=None,
                    choices=sorted(CHAOS_PRESETS),
                    help="chaos demo: serve warm, arm this fault class "
                         "mid-trace, recover(), printing every health "
                         "transition (DESIGN.md §2.9)")
    args = ap.parse_args()
    args.bucket_list = (tuple(int(b) for b in args.buckets.split(","))
                        if args.buckets else (args.seq // 2, args.seq))
    if args.fault:
        run_fault_demo(args)
        return

    results = {}
    modes = (["sync", "async"] if args.maintenance == "both"
             else [args.maintenance])
    workload = None
    for mode in modes:
        sess, corpus = build_session(args)
        if workload is None:
            phases = [corpus] + [
                TemplateCorpus(vocab=sess.engine.cfg.vocab,
                               seq_len=args.seq,
                               seed=100 + 17 * i,
                               n_templates=corpus.n_templates,
                               slot_fraction=corpus.slot_fraction)
                for i in range(1, args.phases)]
            rate = args.rate
            if rate is None:
                rate = probe_rate(sess, buckets=args.bucket_list,
                                  max_batch=args.batch, seq=args.seq)
                # the probe admitted its misses: rebuild so every A/B
                # leg starts from the identical calibration store
                sess, corpus = build_session(args)
            workload = make_workload(phases, args.requests, rate,
                                     args.bucket_list, seed=7)
            print(f"[server] {args.requests} requests, Poisson "
                  f"{rate:.1f} req/s, buckets {args.bucket_list}, "
                  f"max_batch {args.batch}, drift phases {args.phases}")
        r = serve_trace(sess, workload, buckets=args.bucket_list,
                        max_batch=args.batch,
                        max_delay=args.max_delay_ms * 1e-3,
                        async_maintenance=(mode == "async"))
        results[mode] = r
        print(f"[server] {mode:5s} maintenance: "
              f"{r['throughput_rps']:6.1f} req/s  "
              f"p50 {r['p50_ms']:7.1f} ms  p99 {r['p99_ms']:7.1f} ms  "
              f"hit {r['hit_rate']*100:5.1f}%  "
              f"admitted {r['n_admitted']}  batches {r['n_batches']}")
    if len(results) == 2:
        s, a = results["sync"], results["async"]
        print(f"[server] async vs sync: p99 {a['p99_ms']/s['p99_ms']:.2f}x"
              f"  p50 {a['p50_ms']/s['p50_ms']:.2f}x  "
              f"(hit rate {a['hit_rate']*100:.1f}% vs "
              f"{s['hit_rate']*100:.1f}%)")


if __name__ == "__main__":
    main()

"""Training launcher.

Two modes:
* real CPU training of reduced configs (the end-to-end example path):
    python -m repro.launch.train --arch gpt2_small --reduced --steps 200
* distributed-mesh training driver for the full configs (on TPU hardware;
  here it is exercised via the dry-run, which lowers exactly this step).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.data import TemplateCorpus, lm_batches
from repro.models import build_model
from repro.train import TrainConfig, Trainer
from repro.train.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.active_param_count()/1e6:.1f}M active)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=args.seq,
                            seed=args.seed)
    batches = lm_batches(cfg.vocab, args.seq, args.batch,
                         args.steps * max(1, args.grad_accum),
                         corpus=corpus)
    if args.grad_accum > 1:
        def accum_batches():
            it = iter(batches)
            while True:
                group = [next(it) for _ in range(args.grad_accum)]
                yield {"tokens": jnp.stack(
                    [jnp.asarray(g["tokens"]) for g in group])}
        stream = accum_batches()
    else:
        stream = batches

    tcfg = TrainConfig(steps=args.steps, lr=args.lr,
                       grad_accum=args.grad_accum,
                       optimizer=cfg.optimizer, log_every=10)
    trainer = Trainer(model, tcfg)
    params, opt_state, hist = trainer.fit(params, stream)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps,
                        meta={"arch": cfg.name})
        print(f"[train] checkpoint -> {args.ckpt}")
    print(f"[train] done: loss {hist[0][1]:.4f} -> {hist[-1][1]:.4f}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips of TPU v5e; multi-pod:
(pod=2, data=16, model=16) = 512 chips. The ``pod`` axis composes with
``data`` (logical dp = (pod, data)) for batch/FSDP shardings.

Constructors paper over jax API drift: ``axis_types`` only exists on
newer jax (older versions are Auto-only, which is what we pass anyway),
and ``AbstractMesh`` changed its signature from one tuple of
``(name, size)`` pairs to separate shape/name tuples.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:       # older jax: meshes are implicitly Auto
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def abstract_mesh(**axes):
    """Device-free mesh for rule/spec math — tests and dry analysis."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axes.values()), tuple(axes.keys()))
    except TypeError:           # older signature: tuple of (name, size)
        return AbstractMesh(tuple(axes.items()))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (fake) host devices exist — tests."""
    return _mesh((data, model), ("data", "model"))


def use_mesh(mesh):
    """Ambient-mesh context. On jax without ``set_mesh`` this is a no-op:
    every sharding we pass is a NamedSharding that carries its mesh."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is None:
        import contextlib
        return contextlib.nullcontext()
    return set_mesh(mesh)

"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state. Single pod: (data=16, model=16) = 256 chips of TPU v5e; multi-pod:
(pod=2, data=16, model=16) = 512 chips. The ``pod`` axis composes with
``data`` (logical dp = (pod, data)) for batch/FSDP shardings.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (fake) host devices exist — tests."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

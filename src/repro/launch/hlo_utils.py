"""HLO inspection: collective-byte accounting for the roofline.

``cost_analysis`` has no collective term, so we parse the compiled HLO and
sum the RESULT-shape bytes of every collective op (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute). XLA's HLO cost analysis
visits a ``while`` body once — the scan correction (DESIGN.md §7) is applied
one level up by diffing L and L+unit lowerings of the same config.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind (static occurrences —
    while bodies counted once, corrected by the caller's L-diff)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for m in _LINE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        out[kind] += shape_bytes(shape_str)
        counts[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts  # type: ignore
    return out


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    if ma is not None:
        out.update(
            arg_bytes=int(ma.argument_size_in_bytes),
            out_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            alias_bytes=int(ma.alias_size_in_bytes),
            peak_bytes=int(getattr(ma, "peak_memory_in_bytes", 0)),
        )
    return out

"""Whisper-medium — encoder-decoder speech model (backbone only).

[arXiv:2212.04356] 24L d_model=1024 16H d_ff=4096 vocab=51865. The
mel-spectrogram + conv frontend is STUBBED per the assignment:
``input_specs`` feeds precomputed (B, 1500, 1024) frame embeddings.
Decoder is 24L causal with cross-attention to the encoder.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,                 # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    glu=False,
    encoder=EncoderConfig(n_layers=24, n_frames=1500, d_model=1024,
                          n_heads=16, d_ff=4096),
    frontend="audio",
    decode_window=8192,
    source="[arXiv:2212.04356]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=512,
        encoder=EncoderConfig(n_layers=2, n_frames=64, d_model=256,
                              n_heads=4, d_ff=512),
    )

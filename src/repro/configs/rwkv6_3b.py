"""RWKV-6 "Finch" 3B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 32L d_model=2560 d_ff=8960 vocab=65536. Head dim 64
(40 heads). AttMemo is inapplicable (no APM) — see DESIGN.md
§Arch-applicability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                 # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    mixer="rwkv6",
    rwkv_head_dim=64,
    glu=False,                  # rwkv channel-mix is its own shape
    source="[arXiv:2404.05892]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=896, vocab=512, rwkv_head_dim=64,
    )


def optimized() -> ModelConfig:
    """Adopted §Perf pair-2 (it6) configuration (EXPERIMENTS.md):
    batch-sharded recurrent scan — one activation resharding per layer
    instead of per scan step. 4.5x on the dominant roofline term."""
    return CONFIG.replace(act_shard_batch=("data", "model"))

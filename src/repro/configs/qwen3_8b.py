"""Qwen3-8B — dense decoder with GQA (kv=8) and qk-norm.

[hf:Qwen/Qwen3-8B] 36L d_model=4096 32H (kv=8) d_ff=12288 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    decode_window=8192,
    source="[hf:Qwen/Qwen3-8B]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512,
    )

"""DBRX-132B — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base] 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff=10752),
    decode_window=8192,
    optimizer="adafactor",
    source="[hf:databricks/dbrx-base]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="dbrx-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=512, dispatch_chunks=2),
    )

"""Qwen2-1.5B — dense decoder with GQA (kv=2) and QKV bias.

[arXiv:2407.10671] 28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    decode_window=8192,
    source="[arXiv:2407.10671]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512,
    )

"""GPT-2 small analogue — the paper's decoder evaluation model.

[Radford et al.] 12L d_model=768 12H d_ff=3072.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-small",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=50257,
    norm="layernorm",
    act="gelu",
    glu=False,
    tie_embeddings=True,
    source="[Radford et al. 2019]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gpt2-reduced", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=512,
    )

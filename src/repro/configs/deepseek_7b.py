"""DeepSeek-7B — dense llama-architecture decoder (MHA).

[arXiv:2401.02954] 30L d_model=4096 32H (kv=32) d_ff=11008 vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    arch_type="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    decode_window=8192,
    source="[arXiv:2401.02954]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=512,
    )

"""Kimi-K2 1T-A32B — trillion-parameter MoE, 384 experts top-8.

[arXiv:2501.kimi2, paper-table] 61L d_model=7168 64H (GQA kv=8)
expert d_ff=2048 vocab=163840, MoE 384e top-8; leading dense layer.
Trains with Adafactor (fp32 Adam state is physically >HBM at 256 chips;
see EXPERIMENTS §Dry-run).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, dispatch_chunks=16),
    dense_first_n=1,
    dense_d_ff=18432,
    decode_window=8192,
    optimizer="adafactor",
    source="[arXiv:2501.kimi2]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, dispatch_chunks=2),
        dense_first_n=1, dense_d_ff=512,
    )

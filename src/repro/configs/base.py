"""Model / run configuration dataclasses.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the full paper-table config) and ``reduced()`` (a CPU-smoke
variant of the same family: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    # number of token chunks the EP path scans over to bound the top_k x
    # activation inflation (see DESIGN.md §5).
    dispatch_chunks: int = 8


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming precomputed frame embeddings (stub
    frontend -- see DESIGN.md §4)."""
    n_layers: int = 24
    n_frames: int = 1500           # fixed post-conv frame count
    d_model: int = 1024
    n_heads: int = 16
    d_ff: int = 4096


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads
    mixer: str = "gqa"             # gqa | mla | rwkv6 | rglru_hybrid
    # hybrid pattern unit, e.g. ("rglru", "rglru", "attn"); repeated/truncated
    # to n_layers. ("mix",) means homogeneous `mixer`.
    layer_pattern: Tuple[str, ...] = ("mix",)
    qk_norm: bool = False
    qkv_bias: bool = False
    causal: bool = True            # False -> encoder-style bidirectional
    n_classes: int = 0             # >0 adds a mean-pool classification head
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    glu: bool = True               # gated MLP (SwiGLU/GeGLU) vs plain 2-layer
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # local attention window (training)
    # decode-time window for the long_500k sub-quadratic variant on otherwise
    # full-attention archs (None -> full cache attention at decode).
    decode_window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    dense_first_n: int = 0         # kimi: leading dense layers before MoE
    dense_d_ff: int = 0            # d_ff of those leading dense layers
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None  # audio | vision (stubbed embeddings)
    # rwkv6
    rwkv_head_dim: int = 64
    # shard the recurrent-scan batch (and states) over these mesh axes —
    # rwkv hillclimb it4: heads (40) don't divide the model axis, the
    # batch does (DESIGN/EXPERIMENTS §Perf)
    act_shard_batch: Optional[Tuple[str, ...]] = None
    # rglru
    conv_width: int = 4
    rglru_c: float = 8.0
    # AttMemo integration: which layers are memoizable (APM exists).
    # Computed from the pattern; rwkv6 -> none.
    optimizer: str = "adamw"       # adamw | adafactor (hints the trainer)
    source: str = ""               # citation bracket from the assignment

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind, length n_layers."""
        if self.layer_pattern == ("mix",):
            base = {"gqa": "attn", "mla": "mla", "rwkv6": "rwkv6"}[self.mixer]
            kinds = [base] * self.n_layers
        else:
            kinds = [self.layer_pattern[i % len(self.layer_pattern)]
                     for i in range(self.n_layers)]
        return tuple(kinds)

    def memoizable_layers(self) -> Tuple[int, ...]:
        """Layers with an attention-probability matrix (AttMemo-applicable)."""
        return tuple(i for i, k in enumerate(self.layer_kinds())
                     if k in ("attn", "mla"))

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers + head)."""
        d, v = self.d_model, self.vocab
        total = v * d                      # token embedding
        if not self.tie_embeddings:
            total += v * d                 # lm head
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            total += 2 * d                 # two norms (scale only; rmsnorm)
            total += self._mixer_params(kind)
            total += self._channel_params(i)
        total += d                         # final norm
        if self.encoder is not None:
            e = self.encoder
            total += e.n_layers * (
                2 * e.d_model
                + 4 * e.d_model * e.d_model          # qkvo
                + 2 * e.d_model * e.d_ff)            # mlp
            total += e.d_model                        # enc final norm
            # decoder cross-attention per layer
            total += self.n_layers * (4 * d * d + d)
        return total

    def _mixer_params(self, kind: str) -> int:
        d, H, Hkv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        if kind == "attn":
            p = d * H * dh + 2 * d * Hkv * dh + H * dh * d
            if self.qkv_bias:
                p += (H + 2 * Hkv) * dh
            if self.qk_norm:
                p += 2 * dh
            return p
        if kind == "mla":
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank + m.q_lora_rank          # q down + norm
            p += m.q_lora_rank * H * qk_head               # q up
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
            p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
            p += H * m.v_head_dim * d                      # o proj
            return p
        if kind == "rwkv6":
            nh = d // self.rwkv_head_dim
            lora = 64
            p = 5 * d * lora * 2 + 6 * d                   # ddlerp loras + mu
            p += 4 * d * d                                 # r,k,v,g  (w is lora)
            p += d * lora * 2                              # decay lora
            p += d                                         # u (bonus)
            p += nh * self.rwkv_head_dim                   # group-norm scale
            p += d * d                                     # output
            return p
        if kind == "rglru":
            dr = d                                          # recurrent width
            p = 2 * d * dr                                  # x branch + gate branch in
            p += self.conv_width * dr                       # temporal conv
            p += 2 * dr * dr + 2 * dr                       # W_a, W_x gates + biases
            p += dr                                         # Λ (per-dim decay)
            p += dr * d                                     # out linear
            return p
        raise ValueError(kind)

    def _channel_params(self, layer_idx: int) -> int:
        d = self.d_model
        kinds = self.layer_kinds()
        if kinds[layer_idx] == "rwkv6":
            return 2 * d * int(3.5 * d) + d  # rwkv channel-mix approx
        if self.moe is not None and layer_idx >= self.dense_first_n:
            m = self.moe
            mult = 3 if self.glu else 2
            return d * m.n_experts + m.n_experts * mult * d * m.d_ff
        ff = self.dense_d_ff if (self.moe is not None and
                                 layer_idx < self.dense_first_n and
                                 self.dense_d_ff) else self.d_ff
        mult = 3 if self.glu else 2
        return mult * d * ff

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        mult = 3 if self.glu else 2
        n_moe_layers = self.n_layers - self.dense_first_n
        all_experts = n_moe_layers * m.n_experts * mult * self.d_model * m.d_ff
        active = n_moe_layers * m.top_k * mult * self.d_model * m.d_ff
        return full - all_experts + active

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned, global)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

"""RecurrentGemma-2B — Griffin hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427] 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
Pattern (rec, rec, attn) repeated; local-attention window 2048. Natively
sub-quadratic — runs long_500k as-is.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    mixer="rglru_hybrid",
    layer_pattern=("rglru", "rglru", "attn"),
    sliding_window=2048,
    act="gelu",
    conv_width=4,
    tie_embeddings=True,
    source="[arXiv:2402.19427]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-reduced", n_layers=3, d_model=256, n_heads=2,
        n_kv_heads=1, d_head=128, d_ff=512, vocab=512, sliding_window=64,
    )

"""Chameleon-34B — early-fusion VLM decoder (VQ image tokens, qk-norm).

[arXiv:2405.09818] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
The VQ-VAE image tokenizer is STUBBED per the assignment: image patches
arrive as ids in the shared 65536 vocab, so input_specs is plain token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    frontend="vision",
    decode_window=8192,
    optimizer="adafactor",
    source="[arXiv:2405.09818]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="chameleon-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512,
    )

"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Every assigned architecture (and the paper's own evaluation models) is a
module exporting CONFIG and reduced().
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    EncoderConfig, InputShape, INPUT_SHAPES, MLAConfig, ModelConfig, MoEConfig,
)

ARCH_IDS = [
    "minicpm3_4b",
    "rwkv6_3b",
    "whisper_medium",
    "dbrx_132b",
    "deepseek_7b",
    "recurrentgemma_2b",
    "qwen2_1_5b",
    "chameleon_34b",
    "qwen3_8b",
    "kimi_k2_1t_a32b",
    # the paper's own evaluation models (reduced-trainable analogues)
    "bert_base",
    "gpt2_small",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({"qwen2-1.5b": "qwen2_1_5b", "kimi-k2-1t-a32b": "kimi_k2_1t_a32b"})


def _module(arch_id: str):
    key = _ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _module(arch_id).reduced()

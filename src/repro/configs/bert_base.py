"""BERT-base analogue — the paper's primary evaluation model (encoder).

[arXiv:1810.04805] 12L d_model=768 12H d_ff=3072. Used (reduced) for the
AttMemo validation experiments: bidirectional attention == causal mask off.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-base",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=30522,
    causal=False,
    norm="layernorm",
    act="gelu",
    glu=False,
    source="[arXiv:1810.04805]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="bert-reduced", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=512,
    )

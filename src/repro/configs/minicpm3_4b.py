"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B] 62L d_model=2560 40H (GQA kv=40) d_ff=6400
vocab=73448. MLA ranks follow the model card (q_lora 768, kv_lora 256,
qk rope 32 / nope 64, v 64).
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=96,                  # qk_nope(64) + qk_rope(32)
    d_ff=6400,
    vocab=73448,
    mixer="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    decode_window=8192,         # sub-quadratic long_500k variant
    tie_embeddings=True,
    source="[hf:openbmb/MiniCPM3-4B]",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="minicpm3-reduced", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_head=96, d_ff=512, vocab=512,
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
    )


def optimized() -> ModelConfig:
    """Adopted §Perf pair-1 configuration (EXPERIMENTS.md): padded vocab
    (shardable lm_head) + batch-sharded activations. Use with sharding
    rules overrides {'q_lora': 'model', 'kv_lora': 'model'}. 12.8x on the
    dominant roofline term vs CONFIG."""
    return CONFIG.replace(vocab=73728, act_shard_batch=("data", "model"))

"""Training loop: jit'd step, grad accumulation, remat, LR schedule,
periodic checkpointing. Works single-device or under a mesh (params and
batch shardings applied by the launcher)."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.optim import make_optimizer
from repro.optim.schedule import cosine_schedule
from repro.train.checkpoint import save_checkpoint


@dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 20
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    grad_accum: int = 1
    optimizer: str = "adamw"
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_path: str = "checkpoints/model"
    loss: str = "lm"            # lm | classify


class Trainer:
    def __init__(self, model, tcfg: TrainConfig, *, in_shardings=None,
                 donate: bool = True):
        self.model = model
        self.tcfg = tcfg
        init_fn, update_fn = make_optimizer(tcfg.optimizer)
        self._opt_init = init_fn
        loss_fn = (model.train_loss if tcfg.loss == "lm"
                   else model.classify_loss)

        def step(params, opt_state, batch, step_idx):
            lr = cosine_schedule(step_idx, tcfg.warmup, tcfg.steps, tcfg.lr)
            if tcfg.grad_accum > 1:
                def micro(c, mb):
                    loss, g = jax.value_and_grad(loss_fn)(params, mb)
                    acc_l, acc_g = c
                    return (acc_l + loss,
                            jax.tree.map(jnp.add, acc_g, g)), ()
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    micro, (jnp.zeros(()), zeros), batch)
                loss = loss / tcfg.grad_accum
                grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = update_fn(
                params, grads, opt_state, lr=lr,
                weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
            return params, opt_state, loss

        self._step = jax.jit(step, donate_argnums=(0, 1) if donate else ())

    def init_opt(self, params):
        return self._opt_init(params)

    def fit(self, params, batches: Iterator[dict], *, opt_state=None,
            on_log: Optional[Callable] = None):
        opt_state = opt_state or self.init_opt(params)
        history = []
        t0 = time.perf_counter()
        for i, batch in enumerate(batches):
            if i >= self.tcfg.steps:
                break
            batch = jax.tree.map(jnp.asarray, batch)
            params, opt_state, loss = self._step(params, opt_state, batch, i)
            if i % self.tcfg.log_every == 0 or i == self.tcfg.steps - 1:
                lv = float(loss)
                dt = time.perf_counter() - t0
                history.append((i, lv))
                msg = f"step {i:5d}  loss {lv:8.4f}  {dt:6.1f}s"
                (on_log or print)(msg)
            if self.tcfg.ckpt_every and i and i % self.tcfg.ckpt_every == 0:
                save_checkpoint(f"{self.tcfg.ckpt_path}_{i}.npz", params,
                                step=i)
        return params, opt_state, history

"""Flat-npz checkpointing for arbitrary param/optimizer pytrees.

Leaves are flattened to ``path/like/this`` keys; metadata (step, config
name) rides along. No orbax dependency — files are portable npz archives.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif tree is None:
        out[prefix + "__none__"] = np.zeros(0)
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        if parts[-1] == "__none__":
            parts = parts[:-1]
            val = None
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(path: str, params, opt_state=None, *, step: int = 0,
                    meta: dict = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {f"params{_SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        flat.update({f"opt{_SEP}{k}": v
                     for k, v in _flatten(opt_state).items()})
    flat["__meta__"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), np.uint8)
    np.savez(path, **flat)


def load_checkpoint(path: str) -> Tuple[Any, Any, dict]:
    if not path.endswith(".npz"):
        path += ".npz"
    z = np.load(path, allow_pickle=False)
    meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
    pflat, oflat = {}, {}
    for k in z.files:
        if k == "__meta__":
            continue
        scope, rest = k.split(_SEP, 1)
        (pflat if scope == "params" else oflat)[rest] = z[k]
    params = jax.tree.map(jnp.asarray, _unflatten(pflat))
    opt = jax.tree.map(jnp.asarray, _unflatten(oflat)) if oflat else None
    return params, opt, meta

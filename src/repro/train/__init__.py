from repro.train.trainer import Trainer, TrainConfig  # noqa: F401
from repro.train.checkpoint import save_checkpoint, load_checkpoint  # noqa: F401

"""AttMemo-JAX: attention memoization on big-memory systems (Feng et al. 2023), as a multi-pod JAX framework."""

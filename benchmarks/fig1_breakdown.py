"""Paper Fig. 1 — self-attention share of total inference time.

Measures the attention fraction for encoder/decoder reduced models at two
sequence lengths (the paper reports 43-83%, growing with length)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_ms
from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.models import backbone as bb
from repro.models import build_model


def run():
    rows = []
    for arch in ("bert_base", "gpt2_small"):
        cfg = get_reduced(arch).replace(n_layers=4)
        model = build_model(cfg, layer_loop="unroll")
        params = model.init(jax.random.PRNGKey(0))
        for seq in (64, 256):
            corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=seq, seed=0)
            toks = jnp.asarray(corpus.sample(16)[0])
            fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
            total = timeit_ms(fwd, params, toks)

            positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                         toks.shape)
            from repro.models import attention as attn_mod
            mask = "causal" if cfg.causal else "bidir"

            def attn_all(p, t):
                h = bb.embed_tokens(p, t, cfg)
                outs = []
                for li, kind, lp in bb.iter_layers(p, cfg):
                    x = bb.norm_apply(lp["norm1"], h, cfg.norm)
                    y, _ = attn_mod.gqa_apply(lp["mix"], x, cfg,
                                              positions=positions,
                                              mask_kind=mask)
                    outs.append(y)
                return outs
            attn_ms = timeit_ms(jax.jit(attn_all), params, toks)
            frac = attn_ms / total
            rows.append((f"fig1/{arch}_seq{seq}", total * 1e3,
                         f"attn_frac={frac:.2f}"))
    return rows

"""Benchmark harness (deliverable d) — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig10,table6]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "fig1_breakdown",      # Fig 1: attention share of inference
    "fig3_similarity",     # Fig 3 + Fig 12: similarity distributions
    "fig4_threshold",      # Fig 4 + Tables 2/5: threshold/accuracy
    "table4_breakdown",    # Table 4: memo step breakdown
    "table6_gather",       # Table 6: copy vs mapping gather
    "fig10_speedup",       # Fig 10: e2e speedup x batch x level
    "table7_selective",    # Table 7: selective memoization
    "fig11_reuse",         # Fig 11: APM reuse histogram
    "fig13_dbscale",       # Fig 13: DB-size scaling
    "fig15_large_model",   # Fig 15: larger-model potential
    "ablations",           # beyond-paper: similarity knob + index ablation
    "roofline",            # deliverable (g): from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.2f},{derived}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

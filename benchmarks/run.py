"""Benchmark harness (deliverable d) — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig10,table6]
    PYTHONPATH=src python -m benchmarks.run --only serve --json BENCH_serve.json

``--json`` additionally writes a machine-readable perf trajectory: every
CSV row plus the serve fast-path detail (per-phase latency for
select/bucket/kernel with and without the device-resident path) from
``serve_fastpath.collect()`` — the baseline future PRs regress against.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
import traceback

MODULES = [
    "fig1_breakdown",      # Fig 1: attention share of inference
    "fig3_similarity",     # Fig 3 + Fig 12: similarity distributions
    "fig4_threshold",      # Fig 4 + Tables 2/5: threshold/accuracy
    "table4_breakdown",    # Table 4: memo step breakdown
    "table6_gather",       # Table 6: copy vs mapping gather
    "fig10_speedup",       # Fig 10: e2e speedup x batch x level
    "table7_selective",    # Table 7: selective memoization
    "fig11_reuse",         # Fig 11: APM reuse histogram
    "fig13_dbscale",       # Fig 13: DB-size scaling
    "fig15_large_model",   # Fig 15: larger-model potential
    "ablations",           # beyond-paper: similarity knob + index ablation
    "roofline",            # deliverable (g): from the dry-run artifacts
    "serve_fastpath",      # ISSUE 1: device fast path vs host-sync serve
    "serve_online",        # ISSUE 2: MemoStore online adaptation + delta sync
    "serve_compress",      # ISSUE 3: codec x index sweep (bytes/accuracy)
    "serve_runtime",       # ISSUE 4: open-loop runtime, sync vs async maint
    "serve_faults",        # ISSUE 6: chaos classes, degradation + recovery
    "serve_sharded",       # ISSUE 9: 8-way sharded store vs single host
    "serve_prefill",       # ISSUE 10: memoized prefill + KV decode handoff
]


def _normalized_latencies(doc):
    """Serve metrics as DIMENSIONLESS ratios, so a regression check is
    meaningful across machines: fast/host-path ms normalized by the same
    run's select-reference ms, and the clustered-search inverse speedup.
    Lower is better for every key."""
    out = {}
    for level, blk in ((doc.get("serve") or {}).get("levels") or {}).items():
        base = (blk.get("modes") or {}).get("select", {}).get("host_ms")
        if not base:
            continue
        for mode, row in blk["modes"].items():
            # kernel mode included since ISSUE 7: it serves through the
            # one-matmul XLA form on CPU (engine._kernel_impl), so its
            # timings are as stable as bucket's
            for k in ("host_ms", "fast_ms"):
                if k in row:
                    out[f"serve/{level}/{mode}/{k}"] = row[k] / base
    # the fused-kernel standing (ISSUE 7): kernel-mode latency as a
    # fraction of the bucket fast path and the select reference from the
    # SAME run — dimensionless, and additionally ceiling-gated in
    # ABS_BOUNDS (kernel mode must keep beating select outright)
    for level, row in ((doc.get("serve_kernel") or {}).get("levels")
                       or {}).items():
        for k in ("kernel_over_bucket", "kernel_over_select"):
            if row.get(k):
                out[f"serve_kernel/{level}/{k}"] = row[k]
    micro = (doc.get("serve_compress") or {}).get("search_micro") or {}
    for key, row in micro.items():
        if row.get("speedup"):
            out[f"compress/search_{key}/inv_speedup"] = 1.0 / row["speedup"]
    # runtime A/B: async p99 normalized by the same run's sync p99 —
    # both legs share the box and the trace, so the ratio is the
    # machine-independent measure of the maintenance overlap win.
    # Floored at 0.5: deep-win ratios (0.0x) swing multiplicatively with
    # scheduler noise, so the gate only tracks the regime that matters —
    # async drifting toward (or past) parity with sync.
    rt = doc.get("serve_runtime") or {}
    if rt.get("p99_async_over_sync"):
        out["runtime/p99_async_over_sync"] = max(
            0.5, rt["p99_async_over_sync"])
    # facade cost (ISSUE 5): the session layer's own per-batch wrapper
    # time as a fraction of the direct batch time, measured in isolation
    # (deterministic — see serve_runtime._facade_ab). The wall-clock
    # facade/direct p50 ratio is recorded in the JSON for the trajectory
    # but NOT gated: its run-to-run spread on virtualized boxes (±2-3%)
    # dwarfs the sub-1% bound it would be checking.
    fa = rt.get("facade_ab") or {}
    if fa.get("facade_overhead_frac") is not None:
        out["runtime/facade_overhead_frac"] = fa["facade_overhead_frac"]
    # chaos classes (ISSUE 6): both keys are absolute-ceiling gates, not
    # baseline-relative — a fault class may NEVER cost a request
    # (unavailability ≤ 0) and recovery must restore the memo path
    # (post-recovery hit rate within 0.05 of the fault-free baseline).
    # p99 under faults is recorded in the JSON but not gated: it carries
    # one-off XLA compiles for the exact-attention path.
    for cls, leg in ((doc.get("serve_faults") or {}).get("classes")
                     or {}).items():
        if leg.get("availability") is not None:
            out[f"faults/{cls}/unavailability"] = 1.0 - leg["availability"]
        if leg.get("hit_recovery_gap") is not None:
            out[f"faults/{cls}/hit_recovery_gap"] = leg["hit_recovery_gap"]
    # capacity tier (DESIGN.md §2.11): a store ~10x the host budget must
    # serve within 0.05 hit rate of all-in-RAM once promotion warms up
    cap = (doc.get("serve_faults") or {}).get("capacity") or {}
    if cap.get("hit_gap") is not None:
        out["faults/capacity/hit_gap"] = cap["hit_gap"]
    # sharded store (ISSUE 9): both absolute-ceiling gates. Centroid
    # routing may cost at most 0.05 hit rate vs the single-host store at
    # the same total budget, and the greedy balanced ownership must keep
    # the fullest shard within 2x of the mean occupancy.
    sh = doc.get("serve_sharded") or {}
    if sh.get("hit_gap") is not None:
        out["sharded/hit_gap"] = sh["hit_gap"]
    if (sh.get("sharded") or {}).get("imbalance") is not None:
        out["sharded/occupancy_imbalance"] = sh["sharded"]["imbalance"]
    # prefill memoization (ISSUE 10): both absolute-ceiling gates —
    # substituting a memoized prefill hit may cost at most 5% of greedy
    # decode tokens vs the all-exact baseline, and every codec's
    # prefill/decode |Δlogits| must stay inside the kernel-parity bounds
    # (a failure count, so the ceiling is exactly zero)
    pf = doc.get("serve_prefill") or {}
    if pf.get("hit_gap") is not None:
        out["prefill/hit_gap"] = pf["hit_gap"]
    if pf.get("decode_parity_failures") is not None:
        out["prefill/decode_parity_failures"] = float(
            pf["decode_parity_failures"])
    return out


# Absolute ceilings, enforced by --check-regress INDEPENDENTLY of the
# baseline/tolerance machinery (and excluded from the relative
# comparison — a 1e-4 fraction doubling is not a regression): the
# facade contract is "<1% serve latency over the direct runtime"
# (ISSUE 5), not "no worse than last time". The measured fraction is
# ~0.2-0.35% (several-fold margin), so this only fires when someone
# adds real per-batch work to the facade.
ABS_BOUNDS = {"runtime/facade_overhead_frac": 0.01}
# chaos acceptance (ISSUE 6): zero dropped requests under every fault
# class, and post-recovery hit rate within 0.05 of the fault-free run
for _cls in ("corrupt_row", "sync_fail", "evict_bogus", "maint_crash",
             "maint_stall", "queue_overflow",
             # disk-fault classes (DESIGN.md §2.11): losing the capacity
             # tier degrades durability, never availability or recovery
             "disk_write_io", "journal_torn", "checkpoint_crash",
             "mmap_bitflip"):
    ABS_BOUNDS[f"faults/{_cls}/unavailability"] = 0.0
    ABS_BOUNDS[f"faults/{_cls}/hit_recovery_gap"] = 0.05
# big-memory acceptance (DESIGN.md §2.11): serving a store ~10x the
# host byte budget costs at most 0.05 hit rate vs all-in-RAM
ABS_BOUNDS["faults/capacity/hit_gap"] = 0.05
# fused-kernel standing (ISSUE 7): kernel mode must keep beating the
# select reference outright (measured 0.74-0.85 + ~8% runner noise) and
# stay within bucket's ballpark (measured 1.08-1.09; the ceiling fires
# if the fused dispatch regresses to the pre-ISSUE-7 0.87x-speedup
# regime, where kernel lost ~25% to bucket)
for _lvl in ("moderate", "aggressive"):
    ABS_BOUNDS[f"serve_kernel/{_lvl}/kernel_over_select"] = 1.0
    ABS_BOUNDS[f"serve_kernel/{_lvl}/kernel_over_bucket"] = 1.35
# sharded-store acceptance (ISSUE 9): an 8-way mesh serving a database
# beyond any single shard's position budget stays within 0.05 hit rate
# of the single-host store at equal total budget, with the fullest
# shard at most 2x the mean occupancy
ABS_BOUNDS["sharded/hit_gap"] = 0.05
ABS_BOUNDS["sharded/occupancy_imbalance"] = 2.0
# prefill memoization (ISSUE 10): a memoized-prefill hit hands decode a
# cache the backbone cannot tell from exact prefill's — zero per-codec
# parity-bound violations, and at most 0.05 greedy-token gap vs the
# all-exact baseline
ABS_BOUNDS["prefill/hit_gap"] = 0.05
ABS_BOUNDS["prefill/decode_parity_failures"] = 0.0


def check_regress(new_doc, baseline_path, tol=0.10):
    """Compare this run against the last recorded BENCH_serve.json:
    any normalized serve latency worse by > tol fails the run, and any
    ``ABS_BOUNDS`` key over its ceiling fails regardless of baseline.
    Only keys present in both documents enter the relative comparison
    (a missing module is not a regression)."""
    try:
        with open(baseline_path) as f:
            old_doc = json.load(f)
    except FileNotFoundError:
        print(f"# --check-regress: no baseline at {baseline_path}, skipping",
              file=sys.stderr)
        return []
    new_n = _normalized_latencies(new_doc)
    problems = []
    for key, old_v in _normalized_latencies(old_doc).items():
        if key in ABS_BOUNDS:      # absolute-ceiling keys only, below
            continue
        new_v = new_n.get(key)
        if new_v is not None and new_v > old_v * (1.0 + tol):
            problems.append({"key": key, "baseline": old_v, "new": new_v,
                             "regression": new_v / old_v - 1.0})
    for key, bound in ABS_BOUNDS.items():
        new_v = new_n.get(key)
        if new_v is not None and new_v > bound:
            problems.append({"key": key, "baseline": bound, "new": new_v,
                             "regression": new_v / bound - 1.0})
    return problems


def parity_failures(serve_doc, tag=""):
    """Bucket/kernel fast-path logits must match the select reference;
    collect every mode whose parity boolean is False so --json can fail
    loudly with a diff report instead of silently recording it."""
    bad = []
    for level, blk in (serve_doc or {}).get("levels", {}).items():
        for mode, row in blk.get("modes", {}).items():
            if row.get("logits_match_select") is False:
                bad.append({"where": f"{tag}{level}/{mode}",
                            "max_abs_diff": row.get("logits_max_abs_diff"),
                            "threshold": blk.get("threshold")})
    return bad


def kernel_parity_failures(sk_doc):
    """Same hard gate for the serve_kernel section (ISSUE 7): the fused
    dispatch's per-level parity and the per-codec (f16/int8) parity."""
    bad = []
    for level, row in (sk_doc or {}).get("levels", {}).items():
        if row.get("logits_match_select") is False:
            bad.append({"where": f"serve_kernel/{level}",
                        "max_abs_diff": row.get("logits_max_abs_diff"),
                        "threshold": row.get("threshold")})
    for codec, row in (sk_doc or {}).get("codec_parity", {}).items():
        if row.get("logits_match_select") is False:
            bad.append({"where": f"serve_kernel/codec/{codec}",
                        "max_abs_diff": row.get("logits_max_abs_diff"),
                        "threshold": None})
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    ap.add_argument("--json", default=None, metavar="BENCH_serve.json",
                    help="also write rows + serve fast-path detail as JSON")
    ap.add_argument("--check-regress", default=None, metavar="BASELINE.json",
                    help="compare this run's serve latencies (normalized "
                         "to the run's own select reference, so the check "
                         "is machine-independent) against a previous "
                         "BENCH_serve.json; exit nonzero on >10%% "
                         "regression")
    ap.add_argument("--regress-tol", type=float, default=0.10)
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    failed_modules = set()
    rows = []
    for name in MODULES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row_name, us, derived in mod.run():
                rows.append({"name": row_name, "us_per_call": us,
                             "derived": str(derived)})
                print(f"{row_name},{us:.2f},{derived}", flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failures += 1
            failed_modules.add(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if args.json or args.check_regress:
        doc = {"rows": rows}
        # lru-cached: free if serve_fastpath already ran; skip if it just
        # failed (lru_cache does not cache exceptions — a retry would
        # redo the multi-minute sweep only to fail the same way)
        def wanted(name):
            return ((only is None or any(o in name for o in only))
                    and name not in failed_modules)

        detail_sections = [("serve", "serve_fastpath", "collect"),
                           ("serve_kernel", "serve_fastpath",
                            "collect_kernel"),
                           ("serve_online", "serve_online", "collect"),
                           ("serve_compress", "serve_compress", "collect"),
                           ("serve_runtime", "serve_runtime", "collect"),
                           ("serve_faults", "serve_faults", "collect"),
                           ("serve_sharded", "serve_sharded", "collect"),
                           ("serve_prefill", "serve_prefill", "collect")]
        for doc_key, mod_name, fn_name in detail_sections:
            if not wanted(mod_name):
                continue
            try:
                mod = importlib.import_module(f"benchmarks.{mod_name}")
                doc[doc_key] = getattr(mod, fn_name)()
            except Exception:  # noqa: BLE001
                print(f"# {doc_key} detail FAILED:\n"
                      f"{traceback.format_exc()}", file=sys.stderr)
                failures += 1
        if args.check_regress:
            bad = check_regress(doc, args.check_regress,
                                tol=args.regress_tol)
            if bad:
                failures += 1
                print("# LATENCY REGRESSION vs "
                      f"{args.check_regress} (tol {args.regress_tol:.0%}):",
                      file=sys.stderr)
                for b in bad:
                    print(f"#   {b['key']}: {b['baseline']:.3f} -> "
                          f"{b['new']:.3f} (+{b['regression']:.0%})",
                          file=sys.stderr)
                doc["latency_regressions"] = bad
            else:
                print(f"# --check-regress vs {args.check_regress}: OK",
                      file=sys.stderr)
        # fast-path parity is a HARD gate: divergence from the select
        # reference exits nonzero with a diff report, not just a boolean
        # buried in the JSON
        bad = (parity_failures(doc.get("serve"))
               + kernel_parity_failures(doc.get("serve_kernel")))
        if bad:
            failures += 1
            print("# PARITY FAILURE: fast-path logits diverged from the "
                  "select reference beyond tolerance:", file=sys.stderr)
            for b in bad:
                print(f"#   {b['where']} (thr={b['threshold']}): "
                      f"max|Δlogits| = {b['max_abs_diff']}",
                      file=sys.stderr)
            doc["parity_failures"] = bad
        if args.json:
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

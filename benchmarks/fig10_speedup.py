"""Paper Fig. 10 — end-to-end inference speedup vs batch size and
memoization level (bucket mode: the latency win is real, not simulated)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import built_engine

def _lat(eng, toks, **kw):
    eng.infer({"tokens": toks}, **kw)                  # warm/compile
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        logits, st = eng.infer({"tokens": toks}, **kw)
        jax.block_until_ready(logits)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), st


def run():
    rows = []
    # longer sequences: attention is what memoization replaces, so the win
    # scales with S (paper uses 512/1024)
    eng, corpus = built_engine(mode="bucket", seq=192)
    eng.mc.mode = "bucket"
    levels = eng.levels
    rows.append(("fig10/levels", 0.0,
                 ";".join(f"{k}={v:.3f}" for k, v in levels.items())))
    for B in (1, 16, 32):
        toks = jnp.asarray(corpus.sample(B)[0])
        t_base, _ = _lat(eng, toks, use_memo=False)
        rows.append((f"fig10/B{B}_baseline", t_base * 1e6, "no memo"))
        for name, thr in levels.items():
            t, st = _lat(eng, toks, threshold=thr)
            rows.append((f"fig10/B{B}_{name}", t * 1e6,
                         f"speedup={(t_base / t - 1) * 100:+.1f}%;"
                         f"memo_rate={st.memo_rate:.2f}"))
    eng.mc.mode = "select"
    return rows

"""Open-loop serving-runtime benchmark (ISSUE 4 / DESIGN.md §2.7).

Serves one Poisson-arrival, variable-length, mid-run-drifting request
trace through the MemoServer runtime twice — synchronous batch-boundary
maintenance vs the off-thread worker — on identically rebuilt sessions,
and records throughput + p50/p99 latency + hit rate for both. Emitted
into BENCH_serve.json as the ``serve_runtime`` section; the regression
gate tracks the async/sync p99 ratio (``--check-regress``), which is
machine-independent because both legs run on the same box back to back.

Also records the **facade A/B** (ISSUE 5): per-batch serve latency
through ``MemoSession.serve()`` vs a hand-wired ``MemoServer(engine)``
(paired wall-clock ratio, recorded), plus the session layer's own
wrapper time measured in isolation as a fraction of batch time —
``facade_overhead_frac`` (~0.2–0.35% measured), hard-gated at <1% by
``--check-regress``. The public API must stay free.

Sessions are built fresh per leg (NOT the lru-shared ``built_session``):
serving mutates the store, and the A/B is only honest if both legs start
from the identical calibration state.

The **sharded leg** (ISSUE 9) lives in ``collect_sharded`` (exposed as
the ``serve_sharded`` module/section): an 8-way CPU mesh subprocess
(device count locks at first jax init) serving a database bigger than
any single shard's position budget through ``ShardedMemoStore``, vs a
single-host store at the SAME total byte budget. Records the hit-rate
gap (the cost of centroid routing), per-shard occupancy balance, search
latency, and fetched-payload parity; ``--check-regress`` ceilings the
gap at 0.05 and the imbalance at 2x (benchmarks/run.py ABS_BOUNDS).
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_encoder
from repro.data import TemplateCorpus
from repro.launch.server import probe_rate, serve_trace
from repro.memo import MemoServer, MemoSession, MemoSpec

SEQ = 32
BATCH = 8
REQUESTS = 120
BUCKETS = (16, 32)


def _build_session():
    model, params, corpus = trained_encoder("bert_base", n_layers=2,
                                            seq_len=SEQ)
    spec = MemoSpec.flat(mode="bucket", embed_steps=120, admit=True,
                         budget_mb=256.0, recal_every=2, device_slack=8.0)
    # dedicated rng: both A/B legs must build the IDENTICAL store (the
    # shared corpus rng advances between calls)
    rng = np.random.default_rng(123)
    sess = MemoSession.build(
        model, params, spec,
        batches=[{"tokens": jnp.asarray(corpus.sample(BATCH, rng)[0])}
                 for _ in range(4)],
        key=jax.random.PRNGKey(1))
    sess.autotune([{"tokens": jnp.asarray(corpus.sample(BATCH, rng)[0])}],
                  level="aggressive")
    return sess, corpus


def _workload(corpus, rate: float):
    """Poisson arrivals; two lengths per bucket (so the length-gated
    store adapts quickly and both legs reach the same steady hit rate);
    corpus drifts at the midpoint — the phase where maintenance
    (admission + delta sync + recal) is busiest."""
    rng = np.random.default_rng(7)
    drifted = TemplateCorpus(vocab=corpus.vocab, seq_len=SEQ, seed=117,
                             n_templates=corpus.n_templates,
                             slot_fraction=corpus.slot_fraction)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, REQUESTS))
    wl = []
    for i in range(REQUESTS):
        src = corpus if i < REQUESTS // 2 else drifted
        bucket = int(rng.choice(BUCKETS))
        length = bucket - int(rng.choice([0, 2]))
        wl.append((float(arrivals[i]), src.sample(1, rng)[0][0, :length]))
    return wl


def _facade_ab(sess: MemoSession, corpus, rounds: int = 16,
               reps: int = 3, wrapper_reps: int = 2000):
    """The session layer's serve-latency cost, measured two ways.

    **Wall-clock A/B** (recorded, not hard-gated): a hand-wired
    ``MemoServer(engine)`` (the pre-facade call pattern) vs
    ``session.serve()`` — same engine, same jit caches, same FROZEN
    store (admission paused), same tokens per round, paired min-of-reps
    ratios, median over rounds. On the CI-class boxes this distribution
    has per-round spread of ±10%+ (virtualized timing noise at ~15ms
    batch granularity), so the median swings a few percent run to run —
    it documents parity, but cannot *prove* a sub-1% bound.

    **Wrapper isolation** (the gated metric): ``session.serve()``
    returns the raw ``MemoServer`` — the per-batch serve path contains
    ZERO session-layer code (asserted here), so the thickest per-call
    wrapper the facade owns anywhere is ``session.infer`` (kwarg
    plumbing + cumulative stats merge). That wrapper is timed in
    isolation by stubbing the engine call out of it, and reported as a
    fraction of the median direct batch time:
    ``facade_overhead_frac`` ≈ 0.2–0.35% measured (wrapper ~30–50µs vs
    ~14ms batches). The ``--check-regress`` bound (<1%,
    benchmarks/run.py ABS_BOUNDS) keeps a several-fold margin and does
    not depend on differencing two large noisy timings — it fails only
    if someone adds real per-batch work to the facade, not from
    scheduler noise."""
    eng = sess.engine
    admit0 = eng.mc.admit
    eng.mc.admit = False
    rng = np.random.default_rng(3)
    try:
        direct = MemoServer(eng, buckets=BUCKETS, max_batch=BATCH,
                            async_maintenance=False)
        facade = sess.serve(buckets=BUCKETS, max_batch=BATCH,
                            async_maintenance=False)
        # the facade serves through the SAME runtime class, not a proxy:
        # per-batch serving never executes session-layer code
        assert type(facade) is MemoServer
        direct.warmup()
        facade.warmup()

        def one_batch(server, toks):
            t0 = time.perf_counter()
            for j in range(BATCH):
                server.submit(toks[j, : SEQ - 2 * (j % 2)])
            server.step(flush=True)
            return time.perf_counter() - t0

        def best_of(server, toks):
            return min(one_batch(server, toks) for _ in range(reps))

        ratios, td, tf = [], [], []
        for i in range(rounds):
            toks = corpus.sample(BATCH, rng)[0]
            if i % 2:
                f = best_of(facade, toks)
                d = best_of(direct, toks)
            else:
                d = best_of(direct, toks)
                f = best_of(facade, toks)
            td.append(d)
            tf.append(f)
            ratios.append(f / max(d, 1e-9))
        direct.close()
        facade.close()

        # wrapper isolation: session.infer with the engine stubbed out
        toks = jnp.asarray(corpus.sample(BATCH, rng)[0])
        out, st = sess.infer({"tokens": toks})      # canned return values
        real_infer = eng.infer
        eng.infer = lambda batch, **kw: (out, st)
        try:
            t0 = time.perf_counter()
            for _ in range(wrapper_reps):
                sess.infer({"tokens": toks})
            wrapper_s = (time.perf_counter() - t0) / wrapper_reps
        finally:
            eng.infer = real_infer
    finally:
        eng.mc.admit = admit0
    d_ms = float(np.median(td) * 1e3)
    return {"rounds": rounds, "reps": reps,
            "direct_p50_ms": d_ms,
            "facade_p50_ms": float(np.median(tf) * 1e3),
            "facade_over_direct": float(np.median(ratios)),
            "wrapper_us": float(wrapper_s * 1e6),
            "facade_overhead_frac": float(wrapper_s * 1e3 / max(d_ms,
                                                                1e-9))}


@functools.lru_cache(maxsize=1)
def collect():
    sess, corpus = _build_session()
    rate = probe_rate(sess, buckets=BUCKETS, max_batch=BATCH, seq=SEQ)
    # the probe serves (and admits) at real sync-mode cost, mutating the
    # store — rebuild so BOTH legs start from the identical fresh state
    sess, _ = _build_session()
    workload = _workload(corpus, rate)

    out = {"config": {"arch": "bert_base (reduced, 2 layers)",
                      "requests": REQUESTS, "rate_rps": float(rate),
                      "buckets": list(BUCKETS), "max_batch": BATCH,
                      "threshold": float(sess.spec.runtime.threshold),
                      "backend": jax.default_backend()}}
    kw = dict(buckets=BUCKETS, max_batch=BATCH, max_delay=4e-3)
    out["sync"] = serve_trace(sess, workload, async_maintenance=False,
                              **kw)
    sess2, _ = _build_session()      # identical fresh store for the A/B
    out["async"] = serve_trace(sess2, workload, async_maintenance=True,
                               **kw)
    out["p99_async_over_sync"] = (out["async"]["p99_ms"]
                                  / max(out["sync"]["p99_ms"], 1e-9))
    out["hit_rate_gap"] = abs(out["async"]["hit_rate"]
                              - out["sync"]["hit_rate"])
    # facade overhead A/B on a third fresh session (the open-loop legs
    # above mutated sess/sess2's stores mid-trace)
    sess3, corpus3 = _build_session()
    out["facade_ab"] = _facade_ab(sess3, corpus3)
    return out


# ------------------------------------------------------------- sharded leg

_SHARDED_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.store import MemoStore
from repro.core.shard import ShardedMemoStore

APM, DIM = (2, 8, 8), 16
N, T, BATCH, ROUNDS, THR = 2048, 64, 64, 12, 1.0
rng = np.random.default_rng(0)

# clustered corpus: T well-separated templates, each entry a jittered
# template — queries near a template have an unambiguous nearest entry
templates = (rng.normal(0, 1.0, (T, DIM)) * 4.0).astype(np.float32)
assign = rng.integers(0, T, N)
embs = (templates[assign]
        + rng.normal(0, 0.05, (N, DIM))).astype(np.float32)
apms = rng.random((N, *APM)).astype(np.float16)

# equal TOTAL budget, sized so the live set exceeds one shard's
# positions several-fold (the big-memory acceptance shape, ISSUE 9)
entry = MemoStore(APM, DIM, codec="f16").entry_nbytes
budget = 1536 * entry


def build(sharded):
    kw = dict(index_kind="exact", codec="f16", capacity=256,
              budget_bytes=budget)
    s = (ShardedMemoStore(APM, DIM, n_shards=8, hot_k=32,
                          route_nprobe=4, **kw)
         if sharded else
         MemoStore(APM, DIM, device_index_kind="flat", **kw))
    for i in range(0, N, 256):     # identical admission stream -> both
        s.admit(apms[i:i + 256], embs[i:i + 256])   # stores evict the
    s.sync(force_full=True)                         # same slots
    return s


def queries(rng):
    # 3/4 near a template (should hit), 1/4 uniform noise (miss)
    t = templates[rng.integers(0, T, BATCH)]
    q = t + rng.normal(0, 0.05, (BATCH, DIM)).astype(np.float32)
    q[::4] = rng.normal(0, 8.0, (BATCH // 4 + 1, DIM))[: len(q[::4])]
    return jnp.asarray(q, jnp.float32)


def leg(s, sharded):
    di, db = s.device_index, s.device_db
    if sharded:
        fn = jax.jit(lambda args, parts, q: di.search_fetch(
            q, args=args, parts=parts))
    else:
        def fn(args, parts, q):
            d2, idx = di.search_device(q, args=args)
            i0 = idx[:, 0].astype(jnp.int32)
            return d2, idx, tuple(jnp.take(p, i0, 0) for p in parts)
        fn = jax.jit(fn)
    qrng = np.random.default_rng(42)     # same stream for both legs
    hits = total = 0
    times = []
    parity = True
    for r in range(ROUNDS):
        q = queries(qrng)
        jax.block_until_ready(fn(di.search_args, db.parts, q))
        t0 = time.perf_counter()
        d2, idx, rows = jax.block_until_ready(
            fn(di.search_args, db.parts, q))
        times.append(time.perf_counter() - t0)
        dist = np.sqrt(np.maximum(np.asarray(d2)[:, 0], 0.0))
        slot = np.asarray(idx)[:, 0]
        ok = (dist < THR) & (slot >= 0)
        hits += int(ok.sum())
        total += int(ok.size)
        if r == 0 and ok.any():          # fetched payload == arena rows
            want = s.codec.decode_rows(
                tuple(jnp.asarray(p)
                      for p in s.db.parts_at(slot[ok])))
            got = np.asarray(s.codec.decode_rows(
                tuple(np.asarray(p)[ok] for p in rows)), np.float32)
            parity = bool(np.allclose(got, np.asarray(want, np.float32),
                                      atol=1e-3))
    return {"hit_rate": hits / max(1, total),
            "search_us_per_q": float(np.median(times) * 1e6 / BATCH),
            "payload_parity": parity}

single = leg(build(False), False)
sh_store = build(True)
sharded = leg(sh_store, True)
st = sh_store.shard_stats()
live = int(sh_store.db.live_mask[: len(sh_store.db)].sum())
per_shard = sh_store.per_shard_budget_bytes
out = {
    "config": {"n_admitted": N, "dim": DIM, "batch": BATCH,
               "rounds": ROUNDS, "threshold": THR,
               "budget_mb": budget / 1e6, "n_shards": 8,
               "route_nprobe": 4,
               "backend": jax.default_backend()},
    "single": single,
    "sharded": dict(sharded, occupancy=st["occupancy"],
                    imbalance=st["imbalance"],
                    n_shard_evictions=st["n_shard_evictions"],
                    n_spills=st["n_spills"],
                    per_shard_budget_mb=per_shard / 1e6,
                    db_over_shard_budget=live * entry / per_shard),
    "hit_gap": abs(single["hit_rate"] - sharded["hit_rate"]),
    "payload_parity": bool(single["payload_parity"]
                           and sharded["payload_parity"]),
}
assert out["sharded"]["db_over_shard_budget"] > 1.0, out
print("SHARDBENCH", json.dumps(out))
"""


@functools.lru_cache(maxsize=1)
def collect_sharded():
    """8-way mesh sharded-store leg, in a subprocess (the parent jax
    already initialized with the default device count)."""
    env = dict(os.environ, PYTHONPATH="src")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", _SHARDED_CODE],
                         capture_output=True, text=True, env=env,
                         cwd=repo, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("SHARDBENCH "):
            return json.loads(line[len("SHARDBENCH "):])
    raise RuntimeError(f"sharded bench subprocess failed:\n"
                       f"{out.stderr[-3000:]}")


def run_sharded():
    out = collect_sharded()
    sh, si = out["sharded"], out["single"]
    yield ("serve_sharded", sh["search_us_per_q"],
           f"hit={sh['hit_rate']:.3f};single_hit={si['hit_rate']:.3f};"
           f"hit_gap={out['hit_gap']:.3f};"
           f"imbalance={sh['imbalance']:.2f};"
           f"db_over_shard={sh['db_over_shard_budget']:.1f}x;"
           f"single_us={si['search_us_per_q']:.0f};"
           f"parity={out['payload_parity']}")


def run():
    out = collect()
    for mode in ("sync", "async"):
        r = out[mode]
        yield (f"serve_runtime_{mode}", r["p99_ms"] * 1e3,
               f"p50={r['p50_ms']:.1f}ms;p99={r['p99_ms']:.1f}ms;"
               f"rps={r['throughput_rps']:.1f};"
               f"hit={r['hit_rate']:.3f}")
    yield ("serve_runtime_overlap", 0.0,
           f"p99_ratio={out['p99_async_over_sync']:.3f};"
           f"hit_gap={out['hit_rate_gap']:.3f}")
    fa = out["facade_ab"]
    yield ("serve_runtime_facade", fa["facade_p50_ms"] * 1e3,
           f"direct_p50={fa['direct_p50_ms']:.1f}ms;"
           f"wall_ratio={fa['facade_over_direct']:.3f};"
           f"wrapper={fa['wrapper_us']:.0f}us;"
           f"overhead_frac={fa['facade_overhead_frac']:.2e}")

"""Open-loop serving-runtime benchmark (ISSUE 4 / DESIGN.md §2.7).

Serves one Poisson-arrival, variable-length, mid-run-drifting request
trace through the MemoServer runtime twice — synchronous batch-boundary
maintenance vs the off-thread worker — on identically rebuilt engines,
and records throughput + p50/p99 latency + hit rate for both. Emitted
into BENCH_serve.json as the ``serve_runtime`` section; the regression
gate tracks the async/sync p99 ratio (``--check-regress``), which is
machine-independent because both legs run on the same box back to back.

Engines are built fresh per leg (NOT the lru-shared ``built_engine``):
serving mutates the store, and the A/B is only honest if both legs start
from the identical calibration state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_encoder
from repro.core.engine import MemoConfig, MemoEngine
from repro.data import TemplateCorpus
from repro.launch.server import probe_rate, serve_trace

SEQ = 32
BATCH = 8
REQUESTS = 120
BUCKETS = (16, 32)


def _build_engine():
    model, params, corpus = trained_encoder("bert_base", n_layers=2,
                                            seq_len=SEQ)
    eng = MemoEngine(model, params, MemoConfig(
        mode="bucket", embed_steps=120, admit=True, budget_mb=256.0,
        recal_every=2, device_slack=8.0))
    # dedicated rng: both A/B legs must build the IDENTICAL store (the
    # shared corpus rng advances between calls)
    rng = np.random.default_rng(123)
    eng.build(jax.random.PRNGKey(1),
              [{"tokens": jnp.asarray(corpus.sample(BATCH, rng)[0])}
               for _ in range(4)])
    eng.mc.threshold = eng.suggest_levels(
        [{"tokens": jnp.asarray(corpus.sample(BATCH, rng)[0])}
         ])["aggressive"]
    return eng, corpus


def _workload(corpus, rate: float):
    """Poisson arrivals; two lengths per bucket (so the length-gated
    store adapts quickly and both legs reach the same steady hit rate);
    corpus drifts at the midpoint — the phase where maintenance
    (admission + delta sync + recal) is busiest."""
    rng = np.random.default_rng(7)
    drifted = TemplateCorpus(vocab=corpus.vocab, seq_len=SEQ, seed=117,
                             n_templates=corpus.n_templates,
                             slot_fraction=corpus.slot_fraction)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, REQUESTS))
    wl = []
    for i in range(REQUESTS):
        src = corpus if i < REQUESTS // 2 else drifted
        bucket = int(rng.choice(BUCKETS))
        length = bucket - int(rng.choice([0, 2]))
        wl.append((float(arrivals[i]), src.sample(1, rng)[0][0, :length]))
    return wl


@functools.lru_cache(maxsize=1)
def collect():
    eng, corpus = _build_engine()
    rate = probe_rate(eng, buckets=BUCKETS, max_batch=BATCH, seq=SEQ)
    # the probe serves (and admits) at real sync-mode cost, mutating the
    # store — rebuild so BOTH legs start from the identical fresh state
    eng, _ = _build_engine()
    workload = _workload(corpus, rate)

    out = {"config": {"arch": "bert_base (reduced, 2 layers)",
                      "requests": REQUESTS, "rate_rps": float(rate),
                      "buckets": list(BUCKETS), "max_batch": BATCH,
                      "threshold": float(eng.mc.threshold),
                      "backend": jax.default_backend()}}
    kw = dict(buckets=BUCKETS, max_batch=BATCH, max_delay=4e-3)
    out["sync"] = serve_trace(eng, workload, async_maintenance=False,
                              **kw)
    eng2, _ = _build_engine()        # identical fresh store for the A/B
    out["async"] = serve_trace(eng2, workload, async_maintenance=True,
                               **kw)
    out["p99_async_over_sync"] = (out["async"]["p99_ms"]
                                  / max(out["sync"]["p99_ms"], 1e-9))
    out["hit_rate_gap"] = abs(out["async"]["hit_rate"]
                              - out["sync"]["hit_rate"])
    return out


def run():
    out = collect()
    for mode in ("sync", "async"):
        r = out[mode]
        yield (f"serve_runtime_{mode}", r["p99_ms"] * 1e3,
               f"p50={r['p50_ms']:.1f}ms;p99={r['p99_ms']:.1f}ms;"
               f"rps={r['throughput_rps']:.1f};"
               f"hit={r['hit_rate']:.3f}")
    yield ("serve_runtime_overlap", 0.0,
           f"p99_ratio={out['p99_async_over_sync']:.3f};"
           f"hit_gap={out['hit_rate_gap']:.3f}")

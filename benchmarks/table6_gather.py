"""Paper Table 6 — APM gathering: per-entry copy (the PyTorch strawman) vs
arena fancy-index gather (host zero-copy analogue) vs fused device gather
(DeviceDB / the memo_attention BlockSpec gather)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.database import AttentionDB, DeviceDB


def _time(fn, reps=5):
    fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


def run():
    rows = []
    rng = np.random.default_rng(0)
    for L, B in ((64, 32), (128, 32), (128, 64)):
        H, N = 4, 256
        db = AttentionDB((H, L, L), capacity=N)
        db.add(rng.random((N, H, L, L)).astype(np.float16))
        ids = rng.integers(0, N, B)
        t_naive = _time(lambda: db.get_naive(ids))
        t_arena = _time(lambda: db.get(ids, count_reuse=False))
        ddb = DeviceDB(jnp.asarray(db._arena[:N], jnp.float16))
        idx = jnp.asarray(ids)
        gather = jax.jit(ddb.gather)
        t_dev = _time(lambda: gather(idx))
        rows.append((f"table6/L{L}_B{B}_copy", t_naive * 1e3, "per-entry copy"))
        rows.append((f"table6/L{L}_B{B}_arena", t_arena * 1e3,
                     f"speedup={t_naive / max(t_arena, 1e-9):.1f}x"))
        rows.append((f"table6/L{L}_B{B}_device", t_dev * 1e3,
                     f"speedup={t_naive / max(t_dev, 1e-9):.1f}x"))
    return rows

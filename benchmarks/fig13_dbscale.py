"""Paper Fig. 13 — bigger attention database => higher memo rate (the
big-memory trade)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import trained_encoder
from repro.memo import MemoSession, MemoSpec


def run():
    rows = []
    model, params, corpus = trained_encoder()
    toks = jnp.asarray(corpus.sample(48)[0])
    for n_calib in (2, 4, 8):
        batches = [{"tokens": jnp.asarray(corpus.sample(32)[0])}
                   for _ in range(n_calib)]
        eng = MemoSession.build(
            model, params,
            MemoSpec.flat(threshold=0.85, embed_steps=100),
            batches=batches, key=jax.random.PRNGKey(1)).engine
        thr = eng.suggest_levels(
            [{"tokens": jnp.asarray(corpus.sample(16)[0])}])["moderate"]
        _, st = eng.infer({"tokens": toks}, threshold=thr)
        rows.append((f"fig13/db{len(eng.db)}", 0.0,
                     f"db_mb={eng.db.nbytes/1e6:.1f};"
                     f"memo_rate={st.memo_rate:.2f}"))
    return rows

"""Roofline analysis (deliverable g) from the dry-run artifacts.

Per (arch × shape) on the single-pod mesh:
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw
(cost_analysis is per-device on the SPMD-partitioned module; scan bodies
are corrected via the unrolled 1/2-unit diff — see launch/dryrun.py.)

Also reports MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / (HLO_FLOPs × devices).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
HBM_CAP = 16e9               # B / chip


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq


def analyze(rec) -> dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    dev = rec["devices"]
    corr = rec["corrected"]
    full_coll = rec.get("full", {}).get("collectives", {}).get("total", 0)
    t_comp = corr["flops"] / PEAK_FLOPS
    t_mem = corr["bytes"] / HBM_BW
    # the unroll-diff can go slightly negative when XLA fuses collectives
    # differently between the 1- and 2-unit lowerings; clamp to the static
    # count from the full compile
    t_coll = max(corr["collective_bytes"], full_coll) / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = corr["flops"] * dev
    peak = rec.get("full", {}).get("peak_bytes", 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "bottleneck": dom,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "peak_gb": peak / 1e9,
        "fits_hbm": bool(peak and peak <= HBM_CAP),
        "step_lower_bound_s": max(terms.values()),
    }


def load_records(dirpath="experiments/dryrun", mesh="pod256"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, f"*_{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def table(dirpath="experiments/dryrun") -> str:
    rows = [analyze(r) for r in load_records(dirpath)]
    hdr = (f"{'arch':18s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>10s} {'useful':>7s} {'peakGB':>7s} "
           f"{'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for a in rows:
        lines.append(
            f"{a['arch']:18s} {a['shape']:12s} {a['compute_s']:10.3e} "
            f"{a['memory_s']:10.3e} {a['collective_s']:10.3e} "
            f"{a['bottleneck']:>10s} {a['useful_ratio']:7.2f} "
            f"{a['peak_gb']:7.2f} {str(a['fits_hbm']):>5s}")
    return "\n".join(lines)


def run():
    rows = []
    for rec in load_records():
        a = analyze(rec)
        rows.append((
            f"roofline/{a['arch']}_{a['shape']}",
            a["step_lower_bound_s"] * 1e6,
            f"bound={a['bottleneck']};compute_s={a['compute_s']:.3e};"
            f"memory_s={a['memory_s']:.3e};"
            f"collective_s={a['collective_s']:.3e};"
            f"useful={a['useful_ratio']:.2f};peak_gb={a['peak_gb']:.2f}"))
    return rows


if __name__ == "__main__":
    print(table())

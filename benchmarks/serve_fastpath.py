"""Serve latency: device-resident fast path vs host-synchronous path.

The ISSUE-1 acceptance benchmark: end-to-end ``infer`` latency for
``select``/``bucket``/``kernel`` modes with and without the device fast
path on the reduced bert_base config (CPU, interpret mode), plus a
per-phase breakdown (embed / search / fetch / attn). The host path's
phases come from its per-layer timers; the fused device path has no
per-layer timers by design (that is the point), so its phases are
microbenchmarked on the same tensors.

Emitted as machine-readable JSON by ``python -m benchmarks.run
--json BENCH_serve.json`` for the perf trajectory.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import built_engine, timeit_ms
from repro.core.engine import MemoStats

BATCH = 32
REPS = {"select": 8, "bucket": 8, "kernel": 2}   # kernel = interpret-slow


def _median_ms(eng, toks, thr, reps):
    ts = []
    st = MemoStats()
    for _ in range(reps + 2):
        t0 = time.perf_counter()
        logits, st = eng.infer({"tokens": toks}, threshold=thr, stats=st)
        jax.block_until_ready(logits)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts[2:]) * 1e3), st, logits


def _phase_micro(eng, toks):
    """Per-phase latencies on the serving tensors (whole batch, one
    memoizable layer): embed MLP, index search (host numpy round-trip vs
    fused device search), APM fetch (host arena gather + transfer vs
    device gather), and the attention both ways."""
    import repro.models.backbone as bb
    h = bb.embed_tokens(eng.params, toks, eng.cfg)
    positions = jnp.broadcast_to(
        jnp.arange(toks.shape[1], dtype=jnp.int32), toks.shape)
    li, kind, lp = eng._iter_layers()[0]
    x = bb.norm_apply(lp["norm1"], h, eng.cfg.norm)
    emb_dev = eng._embed(x)
    emb_np = np.asarray(emb_dev)
    idx_np = eng.index.search(emb_np, 1)[1][:, 0]
    idx_dev = jnp.asarray(idx_np, jnp.int32)
    apm = jnp.asarray(eng.db.get(idx_np, count_reuse=False))
    search_dev = jax.jit(
        lambda q, a: eng.device_index.search_device(q, args=a)[1])
    codec = eng.store.codec
    gather_dev = jax.jit(lambda parts, i: codec.decode_rows(
        tuple(jnp.take(p, i, axis=0) for p in parts)))
    return {
        "embed_ms": timeit_ms(lambda: eng._embed(x)),
        "search_host_ms": timeit_ms(lambda: eng.index.search(emb_np, 1)),
        "search_device_ms": timeit_ms(
            lambda: search_dev(emb_dev, eng.device_index.search_args)),
        "fetch_host_ms": timeit_ms(
            lambda: jnp.asarray(eng.db.get(idx_np, count_reuse=False))),
        # the hot-path fetch: compressed gather + on-device dequant
        "fetch_device_ms": timeit_ms(
            lambda: gather_dev(eng.device_db.parts, idx_dev)),
        "attn_full_ms": timeit_ms(
            lambda: eng._attn_only(lp, x, kind, positions)),
        "attn_memo_ms": timeit_ms(
            lambda: eng._memo_only(lp, x, kind, apm.astype(jnp.float32))),
    }


@functools.lru_cache(maxsize=1)
def collect():
    eng, corpus = built_engine(threshold=0.8, mode="select")
    toks = jnp.asarray(corpus.sample(BATCH)[0])
    old = (eng.mc.mode, eng.mc.device_fast_path)
    levels = {"moderate": float(eng.levels["moderate"]),
              "aggressive": float(eng.levels["aggressive"])}

    by_level = {}
    try:      # the engine is lru-shared with other benchmark modules:
        for level, thr in levels.items():      # never leak a mode switch
            eng.mc.mode, eng.mc.device_fast_path = "select", None
            ref_ms, _, ref_logits = _median_ms(eng, toks, thr,
                                               REPS["select"])
            ref_logits = np.asarray(ref_logits)
            modes = {"select": {"host_ms": ref_ms}}
            for mode in ("bucket", "kernel"):
                eng.mc.mode = mode
                eng.mc.device_fast_path = False
                host_ms, host_st, _ = _median_ms(eng, toks, thr, REPS[mode])
                eng.mc.device_fast_path = True
                fast_ms, fast_st, fast_logits = _median_ms(eng, toks, thr,
                                                           REPS[mode])
                modes[mode] = {
                    "host_ms": host_ms,
                    "fast_ms": fast_ms,
                    "speedup": host_ms / fast_ms,
                    "memo_rate": fast_st.memo_rate,
                    "host_phases_s": {"embed": host_st.t_embed,
                                      "search": host_st.t_search,
                                      "fetch": host_st.t_fetch,
                                      "attn": host_st.t_attn},
                    "logits_match_select": bool(np.allclose(
                        np.asarray(fast_logits), ref_logits, rtol=2e-3,
                        atol=2e-3)),
                    "logits_max_abs_diff": float(np.max(np.abs(
                        np.asarray(fast_logits) - ref_logits))),
                }
            by_level[level] = {"threshold": thr, "modes": modes}
        eng.mc.mode, eng.mc.device_fast_path = "select", None
        phases = _phase_micro(eng, toks)
    finally:
        eng.mc.mode, eng.mc.device_fast_path = old
    return {
        "config": {"arch": "bert_base (reduced)", "batch": BATCH,
                   "seq": int(toks.shape[1]),
                   "backend": jax.default_backend(),
                   "interpret": jax.default_backend() == "cpu"},
        "levels": by_level,
        "phase_micro_ms": phases,
    }


def run():
    out = collect()
    for level, blk in out["levels"].items():
        for mode, row in blk["modes"].items():
            yield (f"serve_{level}_{mode}_host", row["host_ms"] * 1e3,
                   f"rate={row.get('memo_rate', '')}")
            if "fast_ms" in row:
                yield (f"serve_{level}_{mode}_fast", row["fast_ms"] * 1e3,
                       f"speedup={row['speedup']:.2f}x "
                       f"match={row['logits_match_select']}")
    for name, ms in out["phase_micro_ms"].items():
        yield (f"serve_phase_{name}", ms * 1e3, "")

"""Serve latency: device-resident fast path vs host-synchronous path.

The ISSUE-1 acceptance benchmark: end-to-end ``infer`` latency for
``select``/``bucket``/``kernel`` modes with and without the device fast
path on the reduced bert_base config (CPU, interpret mode), plus a
per-phase breakdown (embed / search / fetch / attn). The host path's
phases come from its per-layer timers; the fused device path has no
per-layer timers by design (that is the point), so its phases are
microbenchmarked on the same tensors.

Emitted as machine-readable JSON by ``python -m benchmarks.run
--json BENCH_serve.json`` for the perf trajectory. ``collect_kernel``
adds the ``serve_kernel`` family (ISSUE 7): kernel-mode latency ratios
vs bucket and select plus a modeled HBM-bytes-moved account of the
fused dispatch. Standalone:

    python -m benchmarks.serve_fastpath --quick   # interpret-Pallas smoke
    python -m benchmarks.serve_fastpath --hw      # compiled TPU/GPU leg
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import built_engine, timeit_ms
from repro.core.engine import MemoStats

BATCH = 32
# kernel mode now serves through the one-matmul XLA form on CPU
# (engine._kernel_impl), so its timings are as stable as bucket's
REPS = {"select": 8, "bucket": 8, "kernel": 8}


def _median_ms(eng, toks, thr, reps):
    ts = []
    st = MemoStats()
    for _ in range(reps + 2):
        t0 = time.perf_counter()
        logits, st = eng.infer({"tokens": toks}, threshold=thr, stats=st)
        jax.block_until_ready(logits)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts[2:]) * 1e3), st, logits


def _phase_micro(eng, toks):
    """Per-phase latencies on the serving tensors (whole batch, one
    memoizable layer): embed MLP, index search (host numpy round-trip vs
    fused device search), APM fetch (host arena gather + transfer vs
    device gather), and the attention both ways."""
    import repro.models.backbone as bb
    h = bb.embed_tokens(eng.params, toks, eng.cfg)
    positions = jnp.broadcast_to(
        jnp.arange(toks.shape[1], dtype=jnp.int32), toks.shape)
    li, kind, lp = eng._iter_layers()[0]
    x = bb.norm_apply(lp["norm1"], h, eng.cfg.norm)
    emb_dev = eng._embed(x)
    emb_np = np.asarray(emb_dev)
    idx_np = eng.index.search(emb_np, 1)[1][:, 0]
    idx_dev = jnp.asarray(idx_np, jnp.int32)
    apm = jnp.asarray(eng.db.get(idx_np, count_reuse=False))
    search_dev = jax.jit(
        lambda q, a: eng.device_index.search_device(q, args=a)[1])
    codec = eng.store.codec
    gather_dev = jax.jit(lambda parts, i: codec.decode_rows(
        tuple(jnp.take(p, i, axis=0) for p in parts)))
    return {
        "embed_ms": timeit_ms(lambda: eng._embed(x)),
        "search_host_ms": timeit_ms(lambda: eng.index.search(emb_np, 1)),
        "search_device_ms": timeit_ms(
            lambda: search_dev(emb_dev, eng.device_index.search_args)),
        "fetch_host_ms": timeit_ms(
            lambda: jnp.asarray(eng.db.get(idx_np, count_reuse=False))),
        # the hot-path fetch: compressed gather + on-device dequant
        "fetch_device_ms": timeit_ms(
            lambda: gather_dev(eng.device_db.parts, idx_dev)),
        "attn_full_ms": timeit_ms(
            lambda: eng._attn_only(lp, x, kind, positions)),
        "attn_memo_ms": timeit_ms(
            lambda: eng._memo_only(lp, x, kind, apm.astype(jnp.float32))),
    }


@functools.lru_cache(maxsize=1)
def collect():
    eng, corpus = built_engine(threshold=0.8, mode="select")
    toks = jnp.asarray(corpus.sample(BATCH)[0])
    old = (eng.mc.mode, eng.mc.device_fast_path)
    levels = {"moderate": float(eng.levels["moderate"]),
              "aggressive": float(eng.levels["aggressive"])}

    by_level = {}
    try:      # the engine is lru-shared with other benchmark modules:
        for level, thr in levels.items():      # never leak a mode switch
            eng.mc.mode, eng.mc.device_fast_path = "select", None
            ref_ms, _, ref_logits = _median_ms(eng, toks, thr,
                                               REPS["select"])
            ref_logits = np.asarray(ref_logits)
            modes = {"select": {"host_ms": ref_ms}}
            for mode in ("bucket", "kernel"):
                eng.mc.mode = mode
                eng.mc.device_fast_path = False
                host_ms, host_st, _ = _median_ms(eng, toks, thr, REPS[mode])
                eng.mc.device_fast_path = True
                fast_ms, fast_st, fast_logits = _median_ms(eng, toks, thr,
                                                           REPS[mode])
                modes[mode] = {
                    "host_ms": host_ms,
                    "fast_ms": fast_ms,
                    "speedup": host_ms / fast_ms,
                    "memo_rate": fast_st.memo_rate,
                    "host_phases_s": {"embed": host_st.t_embed,
                                      "search": host_st.t_search,
                                      "fetch": host_st.t_fetch,
                                      "attn": host_st.t_attn},
                    "logits_match_select": bool(np.allclose(
                        np.asarray(fast_logits), ref_logits, rtol=2e-3,
                        atol=2e-3)),
                    "logits_max_abs_diff": float(np.max(np.abs(
                        np.asarray(fast_logits) - ref_logits))),
                }
            by_level[level] = {"threshold": thr, "modes": modes}
        eng.mc.mode, eng.mc.device_fast_path = "select", None
        phases = _phase_micro(eng, toks)
    finally:
        eng.mc.mode, eng.mc.device_fast_path = old
    return {
        "config": {"arch": "bert_base (reduced)", "batch": BATCH,
                   "seq": int(toks.shape[1]),
                   "backend": jax.default_backend(),
                   "interpret": jax.default_backend() == "cpu"},
        "levels": by_level,
        "phase_micro_ms": phases,
    }


def _hbm_bytes_model(cfg, codec_name, B, S, n_hit):
    """Modeled HBM→VMEM bytes per memoized layer for one batch, from
    tile counts × codec bytes (what the fused dispatch's index maps
    admit — boundary refetches, ≤1 per operand per hit↔miss boundary,
    are ignored):

    * ``kernel_fused`` — the hit flag drives the index maps: a miss
      program streams Q (once per q-row) + K/V; a hit program streams
      V + its APM tiles + (int8) the per-row scale slivers, and zero
      Q/K bytes. Misses move zero DB bytes.
    * ``kernel_unfused`` — the pre-aliasing design: every program
      fetched every operand (misses speculatively streamed entry 0's
      APM row; hits still paid the full K stream).
    * ``gather_path`` — the select/bucket shape: gather + dequantize
      all B full APMs out of the DB, then stream Q/K/V for attention.
    """
    H = cfg.n_heads
    Hkv = getattr(cfg, "n_kv_heads", None) or H
    dh = cfg.d_model // H
    blk = max(8, min(128, S))
    Sp = -(-S // blk) * blk
    nq = nk = Sp // blk
    t_q = blk * dh * 4                              # f32 activations
    t_kv = blk * dh * 4
    code_b = 1 if codec_name == "int8" else 2
    t_apm = blk * blk * code_b
    sliver = blk * 2 if codec_name == "int8" else 0
    n_miss = B - n_hit
    miss = nq * t_q + nq * nk * 2 * t_kv            # Q per row, K+V stream
    hit = nq * nk * (t_kv + t_apm) + nq * sliver    # V + APM (+ scales)
    fused = H * (n_hit * hit + n_miss * miss)
    every = nq * t_q + nq * nk * (2 * t_kv + t_apm) + nq * sliver
    unfused = H * B * every
    gather = B * H * (S * S * code_b + (S * 2 if code_b == 1 else 0))
    gather_path = gather + H * B * (nq * t_q + nq * nk * 2 * t_kv)
    return {"kernel_fused": int(fused), "kernel_unfused": int(unfused),
            "gather_path": int(gather_path),
            "fused_over_unfused": fused / max(1, unfused),
            "fused_over_gather": fused / max(1, gather_path)}


def _codec_parity():
    """Kernel-mode select-parity under BOTH streamed codecs (the fused
    dispatch has a distinct tile path per codec — f16 tiles vs int8
    codes + scale slivers): a small 2-layer engine per codec, one
    kernel-mode batch vs its own select reference."""
    from benchmarks.common import trained_encoder
    from repro.data import TemplateCorpus
    from repro.memo import MemoSession, MemoSpec
    model, params, _ = trained_encoder("bert_base", n_layers=2, seq_len=32)
    corpus = TemplateCorpus(vocab=model.cfg.vocab, seq_len=32,
                            n_templates=6, slot_fraction=0.2, seed=0)
    calib = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
             for _ in range(3)]
    toks = jnp.asarray(corpus.sample(16)[0])
    out = {}
    for codec in ("f16", "int8"):
        sess = MemoSession.build(
            model, params,
            MemoSpec.flat(threshold=0.8, mode="select", embed_steps=60,
                          apm_codec=codec, device_slack=4.0),
            batches=calib, key=jax.random.PRNGKey(1))
        eng = sess.engine
        thr = float(eng.suggest_levels([calib[0]])["moderate"])
        ref, _ = eng.infer({"tokens": toks}, threshold=thr)
        eng.mc.mode = "kernel"
        fast, st = eng.infer({"tokens": toks}, threshold=thr)
        out[codec] = {
            "memo_rate": st.memo_rate,
            "logits_match_select": bool(np.allclose(
                np.asarray(fast), np.asarray(ref), rtol=2e-3, atol=2e-3)),
            "logits_max_abs_diff": float(np.max(np.abs(
                np.asarray(fast) - np.asarray(ref)))),
        }
    return out


@functools.lru_cache(maxsize=1)
def collect_kernel():
    """The ``serve_kernel`` family (ISSUE 7): kernel mode's standing
    relative to the bucket fast path and the select reference, the
    modeled HBM-byte account, and select-parity under both streamed
    codecs. Reuses the lru-cached ``collect()`` sweep — free when
    serve_fastpath already ran."""
    base = collect()
    eng, corpus = built_engine(threshold=0.8, mode="select")
    S = base["config"]["seq"]
    levels = {}
    for level, blk in base["levels"].items():
        kern = blk["modes"]["kernel"]
        buck = blk["modes"]["bucket"]
        sel_ms = blk["modes"]["select"]["host_ms"]
        n_hit = int(round(kern["memo_rate"] * BATCH))
        levels[level] = {
            "threshold": blk["threshold"],
            "kernel_fast_ms": kern["fast_ms"],
            "kernel_speedup": kern["speedup"],          # host/fast, >1 wins
            "kernel_over_bucket": kern["fast_ms"] / buck["fast_ms"],
            "kernel_over_select": kern["fast_ms"] / sel_ms,
            "memo_rate": kern["memo_rate"],
            "logits_match_select": kern["logits_match_select"],
            "hbm_bytes_model": _hbm_bytes_model(
                eng.cfg, eng.store.codec.name, BATCH, S, n_hit),
        }
    return {"config": base["config"], "kernel_impl": eng._kernel_impl,
            "levels": levels, "codec_parity": _codec_parity()}


def run():
    out = collect()
    for level, blk in out["levels"].items():
        for mode, row in blk["modes"].items():
            yield (f"serve_{level}_{mode}_host", row["host_ms"] * 1e3,
                   f"rate={row.get('memo_rate', '')}")
            if "fast_ms" in row:
                yield (f"serve_{level}_{mode}_fast", row["fast_ms"] * 1e3,
                       f"speedup={row['speedup']:.2f}x "
                       f"match={row['logits_match_select']}")
    for name, ms in out["phase_micro_ms"].items():
        yield (f"serve_phase_{name}", ms * 1e3, "")
    kern = collect_kernel()
    for level, row in kern["levels"].items():
        hbm = row["hbm_bytes_model"]
        yield (f"serve_kernel_{level}", row["kernel_fast_ms"] * 1e3,
               f"vs_bucket={row['kernel_over_bucket']:.2f}x "
               f"vs_select={row['kernel_over_select']:.2f}x "
               f"hbm_fused_mb={hbm['kernel_fused'] / 1e6:.1f} "
               f"hbm_ratio={hbm['fused_over_unfused']:.2f}")


def _quick_smoke():
    """CI leg (kernel-smoke): one interpret-Pallas kernel-mode batch vs
    the select reference — compiled-path semantics under the interpreter,
    small enough to finish in seconds."""
    eng, corpus = built_engine(threshold=0.8, mode="select")
    toks = jnp.asarray(corpus.sample(8)[0])
    thr = float(eng.levels["moderate"])
    old = (eng.mc.mode, eng.mc.kernel_impl, eng.mc.device_fast_path)
    try:
        eng.mc.mode, eng.mc.device_fast_path = "select", None
        ref, _ = eng.infer({"tokens": toks}, threshold=thr)
        eng.mc.mode = "kernel"
        eng.mc.kernel_impl = "pallas"     # pin the kernel: this leg exists
        eng.mc.device_fast_path = True    # to smoke the Pallas dispatch
        out, st = eng.infer({"tokens": toks}, threshold=thr)
        ok = bool(np.allclose(np.asarray(out), np.asarray(ref),
                              rtol=2e-3, atol=2e-3))
        print(f"quick kernel smoke: parity={ok} "
              f"memo_rate={st.memo_rate:.2f} backend=interpret")
        return 0 if ok else 1
    finally:
        eng.mc.mode, eng.mc.kernel_impl, eng.mc.device_fast_path = old


def _hw_leg():
    """Real-hardware leg: the compiled (interpret=False) fused kernel on
    TPU/GPU. Skips cleanly on CPU — the interpreter numbers are covered
    by --quick and the XLA-form numbers by the main sweep."""
    if jax.default_backend() == "cpu":
        print("serve_fastpath --hw: backend is cpu (no accelerator) — "
              "skipping the compiled-kernel leg")
        return 0
    eng, corpus = built_engine(threshold=0.8, mode="select")
    toks = jnp.asarray(corpus.sample(BATCH)[0])
    old = (eng.mc.mode, eng.mc.kernel_impl, eng.mc.device_fast_path,
           eng.mc.interpret)
    try:
        eng.mc.mode, eng.mc.device_fast_path = "select", None
        for level in ("moderate", "aggressive"):
            thr = float(eng.levels[level])
            eng.mc.mode, eng.mc.kernel_impl = "select", None
            ref_ms, _, ref = _median_ms(eng, toks, thr, REPS["select"])
            eng.mc.mode = "kernel"
            eng.mc.kernel_impl = "pallas"
            eng.mc.interpret = False      # compiled Pallas, not interpreter
            eng.mc.device_fast_path = True
            fast_ms, st, logits = _median_ms(eng, toks, thr, REPS["kernel"])
            ok = bool(np.allclose(np.asarray(logits), np.asarray(ref),
                                  rtol=2e-3, atol=2e-3))
            print(f"hw kernel {level}: {fast_ms:.2f}ms vs select "
                  f"{ref_ms:.2f}ms ({ref_ms / fast_ms:.2f}x) "
                  f"rate={st.memo_rate:.2f} parity={ok} "
                  f"backend={jax.default_backend()}")
    finally:
        (eng.mc.mode, eng.mc.kernel_impl, eng.mc.device_fast_path,
         eng.mc.interpret) = old
    return 0


if __name__ == "__main__":
    import argparse
    import sys
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one interpret-Pallas kernel batch vs select")
    ap.add_argument("--hw", action="store_true",
                    help="compiled-kernel leg on TPU/GPU (skips on CPU)")
    a = ap.parse_args()
    if a.quick:
        sys.exit(_quick_smoke())
    if a.hw:
        sys.exit(_hw_leg())
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")

"""Online-adaptation benchmark (ISSUE 2 / DESIGN.md §2.5).

Measures the MemoStore lifecycle under corpus drift on a small trained
encoder: steady-state hit rate and ms/batch with online admission ON vs
a frozen store, plus the transfer cost of generation-counted delta sync
vs the full-resync-per-mutation strawman. Emitted into BENCH_serve.json
by ``python -m benchmarks.run --json`` as the ``serve_online`` section —
the adaptation baseline future store PRs (sharded, multi-tenant, async)
regress against.

The engine is built fresh here (NOT the lru-shared ``built_engine``):
admission mutates the store, and leaking admitted entries into the other
benchmark modules would corrupt their numbers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_encoder
from repro.data import TemplateCorpus
from repro.memo import MemoSession, MemoSpec, MemoStats
from repro.launch.serve import _run_phase

BATCH = 16
SEQ = 32
PHASE_BATCHES = 8


@functools.lru_cache(maxsize=1)
def collect():
    model, params, _ = trained_encoder("bert_base", n_layers=2,
                                       seq_len=SEQ)
    corpus = TemplateCorpus(vocab=model.cfg.vocab, seq_len=SEQ,
                            n_templates=6, slot_fraction=0.2, seed=0)
    # generous device slack: admissions land as deltas for the whole run
    # instead of tripping mid-run full re-materializations (shape change =
    # fused-jit retrace)
    sess = MemoSession.build(
        model, params,
        MemoSpec.flat(mode="bucket", embed_steps=150, budget_mb=256.0,
                      device_slack=8.0),
        batches=[{"tokens": jnp.asarray(corpus.sample(BATCH)[0])}
                 for _ in range(4)],
        key=jax.random.PRNGKey(1))
    # per-model autotuned threshold (paper Table 2 / §5.4) from a FRESH
    # calibration-distribution sample
    sess.autotune([{"tokens": jnp.asarray(corpus.sample(BATCH)[0])}],
                  level="aggressive")
    eng = sess.engine

    def drifted(seed):
        return TemplateCorpus(vocab=model.cfg.vocab, seq_len=SEQ,
                              n_templates=6, slot_fraction=0.2, seed=seed)

    out = {"config": {"arch": "bert_base (reduced, 2 layers)",
                      "batch": BATCH, "seq": SEQ,
                      "threshold": float(eng.mc.threshold),
                      "phase_batches": PHASE_BATCHES,
                      "backend": jax.default_backend()}}
    # frozen pass first: it does not admit/evict, so both passes start
    # from the identical calibration-built store; reuse_counts (the
    # eviction clock's input) still warm during serving and are restored
    counts0 = eng.db.reuse_counts.copy()
    for label, admit in (("frozen", False), ("adaptive", True)):
        eng.mc.admit = admit
        eng.db.reuse_counts[:] = counts0
        st = MemoStats()
        r0, t0_, st = _run_phase(eng, drifted(0), PHASE_BATCHES, BATCH, st)
        r1, t1_, st = _run_phase(eng, drifted(117), PHASE_BATCHES, BATCH,
                                 st)
        out[label] = {
            "phase0_hit_rate": float(np.mean(r0)),
            "drift_hit_rates": [float(r) for r in r1],
            "drift_steady_hit_rate": float(np.mean(r1[len(r1) // 2:])),
            # steady state: drift-phase tail (compiles + the admission
            # warm-up happen in the head)
            "ms_per_batch": float(np.median(t1_[len(t1_) // 2:])),
        }
    eng.mc.admit = False
    s = eng.store.stats
    entry = eng.store.entry_nbytes
    out["store"] = {
        "n_admitted": s.n_admitted,
        "n_evicted": s.n_evicted,
        "live_entries": eng.store.live_count,
        "n_delta_syncs": s.n_delta_syncs,
        "n_full_syncs": s.n_full_syncs,
        "delta_sync_bytes": s.bytes_delta,
        "full_sync_bytes": s.bytes_full,
        # the pre-store strawman: every admission batch re-ships the arena
        "full_resync_per_mutation_bytes": s.n_delta_syncs
        * len(eng.db) * entry,
    }
    fr = out["frozen"]["drift_steady_hit_rate"]
    ad = out["adaptive"]["drift_steady_hit_rate"]
    out["recovery_ratio"] = float("inf") if fr == 0 else ad / fr
    return out


def run():
    out = collect()
    for label in ("frozen", "adaptive"):
        row = out[label]
        yield (f"serve_online_{label}", row["ms_per_batch"] * 1e3,
               f"drift_steady_rate={row['drift_steady_hit_rate']:.3f}")
    st = out["store"]
    saved = (1.0 - st["delta_sync_bytes"]
             / max(1, st["full_resync_per_mutation_bytes"]))
    yield ("serve_online_delta_sync", 0.0,
           f"delta_mb={st['delta_sync_bytes']/1e6:.2f};"
           f"full_equiv_mb={st['full_resync_per_mutation_bytes']/1e6:.2f};"
           f"saved={saved*100:.0f}%")

"""Paper Table 4 — single memoized self-attention breakdown: embedding,
search, fetch (the mmap analogue), and remaining compute, vs the plain
attention path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import built_engine, timeit_ms
from repro.core.engine import MemoStats


def run():
    rows = []
    eng, corpus = built_engine()
    toks = jnp.asarray(corpus.sample(32)[0])
    st = MemoStats()
    eng.infer({"tokens": toks}, stats=st)           # warm
    st = MemoStats()
    logits, st = eng.infer({"tokens": toks}, stats=st)
    n = len(eng.layers)
    per = 1e3 / n
    rows.append(("table4/embed_ms_per_layer", st.t_embed * per,
                 f"total_s={st.t_embed:.3f}"))
    rows.append(("table4/search_ms_per_layer", st.t_search * per,
                 f"total_s={st.t_search:.3f}"))
    rows.append(("table4/fetch_ms_per_layer", st.t_fetch * per,
                 f"total_s={st.t_fetch:.3f}"))
    rows.append(("table4/layer_compute_ms", st.t_attn * per,
                 f"total_s={st.t_attn:.3f}"))
    # plain attention reference (what memoization replaces)
    from repro.models import backbone as bb
    li, kind, lp = next(bb.iter_layers(eng.params, eng.cfg))
    h = bb.embed_tokens(eng.params, toks, eng.cfg)
    x = bb.norm_apply(lp["norm1"], h, eng.cfg.norm)
    pos = jnp.broadcast_to(jnp.arange(toks.shape[1], dtype=jnp.int32),
                           toks.shape)
    t_attn = timeit_ms(lambda: eng._attn_only(lp, x, kind, pos))
    t_memo = timeit_ms(lambda: eng._memo_only(
        lp, x, kind, jnp.asarray(eng.db.get([0] * toks.shape[0],
                                            count_reuse=False),
                                 jnp.float32)))
    rows.append(("table4/attn_full_ms", t_attn * 1e3, "QKt+softmax+AV"))
    rows.append(("table4/attn_memo_only_ms", t_memo * 1e3,
                 f"AV_only;saving={(1 - t_memo / t_attn) * 100:.0f}%"))
    return rows

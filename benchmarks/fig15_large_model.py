"""Paper Fig. 15 / §6.9 — memoization potential in a larger decoder LLM:
per-layer top-1 similarity at layer 0 vs a mid layer (the paper reports
layer 0 ≫ layer 15 on LLaMA-7B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.similarity import pairwise_similarity
from repro.data import TemplateCorpus
from repro.models import build_model


def run():
    rows = []
    # deepseek-7b family reduced, deeper than the bench encoder
    cfg = get_reduced("deepseek_7b").replace(n_layers=8)
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=64, seed=4)

    def apms(toks):
        _, caps, _ = model.forward(params, {"tokens": jnp.asarray(toks)},
                                   capture=True)
        return {li: jnp.asarray(c["apm"]) for li, c in caps.items()}

    db = apms(corpus.sample(64)[0])
    q = apms(corpus.sample(16)[0])
    for li in (0, len(db) // 2, len(db) - 1):
        best = np.asarray(jnp.max(pairwise_similarity(q[li], db[li]), 1))
        rows.append((f"fig15/deepseek_layer{li}", 0.0,
                     f"mean_top1_sim={best.mean():.3f};"
                     f"frac_ge_0.5={float((best >= 0.5).mean()):.2f}"))
    return rows

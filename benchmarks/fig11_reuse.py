"""Paper Fig. 11 — APM reuse histogram: no hot set; nearly all records are
reused only a handful of times (why the DB must be big)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import built_engine


def run():
    rows = []
    eng, corpus = built_engine()
    eng.db.reuse_counts[:] = 0
    for _ in range(4):
        toks = jnp.asarray(corpus.sample(32)[0])
        eng.infer({"tokens": toks}, threshold=0.5)
    hist = eng.db.reuse_histogram()
    used = eng.db.reuse_counts[: len(eng.db)]
    rows.append(("fig11/reuse_max", 0.0, f"max_reuse={int(used.max())}"))
    rows.append(("fig11/reuse_hist", 0.0,
                 ";".join(f"x{i}={int(c)}" for i, c in enumerate(hist))))
    frac_cold = float((used == 0).mean())
    rows.append(("fig11/frac_never_reused", 0.0, f"{frac_cold:.2f}"))
    return rows

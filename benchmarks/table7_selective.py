"""Paper Table 7 — selective memoization: apply memo only at layers with
positive predicted benefit (Eq. 3); report latency + memo-rate deltas."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import built_engine


def _lat(eng, toks, **kw):
    eng.infer({"tokens": toks}, **kw)
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        logits, st = eng.infer({"tokens": toks}, **kw)
        jax.block_until_ready(logits)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), st


def run():
    rows = []
    eng, corpus = built_engine(mode="bucket")
    eng.mc.mode = "bucket"
    toks = jnp.asarray(corpus.sample(32)[0])
    pm = eng.profile({"tokens": toks})
    active = pm.active_layers()
    rows.append(("table7/active_layers", 0.0,
                 f"{len(active)}/{len(eng.layers)}:{active}"))
    t_all, st_all = _lat(eng, toks, threshold=eng.levels["moderate"])
    t_sel, st_sel = _lat(eng, toks, threshold=eng.levels["moderate"],
                         active_layers=active)
    rows.append(("table7/all_layers", t_all * 1e6,
                 f"memo_rate={st_all.memo_rate:.2f}"))
    rows.append(("table7/selective", t_sel * 1e6,
                 f"memo_rate={st_sel.memo_rate:.2f};"
                 f"time_delta={(1 - t_sel / t_all) * 100:+.1f}%"))
    eng.mc.mode = "select"
    return rows

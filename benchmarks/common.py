"""Shared benchmark scaffolding: a trained reduced encoder + memo engine,
cached across benchmark modules (building once keeps `-m benchmarks.run`
tractable on 1 CPU core)."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.memo import MemoSession, MemoSpec
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

SEQ = 64
VOCAB = 512


def timeit_ms(fn, *args, reps=3):
    fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e3)


@functools.lru_cache(maxsize=4)
def trained_encoder(arch: str = "bert_base", n_layers: int = 4,
                    train_steps: int = 50, slot_fraction: float = 0.25,
                    seq_len: int = SEQ):
    """Returns (model, params, corpus): a classifier trained on the template
    corpus — the reduced analogue of the paper's BERT/SST-2 setup."""
    cfg = get_reduced(arch).replace(n_classes=4, n_layers=n_layers)
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=seq_len, n_templates=8,
                            slot_fraction=slot_fraction, seed=0)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(model.classify_loss)(p, b)
        p, o = adamw_update(p, g, o, lr=3e-4)
        return loss, p, o

    for b in corpus.batches(train_steps, 32):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        loss, params, opt = step(params, opt, b)
    return model, params, corpus


@functools.lru_cache(maxsize=4)
def built_session(threshold: float = 0.8, mode: str = "select",
                  calib_batches: int = 6, arch: str = "bert_base",
                  seq: int = SEQ, n_layers: int = 4):
    """A calibrated MemoSession over the trained reduced encoder — all
    benchmark engines construct through the ``repro.memo`` facade."""
    model, params, corpus = trained_encoder(arch, n_layers=n_layers,
                                            seq_len=seq)
    spec = MemoSpec.flat(threshold=threshold, mode=mode, embed_steps=150)
    batches = [{"tokens": jnp.asarray(corpus.sample(32)[0])}
               for _ in range(calib_batches)]
    sess = MemoSession.build(model, params, spec, batches=batches,
                             key=jax.random.PRNGKey(1))
    # per-model threshold levels (paper Table 2 / §5.4 autotuner)
    sess.levels = sess.suggest_levels(
        [{"tokens": jnp.asarray(corpus.sample(16)[0])}])
    return sess, corpus


def built_engine(threshold: float = 0.8, mode: str = "select",
                 calib_batches: int = 6, arch: str = "bert_base",
                 seq: int = SEQ, n_layers: int = 4):
    """Back-compat view of ``built_session`` (same lru-shared build):
    returns the underlying engine with ``.levels`` attached."""
    sess, corpus = built_session(threshold, mode, calib_batches, arch,
                                 seq, n_layers)
    eng = sess.engine
    eng.levels = sess.levels
    return eng, corpus


def accuracy(model, params, toks, labels):
    logits = model.classify(params, {"tokens": jnp.asarray(toks)})
    return float((np.argmax(np.asarray(logits), -1) == labels).mean())


def accuracy_memo(eng, toks, labels, threshold=None, active=None):
    logits, st = eng.infer({"tokens": jnp.asarray(toks)},
                           threshold=threshold, active_layers=active)
    return (float((np.argmax(np.asarray(logits), -1) == labels).mean()), st)

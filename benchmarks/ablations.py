"""Beyond-paper ablations.

1. Similarity-knob sweep: the template corpus exposes the structural
   similarity the paper's natural corpora fix implicitly (slot_fraction =
   fraction of varying positions). Sweep it to map corpus similarity →
   memo rate → accuracy, at a fixed calibrated threshold policy. The
   paper could not run this experiment (no knob on SST-2).
2. Index ablation: exact vs IVF search inside the engine (the paper's
   Faiss/HNSW-vs-exhaustive Figure 7 analogue) — recall@1 against the
   exact oracle plus end-to-end memo agreement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.index import ExactIndex, recall_at_1
from repro.memo import MemoSession, MemoSpec
from repro.data import TemplateCorpus
from repro.models import build_model
from repro.optim import adamw_init, adamw_update


def _train(cfg, corpus, steps=40):
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(model.classify_loss)(p, b)
        p, o = adamw_update(p, g, o, lr=3e-4)
        return loss, p, o
    for b in corpus.batches(steps, 32):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        _, params, opt = step(params, opt, b)
    return model, params


def run():
    rows = []
    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=3)

    # -- 1. similarity knob ------------------------------------------------
    for frac in (0.1, 0.3, 0.6):
        corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=64, n_templates=8,
                                slot_fraction=frac, seed=0)
        model, params = _train(cfg, corpus)
        eng = MemoSession.build(
            model, params, MemoSpec.flat(embed_steps=80),
            batches=[{"tokens": jnp.asarray(corpus.sample(32)[0])}
                     for _ in range(3)],
            key=jax.random.PRNGKey(1)).engine
        thr = eng.suggest_levels(
            [{"tokens": jnp.asarray(corpus.sample(16)[0])}])["moderate"]
        toks, labels = corpus.sample(64)
        logits, st = eng.infer({"tokens": jnp.asarray(toks)}, threshold=thr)
        acc = float((np.argmax(np.asarray(logits), -1) == labels).mean())
        logits0, _ = eng.infer({"tokens": jnp.asarray(toks)}, use_memo=False)
        acc0 = float((np.argmax(np.asarray(logits0), -1) == labels).mean())
        rows.append((f"knob/slot{frac}", 0.0,
                     f"memo_rate={st.memo_rate:.2f};acc={acc:.3f};"
                     f"acc_delta={acc - acc0:+.3f}"))

    # -- 2. index ablation ---------------------------------------------------
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=64, seed=0)
    model, params = _train(cfg, corpus)
    for kind in ("exact", "ivf"):
        eng = MemoSession.build(
            model, params,
            MemoSpec.flat(embed_steps=80, index_kind=kind),
            batches=[{"tokens": jnp.asarray(corpus.sample(32)[0])}
                     for _ in range(4)],
            key=jax.random.PRNGKey(1)).engine
        q = np.asarray(eng._embed(jnp.asarray(
            jax.random.normal(jax.random.PRNGKey(7), (16, 64, cfg.d_model)))))
        if kind == "ivf":
            oracle = ExactIndex(eng.mc.embed_dim)
            oracle.add(eng.index._embs)
            rec = recall_at_1(eng.index, oracle, q)
        else:
            rec = 1.0
        thr = eng.suggest_levels(
            [{"tokens": jnp.asarray(corpus.sample(16)[0])}])["moderate"]
        _, st = eng.infer({"tokens": jnp.asarray(corpus.sample(32)[0])},
                          threshold=thr)
        rows.append((f"index/{kind}", 0.0,
                     f"recall@1={rec:.2f};memo_rate={st.memo_rate:.2f}"))
    return rows

"""Prefill-memoization benchmark (ISSUE 10 / DESIGN.md §2.13).

Per KV codec (f16 / int8 / lowrank), builds a prefill-enabled session
over the reduced causal GPT-2 and serves a half-replay / half-novel
prompt stream through BOTH prefill legs — ``prefill_exact`` and the
memoized ``prefill`` — so the latency A/B is read at the workload's own
hit rate. A pure-replay batch (self-hits: the decode cache comes from
the stored KV entry, so any gap is codec quantization, not input drift)
then drives the parity + throughput leg: teacher-forced greedy decode
from both cache sets, recording max|Δlogits| at the prefill boundary
and across decode steps, greedy-token agreement, and end-to-end
prefill+decode tokens/s.

Emitted into BENCH_serve.json as the ``serve_prefill`` section. Two
hard gates ride ``--check-regress`` (benchmarks/run.py ABS_BOUNDS):

- ``prefill/decode_parity_failures == 0`` — every codec's prefill and
  decode |Δlogits| stays inside the same per-codec bounds the kernel
  parity gates use (tests/test_prefill.py asserts the identical
  numbers);
- ``prefill/hit_gap <= 0.05`` — substituting memoized prefill may cost
  at most 5% of greedy decode tokens vs the all-exact baseline.

Standalone (the CI ``prefill-smoke`` job):
    PYTHONPATH=src python -m benchmarks.serve_prefill --quick
"""
from __future__ import annotations

import argparse
import functools
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.memo import MemoSession, MemoSpec, MemoStats
from repro.models import build_model

SEQ = 16
BATCH = 8
CALIB_BATCHES = 4
CODECS = ("f16", "int8", "lowrank")
# APM lowrank rank: softmax rows decay fast; rank >= 3*SEQ/4 keeps the
# truncation error inside the prefill bound
APM_RANK = (3 * SEQ) // 4
# KV lowrank rank: K/V spectra decay much slower than softmax rows (see
# core/prefill.py), so the parity leg runs the factorization at full
# rank — the gate covers the SVD-encode/quantized-factor machinery
# (int8 factor error only); truncation below full rank is a quality
# knob, not a parity property
KV_RANK = SEQ

# per-codec |Δlogits| ceilings — the kernel-parity bounds
# (tests/test_prefill.py asserts the same numbers): the prefill boundary
# carries the APM codec's error, decode carries the KV codec's.
BOUNDS = {
    "f16":     {"prefill": 5e-3, "decode": 5e-3},
    "int8":    {"prefill": 2e-2, "decode": 2e-2},
    "lowrank": {"prefill": 1e-1, "decode": 5e-2},
}


def _build(codec: str):
    """Prefill-enabled session over the reduced causal GPT-2; the KV
    codec rides the APM codec ("auto": f16 base -> f16 KV, else int8)
    except lowrank, which is requested explicitly with its rank."""
    cfg = get_reduced("gpt2_small")
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, n_templates=8,
                            slot_fraction=0.25, seed=3)
    lowrank = codec == "lowrank"
    spec = MemoSpec.flat(
        threshold=0.6, mode="bucket", embed_steps=60,
        apm_codec=codec, apm_rank=APM_RANK if lowrank else None,
        prefill_enabled=True,
        prefill_kv_codec="lowrank" if lowrank else "auto",
        prefill_kv_rank=KV_RANK if lowrank else None)
    rng = np.random.default_rng(17)
    calib = [jnp.asarray(corpus.sample(BATCH, rng)[0])
             for _ in range(CALIB_BATCHES)]
    sess = MemoSession.build(model, params, spec,
                             batches=[{"tokens": t} for t in calib],
                             key=jax.random.PRNGKey(1))
    return sess.engine, model, corpus, calib


def _decode_loop(eng, model, logits, caches, steps, force=None):
    """Greedy decode continuation; ``force`` teacher-forces the token
    stream (parity legs) instead of self-feeding (timing legs). Returns
    (per-step greedy picks, final logits trace)."""
    picks, trace = [], []
    for step in range(steps):
        tok = jnp.argmax(logits, -1).reshape(-1)
        picks.append(np.asarray(tok))
        feed = force[step] if force is not None else tok
        logits, caches = model.decode_step(
            eng.params, jnp.asarray(feed)[:, None], caches,
            jnp.int32(SEQ + step))
        trace.append(logits)
    jax.block_until_ready(logits)
    return picks, trace


def _codec_leg(codec: str, n_batches: int, decode_steps: int):
    eng, model, corpus, calib = _build(codec)
    rng = np.random.default_rng(29)
    st = MemoStats()

    # latency A/B on half-replay / half-novel traffic: both legs see the
    # SAME batches, so the comparison is at the workload's own hit rate
    lat_e, lat_m = [], []
    for i in range(n_batches):
        toks = (calib[(i // 2) % len(calib)] if i % 2 == 0
                else jnp.asarray(corpus.sample(BATCH, rng)[0]))
        batch = {"tokens": toks}
        t0 = time.perf_counter()
        le, _ = eng.prefill_exact(batch)
        jax.block_until_ready(le)
        lat_e.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        lm, _, st = eng.prefill(batch, stats=st)
        jax.block_until_ready(lm)
        lat_m.append(time.perf_counter() - t0)
    hit_rate = st.memo_rate
    exact_ms = float(np.median(lat_e[1:] or lat_e) * 1e3)
    memo_ms = float(np.median(lat_m[1:] or lat_m) * 1e3)

    # parity on a pure-replay batch (self-hits): teacher-forced on the
    # exact leg's greedy tokens so one near-tie flip can't snowball the
    # logits gap — this loop also compiles decode_step for the timed leg
    replay = {"tokens": calib[0]}
    h0, a0 = st.n_hits, st.n_layer_attempts
    le, ce = eng.prefill_exact(replay)
    lm, cm, st = eng.prefill(replay, stats=st)
    replay_hits = st.n_hits - h0
    replay_attempts = st.n_layer_attempts - a0
    pf_dmax = float(jnp.max(jnp.abs(lm - le)))
    picks_e, trace_e = _decode_loop(eng, model, le, ce, decode_steps)
    picks_m, trace_m = _decode_loop(eng, model, lm, cm, decode_steps,
                                    force=picks_e)
    dec_dmax = max(float(jnp.max(jnp.abs(m - e)))
                   for m, e in zip(trace_m, trace_e))
    agree = sum(int((m == e).sum())
                for m, e in zip(picks_m, picks_e))
    total = decode_steps * BATCH

    # end-to-end prefill+decode throughput per leg (greedy self-fed;
    # everything is compiled by now, so the walls are steady-state)
    def e2e(prefill_fn):
        t0 = time.perf_counter()
        out = prefill_fn(replay)
        _decode_loop(eng, model, out[0], out[1], decode_steps)
        return time.perf_counter() - t0

    wall_e = e2e(eng.prefill_exact)
    wall_m = e2e(lambda b: eng.prefill(b)[:2])
    tok = BATCH * decode_steps

    bounds = BOUNDS[codec]
    return {
        "exact_ms": exact_ms, "memo_ms": memo_ms,
        "memo_over_exact": memo_ms / max(exact_ms, 1e-9),
        "hit_rate": float(hit_rate),
        "replay_hit_rate": replay_hits / max(1, replay_attempts),
        "prefill_max_abs_diff": pf_dmax,
        "decode_max_abs_diff": dec_dmax,
        "bound_prefill": bounds["prefill"],
        "bound_decode": bounds["decode"],
        "parity_ok": bool(pf_dmax <= bounds["prefill"]
                          and dec_dmax <= bounds["decode"]),
        "greedy_agreement": agree / total,
        "e2e_tok_s_exact": tok / max(wall_e, 1e-9),
        "e2e_tok_s_memo": tok / max(wall_m, 1e-9),
    }


@functools.lru_cache(maxsize=2)
def collect(quick: bool = False):
    codecs = ("int8",) if quick else CODECS
    n_batches = 4 if quick else 8
    decode_steps = 4 if quick else 8
    out = {"config": {"arch": "gpt2_small (reduced)", "seq": SEQ,
                      "batch": BATCH, "n_batches": n_batches,
                      "decode_steps": decode_steps,
                      "apm_rank": APM_RANK, "kv_rank": KV_RANK,
                      "quick": bool(quick),
                      "backend": jax.default_backend()},
           "codecs": {}}
    for codec in codecs:
        t0 = time.time()
        leg = _codec_leg(codec, n_batches, decode_steps)
        leg["wall_s"] = round(time.time() - t0, 2)
        out["codecs"][codec] = leg
    legs = out["codecs"].values()
    out["hit_gap"] = max(1.0 - leg["greedy_agreement"] for leg in legs)
    out["decode_parity_failures"] = sum(
        0 if leg["parity_ok"] else 1 for leg in legs)
    return out


def run():
    out = collect()
    for codec, leg in out["codecs"].items():
        yield (f"serve_prefill_{codec}", leg["memo_ms"] * 1e3,
               f"exact={leg['exact_ms']:.1f}ms;"
               f"memo={leg['memo_ms']:.1f}ms;"
               f"hit={leg['hit_rate']:.3f};"
               f"pf_diff={leg['prefill_max_abs_diff']:.2e};"
               f"dec_diff={leg['decode_max_abs_diff']:.2e};"
               f"agree={leg['greedy_agreement']:.3f};"
               f"tok_s={leg['e2e_tok_s_memo']:.0f};"
               f"parity={leg['parity_ok']}")
    yield ("serve_prefill_gate", 0.0,
           f"hit_gap={out['hit_gap']:.3f};"
           f"parity_failures={out['decode_parity_failures']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="int8 only, 4 batches, 4 decode steps (the CI "
                         "prefill-smoke size)")
    args = ap.parse_args()
    out = collect(quick=args.quick)
    failures = []
    for codec, leg in out["codecs"].items():
        print(f"{codec:>8}: exact={leg['exact_ms']:.1f}ms "
              f"memo={leg['memo_ms']:.1f}ms "
              f"hit={leg['hit_rate']:.3f} "
              f"replay_hit={leg['replay_hit_rate']:.3f} "
              f"pf_diff={leg['prefill_max_abs_diff']:.2e}"
              f"<={leg['bound_prefill']:.0e} "
              f"dec_diff={leg['decode_max_abs_diff']:.2e}"
              f"<={leg['bound_decode']:.0e} "
              f"agree={leg['greedy_agreement']:.3f} "
              f"tok/s={leg['e2e_tok_s_memo']:.0f}"
              + ("" if leg["parity_ok"] else "   <-- FAIL"))
        if not leg["parity_ok"]:
            failures.append(
                f"{codec}: |Δlogits| prefill "
                f"{leg['prefill_max_abs_diff']:.2e} "
                f"(bound {leg['bound_prefill']:.0e}) / decode "
                f"{leg['decode_max_abs_diff']:.2e} "
                f"(bound {leg['bound_decode']:.0e})")
        if leg["replay_hit_rate"] < 0.5:
            failures.append(f"{codec}: replay hit rate "
                            f"{leg['replay_hit_rate']:.3f} < 0.5 — the "
                            f"parity leg barely exercised the memo path")
    if out["hit_gap"] > 0.05:
        failures.append(f"hit_gap {out['hit_gap']:.3f} > 0.05")
    print(f"{'gate':>8}: hit_gap={out['hit_gap']:.3f} "
          f"parity_failures={out['decode_parity_failures']}")
    if failures:
        print("\nPREFILL FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("\nprefill memoization: decode parity within per-codec bounds, "
          "hit gap within tolerance")


if __name__ == "__main__":
    main()

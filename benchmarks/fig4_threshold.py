"""Paper Fig. 4 + Tables 2/5 — memoization threshold sweep: memo rate vs
inference accuracy at conservative/moderate/aggressive levels."""
from __future__ import annotations

from benchmarks.common import accuracy, accuracy_memo, built_engine

def run():
    rows = []
    eng, corpus = built_engine()
    toks, labels = corpus.sample(96)
    base = accuracy(eng.model, eng.params, toks, labels)
    rows.append(("fig4/baseline", 0.0, f"acc={base:.3f};memo_rate=0.00"))
    thresholds = dict(eng.levels)          # paper Table 2, autotuned
    thresholds["all"] = -1.0
    for name, thr in thresholds.items():
        acc, st = accuracy_memo(eng, toks, labels, threshold=thr)
        rows.append((f"fig4/{name}", 0.0,
                     f"acc={acc:.3f};memo_rate={st.memo_rate:.2f};"
                     f"acc_delta={acc - base:+.3f}"))
    return rows

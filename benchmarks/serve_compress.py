"""Compressed memo tiers: codec × index sweep (ISSUE 3 / DESIGN.md §2.6).

Two sweeps, both CPU-interpret friendly:

* **Search microbenchmark** — flat exhaustive ``DeviceIndex`` vs
  ``ClusteredDeviceIndex`` over synthetic DBs at increasing N, with
  serving-shaped query batches (a handful of request templates per
  batch — the regime batch-shared probing is designed for). Records
  ms/search, speedup, recall@1 vs the exact oracle, and resident index
  bytes (int8+scales vs f32). The ISSUE-3 acceptance row is
  ``search_N16384``: clustered ≥ 3× faster at recall ≥ 0.95.

* **Engine sweep** — one trained reduced encoder served end-to-end
  under each APM codec: ms/batch, hit rate, codec-true bytes/entry (and
  the ratio vs the f16 layout), device-tier HBM bytes, delta-sync bytes
  for a fixed admission (the sync-bandwidth receipt), max|Δlogits| and
  prediction agreement vs the UNCOMPRESSED (f16) reference engine — the
  measured accuracy/bytes trade-off table quoted in DESIGN.md §2.6.

Emitted into BENCH_serve.json by ``python -m benchmarks.run --json`` as
the ``serve_compress`` section.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit_ms, trained_encoder
from repro.memo import MemoSession, MemoSpec, MemoStats
from repro.core.index import (
    ClusteredDeviceIndex, DeviceIndex, ExactIndex, recall_at_1)
from repro.data import TemplateCorpus

BATCH = 16
SEQ = 32
CODECS = ("f16", "int8", "lowrank")
SEARCH_NS = (4096, 16384)
SEARCH_DIM = 128
SEARCH_B = 32


def _search_micro():
    rng = np.random.default_rng(0)
    out = {}
    for n in SEARCH_NS:
        centers = rng.normal(size=(64, SEARCH_DIM)) * 5
        db = (centers[rng.integers(0, 64, n)]
              + rng.normal(size=(n, SEARCH_DIM))).astype(np.float32)
        # serving-shaped batch: SEARCH_B requests over 4 templates
        rows = db[rng.integers(0, n, 4)]
        q = (rows[np.repeat(np.arange(4), SEARCH_B // 4)]
             + 0.1 * rng.normal(size=(SEARCH_B, SEARCH_DIM))
             ).astype(np.float32)
        qd = jnp.asarray(q)
        flat = DeviceIndex(SEARCH_DIM)
        flat.add(db)
        cl = ClusteredDeviceIndex(SEARCH_DIM)
        cl.add(db)
        f_flat = jax.jit(lambda q, a: flat.search_device(q, args=a)[1])
        f_cl = jax.jit(lambda q, a: cl.search_device(q, args=a)[1])
        fargs, cargs = flat.search_args, cl.search_args
        flat_ms = timeit_ms(lambda: f_flat(qd, fargs), reps=10)
        cl_ms = timeit_ms(lambda: f_cl(qd, cargs), reps=10)
        exact = ExactIndex(SEARCH_DIM)
        exact.add(db)
        flat_bytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                         for a in fargs)
        cl_bytes = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                       for a in cargs)
        out[f"N{n}"] = {
            "n": n, "dim": SEARCH_DIM, "batch": SEARCH_B,
            "flat_ms": flat_ms, "clustered_ms": cl_ms,
            "speedup": flat_ms / cl_ms,
            "recall_at_1": recall_at_1(cl, exact, q),
            "flat_index_bytes": flat_bytes,
            "clustered_index_bytes": cl_bytes,
            "index_bytes_ratio": cl_bytes / flat_bytes,
            "n_clusters": int(cl._pvecs.shape[0]),
            "m_pad": int(cl._pvecs.shape[1]),
        }
    return out


def _engine_sweep():
    model, params, _ = trained_encoder("bert_base", n_layers=2, seq_len=SEQ)
    corpus = TemplateCorpus(vocab=model.cfg.vocab, seq_len=SEQ,
                            n_templates=6, slot_fraction=0.2, seed=0)
    calib = [{"tokens": jnp.asarray(corpus.sample(BATCH)[0])}
             for _ in range(4)]
    toks = jnp.asarray(corpus.sample(BATCH)[0])
    rng = np.random.default_rng(1)

    engines = {}
    for codec in CODECS:
        sess = MemoSession.build(
            model, params,
            MemoSpec.flat(threshold=0.8, mode="bucket", embed_steps=150,
                          apm_codec=codec, device_slack=4.0),
            batches=calib, key=jax.random.PRNGKey(1))
        eng = sess.engine
        if codec == CODECS[0]:
            thr = eng.suggest_levels(
                [{"tokens": jnp.asarray(corpus.sample(BATCH)[0])}]
            )["moderate"]
        eng.mc.threshold = thr
        engines[codec] = eng

    # the uncompressed reference: f16 store, select semantics
    ref_eng = engines["f16"]
    ref_eng.mc.mode = "select"
    ref_logits, _ = ref_eng.infer({"tokens": toks})
    ref_logits = np.asarray(ref_logits)
    ref_eng.mc.mode = "bucket"

    out = {}
    for codec, eng in engines.items():
        st = MemoStats()
        ts = []
        for _ in range(6):
            t0 = time.perf_counter()
            logits, st = eng.infer({"tokens": toks}, stats=st)
            jax.block_until_ready(logits)
            ts.append(time.perf_counter() - t0)
        logits = np.asarray(logits)
        store = eng.store
        # delta-sync receipt: admit a fixed batch of entries, measure
        # exactly the bytes the incremental sync ships
        n_new = 8
        apms = np.asarray(
            jax.nn.softmax(jnp.asarray(rng.normal(
                size=(n_new,) + store.apm_shape)), -1), np.float16)
        embs = rng.normal(size=(n_new, store.embed_dim)).astype(np.float32)
        embs[:, 0] += 1e4                      # far from live traffic
        b0 = store.stats.bytes_delta
        store.admit(apms, embs)
        r = store.sync()
        delta_bytes = store.stats.bytes_delta - b0
        assert r["kind"] == "delta", r
        out[codec] = {
            "ms_per_batch": float(np.median(ts[2:]) * 1e3),
            "memo_rate": st.memo_rate,
            "entry_nbytes": store.entry_nbytes,
            "entry_bytes_ratio": store.entry_nbytes
            / store.logical_entry_nbytes,
            "apm_entry_nbytes": store.db.entry_nbytes,
            "apm_bytes_ratio": store.db.entry_nbytes
            / store.db.logical_entry_nbytes,
            "device_hbm_bytes": store.device_db.nbytes,
            "delta_sync_bytes_8_entries": delta_bytes,
            "max_abs_dlogits_vs_f16_select": float(
                np.max(np.abs(logits - ref_logits))),
            "prediction_agreement_vs_f16": float(
                (logits.argmax(-1) == ref_logits.argmax(-1)).mean()),
        }
    f16 = out["f16"]
    for codec in CODECS:
        out[codec]["hbm_ratio_vs_f16"] = (out[codec]["device_hbm_bytes"]
                                          / f16["device_hbm_bytes"])
        out[codec]["delta_ratio_vs_f16"] = (
            out[codec]["delta_sync_bytes_8_entries"]
            / f16["delta_sync_bytes_8_entries"])
    return out


@functools.lru_cache(maxsize=1)
def collect():
    return {
        "config": {"backend": jax.default_backend(),
                   "search": {"dim": SEARCH_DIM, "batch": SEARCH_B,
                              "ns": list(SEARCH_NS)},
                   "engine": {"arch": "bert_base (reduced, 2 layers)",
                              "batch": BATCH, "seq": SEQ}},
        "search_micro": _search_micro(),
        "codec_sweep": _engine_sweep(),
    }


def run():
    out = collect()
    for key, row in out["search_micro"].items():
        yield (f"compress_search_{key}_flat", row["flat_ms"] * 1e3,
               f"N={row['n']}")
        yield (f"compress_search_{key}_clustered", row["clustered_ms"] * 1e3,
               f"speedup={row['speedup']:.2f}x;"
               f"recall={row['recall_at_1']:.3f};"
               f"bytes_ratio={row['index_bytes_ratio']:.2f}")
    for codec, row in out["codec_sweep"].items():
        yield (f"compress_serve_{codec}", row["ms_per_batch"] * 1e3,
               f"rate={row['memo_rate']:.2f};"
               f"apm_bytes={row['apm_bytes_ratio']:.2f}x;"
               f"hbm={row['hbm_ratio_vs_f16']:.2f}x;"
               f"delta={row['delta_ratio_vs_f16']:.2f}x;"
               f"dlogits={row['max_abs_dlogits_vs_f16_select']:.4f};"
               f"agree={row['prediction_agreement_vs_f16']:.3f}")

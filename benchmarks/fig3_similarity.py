"""Paper Fig. 3 / Fig. 12 — distribution of top-1 APM similarity scores per
layer, and its growth with sequence length."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_encoder
from repro.core.similarity import pairwise_similarity


def _layer_apms(model, params, toks):
    _, caps = model.classify(params, {"tokens": jnp.asarray(toks)},
                             capture=True)
    return {li: jnp.asarray(c["apm"]) for li, c in caps.items()}


def run():
    rows = []
    model, params, corpus = trained_encoder()
    db_toks, _ = corpus.sample(96)
    q_toks, _ = corpus.sample(32)
    db = _layer_apms(model, params, db_toks)
    q = _layer_apms(model, params, q_toks)
    for li in sorted(db):
        sims = pairwise_similarity(q[li], db[li])      # (Q, N)
        best = np.asarray(jnp.max(sims, axis=1))
        high = float((best >= 0.7).mean())
        rows.append((f"fig3/layer{li}", 0.0,
                     f"mean_top1_sim={best.mean():.3f};frac_ge_0.7={high:.2f}"))

    # Fig. 12: longer sequences -> more similarity
    from repro.data import TemplateCorpus
    for seq in (16, 32, 64):
        c2 = TemplateCorpus(vocab=model.cfg.vocab, seq_len=seq, seed=2)
        db2 = _layer_apms(model, params, c2.sample(48)[0])
        q2 = _layer_apms(model, params, c2.sample(16)[0])
        li = sorted(db2)[0]
        best = np.asarray(jnp.max(pairwise_similarity(q2[li], db2[li]), 1))
        rows.append((f"fig12/seq{seq}", 0.0,
                     f"mean_top1_sim={best.mean():.3f}"))
    return rows

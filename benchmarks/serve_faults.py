"""Chaos harness: fault-tolerant serving (ISSUE 6 / DESIGN.md §2.9).

Drives the PR 4 open-loop trace through a supervised ``MemoServer``
once per chaos class (``repro.core.faults.CHAOS_PRESETS``): a warm
phase, a fault window with the class's fault points armed, then
disarm + ``recover()`` and a recovery phase. Per class it records

* ``availability``   — completions / submissions over all three phases.
  The acceptance bar is **1.0 under every class**: a memo fault may
  cost hit rate, never a request (gated via ``faults/<cls>/
  unavailability`` ≤ 0.0 in benchmarks/run.py ABS_BOUNDS).
* ``p99_ms``         — tail latency across the whole trace, fault
  window included.
* ``hit_rate_after_recovery`` and ``hit_recovery_gap`` — the recovery
  phase's hit rate vs the same phase of a fault-free baseline run
  (gated ≤ 0.05: recovery must re-arm the memo path, not limp along
  serving exact attention forever).
* the health trail + supervision counters (sheds, retries,
  quarantines, exact-attention batches).

Sessions are rebuilt per class via ``save`` + ``load`` of one
calibrated store, so every class starts from the identical state (and
the persistence path itself gets exercised once per class). Disk
classes (DESIGN.md §2.11) serve with a capacity tier attached and
additionally assert the tier DIRECTORY reopens clean afterwards. A
``capacity`` section serves a store ~10x the host byte budget and
gates the steady-state hit gap vs all-in-RAM (≤ 0.05); a
``persistence`` section records that truncated / bit-flipped save
files fail with a clean ``MemoStoreError`` and that a torn re-save
never clobbers the existing good file.

Emitted into BENCH_serve.json as the ``serve_faults`` section.
Standalone (the CI chaos-smoke job)::

    PYTHONPATH=src python -m benchmarks.serve_faults --quick
    PYTHONPATH=src python -m benchmarks.serve_faults --quick \\
        --classes disk_write_io,journal_torn
"""
from __future__ import annotations

import argparse
import functools
import os
import shutil
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_encoder
from repro.core.faults import CHAOS_PRESETS, FaultInjector, MemoStoreError
from repro.core.runtime import Health
from repro.launch.server import probe_rate
from repro.memo import MemoSession, MemoSpec

SEQ = 32
BATCH = 8
BUCKETS = (16, 32)

# per-class supervision knobs: each class must traverse its part of the
# health ladder *within the fault window*, so retries/backoff are sized
# to the trace, not to production defaults
SERVER_KW = {
    "corrupt_row":    {},
    "sync_fail":      {"maint_retries": 2, "maint_backoff_s": 0.005},
    "evict_bogus":    {},
    "maint_crash":    {"maint_retries": 1, "maint_backoff_s": 0.005,
                       "disable_after": 2},
    "maint_stall":    {"maint_retries": 0, "watchdog_s": 0.02},
    "queue_overflow": {"maint_put_timeout": 0.01},
    # disk classes (DESIGN.md §2.11): checkpoint every apply so the
    # crash point actually fires inside the fault window
    "disk_write_io":    {},
    "journal_torn":     {},
    "checkpoint_crash": {"checkpoint_every": 1},
    "mmap_bitflip":     {},
}

# classes that need a capacity tier attached to the session (the fault
# points live inside CapacityTier) — they additionally assert that the
# tier directory REOPENS clean after the trace (crash consistency)
DISK_CLASSES = ("disk_write_io", "journal_torn", "checkpoint_crash",
                "mmap_bitflip")


def _build_and_save(path: str):
    model, params, corpus = trained_encoder("bert_base", n_layers=2,
                                            seq_len=SEQ)
    spec = MemoSpec.flat(mode="bucket", embed_steps=120, admit=True,
                         budget_mb=256.0, device_slack=8.0, faults={})
    rng = np.random.default_rng(123)
    sess = MemoSession.build(
        model, params, spec,
        batches=[{"tokens": jnp.asarray(corpus.sample(BATCH, rng)[0])}
                 for _ in range(4)],
        key=jax.random.PRNGKey(1))
    sess.autotune([{"tokens": jnp.asarray(corpus.sample(BATCH, rng)[0])}],
                  level="aggressive")       # persists via spec.to_dict
    sess.save(path)
    # capacity probe on a THROWAWAY load (probing admits junk entries)
    rate = probe_rate(MemoSession.load(path, model, params),
                      buckets=BUCKETS, max_batch=BATCH, seq=SEQ)
    return model, params, corpus, rate


def _workload(corpus, rate: float, n_requests: int, seed: int):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    wl = []
    for i in range(n_requests):
        bucket = int(rng.choice(BUCKETS))
        length = bucket - int(rng.choice([0, 2]))
        wl.append((float(arrivals[i]),
                   corpus.sample(1, rng)[0][0, :length]))
    return wl


def _hot_workload(corpus, rate: float, n_requests: int, seed: int,
                  n_hot: int):
    """A workload whose distinct-request set is capped at ``n_hot``
    (sequence AND length fixed per hot item, so repeats can hit). The
    capacity leg needs a working set that fits the host budget:
    steady-state hit rate then measures what demotion cost, not cache
    thrash from a working set no budget could hold."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    items = []
    for _ in range(max(1, n_hot)):
        bucket = int(rng.choice(BUCKETS))
        length = bucket - int(rng.choice([0, 2]))
        items.append(corpus.sample(1, rng)[0][0, :length])
    return [(float(arrivals[i]), items[int(rng.integers(0, len(items)))])
            for i in range(n_requests)]


def _phase_rate(stats, mark):
    """Hit rate over the window since ``mark`` (a (hits, attempts)
    tuple)."""
    d_att = stats.n_layer_attempts - mark[1]
    return (stats.n_hits - mark[0]) / max(1, d_att)


def _chaos_leg(cls, path, model, params, corpus, rate, n_requests):
    """One three-phase trace: warm → fault window → recover. ``cls`` is
    a CHAOS_PRESETS key or None for the fault-free baseline. Disk
    classes run with a capacity tier attached and finish by reopening
    the tier directory cold (``MemoSession.load(<dir>)``), asserting it
    recovers to a store that passes ``verify_integrity``."""
    disk = cls in DISK_CLASSES
    capdir = tempfile.mkdtemp(prefix="memo_chaos_tier_") if disk else None
    sess = MemoSession.load(
        path, model, params,
        overrides={"capacity_dir": capdir} if disk else None)
    inj = sess.engine.faults
    srv = sess.serve(buckets=BUCKETS, max_batch=BATCH, max_delay=4e-3,
                     async_maintenance=True,
                     **(SERVER_KW.get(cls) or {}))
    srv.warmup()
    lats, submitted, completed = [], 0, 0
    try:
        phases = [(None, 11), (CHAOS_PRESETS[cls] if cls else None, 13),
                  (None, 17)]
        for pi, (preset, seed) in enumerate(phases):
            if preset:
                for point, kw in preset.items():
                    inj.arm(point, **kw)
            if pi == 2:                        # recovery phase entry
                inj.disarm()
                try:                           # quiesce best-effort: a
                    srv.drain_maintenance(     # stalled worker finishes,
                        timeout=10,            # a dead one is recovered
                        raise_errors=False)    # below
                except Exception:  # noqa: BLE001 — timeout/dead worker
                    pass
                srv.recover()
                mark = (srv.stats.n_hits, srv.stats.n_layer_attempts)
            wl = _workload(corpus, rate, n_requests, seed)
            submitted += len(wl)
            comps = srv.run(wl)
            completed += len(comps)
            lats.extend(c.latency for c in comps)
        srv.drain_maintenance(timeout=30, raise_errors=False)
        recovered_rate = _phase_rate(srv.stats, mark)
        lat_ms = np.asarray(lats) * 1e3
        leg = {
            "availability": completed / max(1, submitted),
            "n_submitted": submitted,
            "n_completed": completed,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "hit_rate_after_recovery": float(recovered_rate),
            "hit_rate_total": float(srv.stats.memo_rate),
            "final_health": srv.health.value,
            "health_log": [(round(t, 4), h, why)
                           for t, h, why in srv.health_log],
            "n_health_transitions": srv.n_health_transitions,
            "n_maint_shed": srv.n_maint_shed,
            "n_maint_retries": srv.n_maint_retries,
            "n_exact_batches": srv.n_exact_batches,
            "n_quarantined": sess.store.stats.n_quarantined,
            "n_evict_rejected": sess.store.stats.n_evict_rejected,
            "live_entries": sess.store.live_count,
        }
        if disk:
            leg["n_disk_errors"] = sess.store.stats.n_disk_errors
            leg["n_disk_quarantined"] = \
                sess.store.stats.n_disk_quarantined
    finally:
        inj.disarm()
        srv.close()
    if disk:
        # crash consistency: the tier directory must reopen cold
        # (``close`` checkpointed best-effort; recovery replays the
        # rest) to a store that passes verify_integrity, no matter
        # what the class did to it
        try:
            re = MemoSession.load(capdir, model, params)
            leg["reopen_verify_clean"] = \
                not re.store.verify_integrity(quarantine=False)
            leg["reopen_live_entries"] = re.store.live_count
            leg["reopen_recovery"] = re.store.capacity.recovery
        except MemoStoreError as e:
            leg["reopen_verify_clean"] = False
            leg["reopen_error"] = str(e)
        shutil.rmtree(capdir, ignore_errors=True)
    return leg


def _capacity_leg(path, model, params, corpus, rate, n_requests):
    """The big-memory acceptance leg (DESIGN.md §2.11): serve a store
    ~10x the host byte budget from the capacity tier and compare
    steady-state hit rate against the identical all-in-RAM session.
    Each leg runs the SAME hot-set workload twice (distinct requests
    sized to fit the host budget, the cold mass stays on disk) — pass 1
    warms (promotions migrate hot rows disk → host → device), pass 2 is
    the steady state that gets scored — so the gap isolates what
    demotion truly cost."""

    def two_pass(sess, wl):
        srv = sess.serve(buckets=BUCKETS, max_batch=BATCH, max_delay=4e-3,
                         async_maintenance=True)
        srv.warmup()
        try:
            srv.run(list(wl))
            srv.drain_maintenance(timeout=30, raise_errors=False)
            mark = (srv.stats.n_hits, srv.stats.n_layer_attempts)
            srv.run(list(wl))
            srv.drain_maintenance(timeout=30, raise_errors=False)
            return _phase_rate(srv.stats, mark), srv
        finally:
            srv.close()

    ram = MemoSession.load(path, model, params)
    n_total = ram.store.live_count
    entry_nbytes = ram.store.entry_nbytes
    # host budget = a tenth of the store → the tier holds ~10x the
    # bytes RAM is allowed; everything else rides the disk tier. The
    # hot set is a quarter of that budget, leaving headroom for the
    # per-layer entries each request admits plus warmup junk.
    host_entries = max(1, n_total // 10)
    wl = _hot_workload(corpus, rate, n_requests, 29,
                       max(2, host_entries // 4))
    hit_ram, _ = two_pass(ram, wl)

    d = tempfile.mkdtemp(prefix="memo_chaos_capacity_")
    try:
        budget_mb = host_entries * entry_nbytes / 1e6
        sess = MemoSession.load(
            path, model, params,
            overrides={"capacity_dir": os.path.join(d, "tier"),
                       "budget_mb": budget_mb})
        demoted = sess.store.demote_to_budget()
        sess.store.sync(force_full=True)
        hit_disk, srv = two_pass(sess, wl)
        return {
            "n_entries": int(n_total),
            "host_budget_entries": int(host_entries),
            "n_demoted_at_start": len(demoted),
            "bytes_ratio": float(n_total / host_entries),
            "hit_rate_ram": float(hit_ram),
            "hit_rate_capacity": float(hit_disk),
            "hit_gap": max(0.0, float(hit_ram) - float(hit_disk)),
            "n_promoted": sess.store.stats.n_promoted,
            "n_demoted": sess.store.stats.n_demoted,
            "n_checkpoints": srv.n_checkpoints,
            "final_health": srv.health.value,
        }
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _persistence_leg(path, model, params):
    """Save/load under injected file faults: every leg must fail with a
    clean ``MemoStoreError`` (never a numpy/zipfile internal)."""
    out = {}
    d = tempfile.mkdtemp(prefix="memo_chaos_")
    try:
        torn = os.path.join(d, "torn.npz")
        shutil.copy(path, torn)
        with open(torn, "rb+") as f:
            f.truncate(os.path.getsize(torn) // 2)
        try:
            MemoSession.load(torn, model, params)
            out["truncated_clean_error"] = False
        except MemoStoreError:
            out["truncated_clean_error"] = True

        inj = FaultInjector()
        inj.arm("session.load_bitflip", at=1, count=1)
        try:
            MemoSession.load(path, model, params, faults=inj)
            out["bitflip_clean_error"] = False
        except MemoStoreError:
            out["bitflip_clean_error"] = True

        sess = MemoSession.load(path, model, params)
        sess.engine.faults.arm("session.save_truncate", at=1, count=1)
        torn2 = os.path.join(d, "torn2.npz")
        sess.save(torn2)
        try:
            MemoSession.load(torn2, model, params)
            out["save_truncate_clean_error"] = False
        except MemoStoreError:
            out["save_truncate_clean_error"] = True

        # atomic save: a crash mid-save over an EXISTING good file must
        # leave the old bytes serving (temp + os.replace, never inplace)
        good = os.path.join(d, "good.m3")
        sess.save(good)
        sess.engine.faults.arm("session.save_truncate", at=1, count=1)
        sess.save(good)                       # torn re-save, same path
        try:
            out["atomic_save_old_survives"] = (
                MemoSession.load(good, model, params)
                .store.live_count == sess.store.live_count)
        except MemoStoreError:
            out["atomic_save_old_survives"] = False
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return out


@functools.lru_cache(maxsize=2)
def collect(quick: bool = False, classes: tuple = None):
    if classes:
        unknown = sorted(set(classes) - set(CHAOS_PRESETS))
        if unknown:
            raise ValueError(f"unknown chaos classes {unknown}; known: "
                             f"{sorted(CHAOS_PRESETS)}")
    n_requests = 16 if quick else 32          # per phase
    d = tempfile.mkdtemp(prefix="memo_chaos_store_")
    try:
        path = os.path.join(d, "store.m3")
        model, params, corpus, rate = _build_and_save(path)
        out = {"config": {"arch": "bert_base (reduced, 2 layers)",
                          "requests_per_phase": n_requests,
                          "rate_rps": float(rate),
                          "buckets": list(BUCKETS),
                          "quick": bool(quick),
                          "backend": jax.default_backend()}}
        base = _chaos_leg(None, path, model, params, corpus, rate,
                          n_requests)
        out["baseline"] = base
        out["classes"] = {}
        for cls in (classes or CHAOS_PRESETS):
            t0 = time.time()
            leg = _chaos_leg(cls, path, model, params, corpus, rate,
                             n_requests)
            leg["hit_recovery_gap"] = max(
                0.0, base["hit_rate_after_recovery"]
                - leg["hit_rate_after_recovery"])
            leg["wall_s"] = round(time.time() - t0, 2)
            out["classes"][cls] = leg
        # The capacity + persistence legs ride the full run, or any run
        # that explicitly selects a disk class (the machinery they gate).
        if not classes or set(classes) & set(DISK_CLASSES):
            out["capacity"] = _capacity_leg(path, model, params, corpus,
                                            rate, n_requests)
            out["persistence"] = _persistence_leg(path, model, params)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return out


def run():
    out = collect()
    for cls, leg in out["classes"].items():
        yield (f"serve_faults_{cls}", leg["p99_ms"] * 1e3,
               f"avail={leg['availability']:.3f};"
               f"p99={leg['p99_ms']:.1f}ms;"
               f"hit_rec={leg['hit_rate_after_recovery']:.3f};"
               f"gap={leg['hit_recovery_gap']:.3f};"
               f"health={leg['final_health']}")
    p = out.get("persistence")
    if p:
        yield ("serve_faults_persistence", 0.0,
               f"truncated={p['truncated_clean_error']};"
               f"bitflip={p['bitflip_clean_error']};"
               f"save_truncate={p['save_truncate_clean_error']};"
               f"atomic={p['atomic_save_old_survives']}")
    cap = out.get("capacity")
    if cap:
        yield ("serve_faults_capacity", cap["hit_gap"] * 1e3,
               f"ratio={cap['bytes_ratio']:.1f}x;"
               f"hit_ram={cap['hit_rate_ram']:.3f};"
               f"hit_cap={cap['hit_rate_capacity']:.3f};"
               f"gap={cap['hit_gap']:.3f};"
               f"promoted={cap['n_promoted']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="16 requests/phase (the CI chaos-smoke size)")
    ap.add_argument("--classes", default=None,
                    help="comma-separated chaos classes to run (default: "
                         "all; the capacity + persistence legs run on the "
                         "full set or whenever a disk class is selected)")
    args = ap.parse_args()
    classes = None
    if args.classes:
        classes = tuple(c.strip() for c in args.classes.split(",")
                        if c.strip())
        unknown = sorted(set(classes) - set(CHAOS_PRESETS))
        if unknown:
            raise SystemExit(f"unknown chaos classes {unknown}; known: "
                             f"{sorted(CHAOS_PRESETS)}")
    out = collect(quick=args.quick, classes=classes)
    failures = []
    for cls, leg in out["classes"].items():
        ok_avail = leg["availability"] >= 1.0
        ok_gap = leg["hit_recovery_gap"] <= 0.05
        ok_reopen = leg.get("reopen_verify_clean", True)
        print(f"{cls:>16}: avail={leg['availability']:.3f} "
              f"p99={leg['p99_ms']:.1f}ms "
              f"hit_rec={leg['hit_rate_after_recovery']:.3f} "
              f"gap={leg['hit_recovery_gap']:.3f} "
              f"health={leg['final_health']} "
              f"shed={leg['n_maint_shed']} "
              f"retries={leg['n_maint_retries']} "
              f"quarantined={leg['n_quarantined']}"
              + (f" reopen={'ok' if ok_reopen else 'DIRTY'}"
                 if cls in DISK_CLASSES else "")
              + ("" if ok_avail and ok_gap and ok_reopen
                 else "   <-- FAIL"))
        if not ok_avail:
            failures.append(f"{cls}: availability "
                            f"{leg['availability']:.3f} < 1.0")
        if not ok_gap:
            failures.append(f"{cls}: hit_recovery_gap "
                            f"{leg['hit_recovery_gap']:.3f} > 0.05")
        if not ok_reopen:
            failures.append(
                f"{cls}: capacity dir did not reopen clean "
                f"({leg.get('reopen_error', 'verify_integrity dirty')})")
        if leg["final_health"] != Health.HEALTHY.value:
            failures.append(f"{cls}: final health "
                            f"{leg['final_health']} != healthy")
    cap = out.get("capacity")
    if cap:
        ok_cap = cap["hit_gap"] <= 0.05
        print(f"{'capacity':>16}: ratio={cap['bytes_ratio']:.1f}x "
              f"hit_ram={cap['hit_rate_ram']:.3f} "
              f"hit_cap={cap['hit_rate_capacity']:.3f} "
              f"gap={cap['hit_gap']:.3f} "
              f"promoted={cap['n_promoted']} "
              f"demoted={cap['n_demoted']} "
              f"health={cap['final_health']}"
              + ("" if ok_cap else "   <-- FAIL"))
        if not ok_cap:
            failures.append(f"capacity: hit_gap {cap['hit_gap']:.3f} "
                            f"> 0.05 at {cap['bytes_ratio']:.1f}x budget")
    for k, v in (out.get("persistence") or {}).items():
        print(f"{'persistence':>16}: {k}={v}"
              + ("" if v else "   <-- FAIL"))
        if not v:
            failures.append(f"persistence: {k} is False")
    if failures:
        print("\nCHAOS FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("\nall chaos classes: availability 1.0, recovery within "
          "tolerance")


if __name__ == "__main__":
    main()

"""Sharded memo store benchmark (ISSUE 9 / DESIGN.md §2.12).

Thin module wrapper so ``--only serve_sharded`` and the JSON detail
section address the sharded leg on its own (the CI ``shard-smoke`` job);
the implementation — an 8-way CPU-mesh subprocess serving a database
bigger than any one shard's position budget, vs a single-host store at
the same total byte budget — lives in ``serve_runtime.collect_sharded``.
"""
from __future__ import annotations

from benchmarks.serve_runtime import collect_sharded as collect  # noqa: F401
from benchmarks.serve_runtime import run_sharded as run  # noqa: F401

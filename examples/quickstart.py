"""Quickstart: AttMemo in ~60 lines, through the ``repro.memo`` facade.

Train a small encoder on the template corpus, build a memoization
session (attention + index databases behind one object), and compare
plain vs memoized inference.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.memo import MemoSession, MemoSpec, RuntimeSpec
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

# 1. a small BERT-family classifier (the paper's primary evaluation model)
cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=4)
model = build_model(cfg, layer_loop="unroll")
params = model.init(jax.random.PRNGKey(0))
corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=64, n_templates=8,
                        slot_fraction=0.25)

# 2. brief training
opt = adamw_init(params)

@jax.jit
def step(p, o, b):
    loss, g = jax.value_and_grad(model.classify_loss)(p, b)
    p, o = adamw_update(p, g, o, lr=3e-4)
    return loss, p, o

print("training ...")
for batch in corpus.batches(40, 32):
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, params, opt = step(params, opt, batch)
print(f"  final loss {float(loss):.4f}")

# 3. build the memoization session from a calibration stream
spec = MemoSpec(runtime=RuntimeSpec(threshold=0.8, mode="bucket"))
calib = [{"tokens": jnp.asarray(corpus.sample(32)[0])} for _ in range(5)]
session = MemoSession.build(model, params, spec, batches=calib,
                            key=jax.random.PRNGKey(1), verbose=True)
store = session.store
print(f"attention DB: {len(store.db)} APMs, {store.db.nbytes/1e6:.1f} MB")

# per-model threshold calibration (paper Table 2 / §5.4 autotuner)
levels = session.autotune(
    [{"tokens": jnp.asarray(corpus.sample(16)[0])}], level="aggressive")
print(f"calibrated thresholds: {levels}")

# 4. plain vs memoized inference
toks, labels = corpus.sample(64)
batchd = {"tokens": jnp.asarray(toks)}

logits, _ = session.infer(batchd, use_memo=False)     # warm both paths
logits_m, _ = session.infer(batchd)

t0 = time.perf_counter()
logits, _ = session.infer(batchd, use_memo=False)
t_plain = time.perf_counter() - t0
t0 = time.perf_counter()
logits_m, st = session.infer(batchd)
t_memo = time.perf_counter() - t0

acc = (np.argmax(np.asarray(logits), -1) == labels).mean()
acc_m = (np.argmax(np.asarray(logits_m), -1) == labels).mean()
print(f"plain    : {t_plain*1e3:7.1f} ms  acc {acc:.3f}")
print(f"memoized : {t_memo*1e3:7.1f} ms  acc {acc_m:.3f}  "
      f"memo-rate {st.memo_rate*100:.0f}%")

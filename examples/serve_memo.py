"""Serving example — the full memo lifecycle through ``repro.memo``.

Walks what the facade exposes (DESIGN.md §2.5–2.8): build → lookup →
online admission under a byte budget → CLOCK eviction → generation-
counted delta sync → atomic snapshot publish — then serves an open-loop
variable-length request stream through ``session.serve()`` (the
MemoServer runtime with off-thread maintenance).

    PYTHONPATH=src python examples/serve_memo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.memo import (
    AdmissionPolicy, EmbedSpec, MemoSession, MemoSpec, RuntimeSpec)
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

SEQ = 32
cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2)
model = build_model(cfg, layer_loop="unroll")
params = model.init(jax.random.PRNGKey(0))
corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, seed=2,
                        n_templates=6, slot_fraction=0.2)

# a briefly-trained classifier (the paper's BERT/SST-2 analogue)
opt = adamw_init(params)


@jax.jit
def _step(p, o, b):
    loss, g = jax.value_and_grad(model.classify_loss)(p, b)
    p, o = adamw_update(p, g, o, lr=3e-4)
    return p, o, loss


for b in corpus.batches(30, 32):
    b = {k: jnp.asarray(v) for k, v in b.items()}
    params, opt, loss = _step(params, opt, b)

# --- build: calibration corpus becomes the store's first epoch ---------
spec = MemoSpec(
    runtime=RuntimeSpec(threshold=0.8, mode="bucket", device_slack=8.0),
    embed=EmbedSpec(steps=80),
    admission=AdmissionPolicy(enabled=True, budget_mb=64.0,
                              recal_every=2))
calib = [{"tokens": jnp.asarray(corpus.sample(16)[0])} for _ in range(4)]
session = MemoSession.build(model, params, spec, batches=calib,
                            key=jax.random.PRNGKey(1))
# per-model threshold autotune (paper Table 2 / §5.4) from a fresh sample
session.autotune([{"tokens": jnp.asarray(corpus.sample(16)[0])}],
                 level="aggressive")
store = session.store
print(f"[store] built: {len(store.db)} entries, "
      f"{store.live_count * store.entry_nbytes / 1e6:.2f} MB "
      f"({store.codec.name} codec), threshold "
      f"{spec.runtime.threshold:.3f} (autotuned)")

# --- lookup: the host-tier search API ----------------------------------
# (the engine embeds internally; query with stored calibration
# embeddings to show the raw store API)
q = store.embeddings_at(np.arange(4))
dist, slots = store.lookup(q, k=1)
print(f"[store] lookup: top-1 slots {slots[:, 0].tolist()} at L2 "
      f"{np.round(dist[:, 0], 4).tolist()} (self-queries → 0)")

# --- online admission: drifted traffic, captured misses, delta sync ----
drifted = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, seed=117,
                         n_templates=6, slot_fraction=0.2)
rates = []
for i in range(6):
    toks = jnp.asarray(drifted.sample(16)[0])
    _, st = session.infer({"tokens": toks})
    rates.append(st.memo_rate)
s = store.stats
print(f"[store] drift hit-rate {' '.join(f'{r:.2f}' for r in rates)} — "
      f"{s.n_admitted} admitted, {s.n_delta_syncs} delta syncs "
      f"({s.bytes_delta / 1e6:.2f} MB shipped vs "
      f"{s.n_delta_syncs * len(store.db) * store.entry_nbytes / 1e6:.1f} MB "
      f"full-resync strawman)")

# --- eviction: reuse-aware CLOCK, tombstoned index rows ----------------
before = store.live_count
store.evict(8)
store.sync()                       # ships the tombstones, publishes
print(f"[store] evicted {before - store.live_count} cold entries "
      f"(live {store.live_count}); snapshot generation "
      f"{store.snapshot.generation}")

# --- the serving runtime: open-loop variable-length requests -----------
rng = np.random.default_rng(7)
wl = []
t = 0.0
for i in range(32):
    t += float(rng.exponential(0.01))
    ln = int(rng.choice([SEQ // 2, SEQ]))
    wl.append((t, np.asarray(drifted.sample(1)[0][0, :ln])))
t0 = time.perf_counter()
with session.serve(buckets=(SEQ // 2, SEQ), max_batch=8,
                   async_maintenance=True) as server:
    server.warmup()
    comps = server.run(wl)
wall = time.perf_counter() - t0
lat = np.asarray([c.latency for c in comps]) * 1e3
print(f"[serve] {len(comps)} requests in {wall:.2f}s "
      f"({len(comps) / wall:.0f} req/s) | p50 {np.percentile(lat, 50):.1f} "
      f"ms p99 {np.percentile(lat, 99):.1f} ms | hit rate "
      f"{server.stats.memo_rate * 100:.0f}% | "
      f"{server.stats.n_admitted} admitted off-thread")

"""Serving example: batched request stream through the memoized engine
with selective memoization (Eq. 3) and hit/miss bucketing — the paper's
online inference engine end to end.

    PYTHONPATH=src python examples/serve_memo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.engine import LEVELS, MemoConfig, MemoEngine, MemoStats
from repro.data import TemplateCorpus
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=4)
model = build_model(cfg, layer_loop="unroll")
params = model.init(jax.random.PRNGKey(0))
corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=64, seed=2)

opt = adamw_init(params)
step = jax.jit(lambda p, o, b: _s(p, o, b))
def _s(p, o, b):
    loss, g = jax.value_and_grad(model.classify_loss)(p, b)
    return (*adamw_update(p, g, o, lr=3e-4), loss)
for b in corpus.batches(40, 32):
    b = {k: jnp.asarray(v) for k, v in b.items()}
    params, opt, loss = step(params, opt, b)

engine = MemoEngine(model, params, MemoConfig(threshold=LEVELS["moderate"],
                                              mode="bucket"))
calib = [{"tokens": jnp.asarray(corpus.sample(32)[0])} for _ in range(6)]
engine.build(jax.random.PRNGKey(1), calib)

# offline profiler -> selective memoization plan (Eq. 3)
pm = engine.profile({"tokens": jnp.asarray(corpus.sample(32)[0])})
print(pm.summary())
active = pm.active_layers()
print(f"[serve] memoizing layers {active} of {engine.layers}\n")

# request loop
stats = MemoStats()
lat = {"plain": [], "memo": []}
for req in range(8):
    toks = jnp.asarray(corpus.sample(16)[0])
    t0 = time.perf_counter()
    out, _ = engine.infer({"tokens": toks}, use_memo=False)
    jax.block_until_ready(out)
    lat["plain"].append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    out, stats = engine.infer({"tokens": toks}, stats=stats,
                              active_layers=active)
    jax.block_until_ready(out)
    lat["memo"].append(time.perf_counter() - t0)

p = np.median(lat["plain"][1:]) * 1e3
m = np.median(lat["memo"][1:]) * 1e3
print(f"[serve] plain {p:7.1f} ms/batch | memo {m:7.1f} ms/batch "
      f"({(1 - m/p)*100:+.1f}%)")
print(f"[serve] memo rate {stats.memo_rate*100:.0f}%  "
      f"embed {stats.t_embed:.2f}s search {stats.t_search:.2f}s "
      f"fetch {stats.t_fetch:.2f}s")

"""End-to-end driver (deliverable b): train a ~100M-param GPT-2-class LM
for a few hundred steps with the full substrate (data pipeline, AdamW,
cosine schedule, checkpointing), then build an AttMemo database from the
trained model and report memoized scoring latency.

    PYTHONPATH=src python examples/train_memoize.py [--steps 300] [--small]

--small shrinks to a CI-sized run (default is the real ~100M config; on a
single CPU core a few hundred steps is hours — the flag exists so the
example is runnable everywhere).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import TemplateCorpus, lm_batches
from repro.memo import EmbedSpec, MemoSession, MemoSpec, RuntimeSpec
from repro.models import build_model
from repro.train import TrainConfig, Trainer
from repro.train.checkpoint import load_checkpoint, save_checkpoint

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true")
ap.add_argument("--seq", type=int, default=None)
ap.add_argument("--batch", type=int, default=None)
ap.add_argument("--ckpt", default="checkpoints/gpt2_memo.npz")
args = ap.parse_args()

if args.small:
    cfg = get_reduced("gpt2_small").replace(n_layers=4)
    seq, batch = args.seq or 64, args.batch or 8
else:
    cfg = get_config("gpt2_small")          # ~110M params (paper Table 1)
    seq, batch = args.seq or 256, args.batch or 8

print(f"[e2e] {cfg.name}: {cfg.param_count()/1e6:.0f}M params, "
      f"{args.steps} steps @ batch {batch} x seq {seq}")
model = build_model(cfg, layer_loop="unroll")
params = model.init(jax.random.PRNGKey(0))
corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=seq, n_templates=16,
                        slot_fraction=0.3, seed=0)

trainer = Trainer(model, TrainConfig(steps=args.steps, lr=3e-4,
                                     warmup=max(10, args.steps // 10),
                                     log_every=max(1, args.steps // 10)))
params, _, hist = trainer.fit(
    params, lm_batches(cfg.vocab, seq, batch, args.steps, corpus=corpus))
print(f"[e2e] loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")
save_checkpoint(args.ckpt, params, step=args.steps, meta={"arch": cfg.name})

# --- memoize the trained decoder's self-attention -------------------------
spec = MemoSpec(runtime=RuntimeSpec(threshold=0.9, mode="select",
                                    max_layers=4),
                embed=EmbedSpec(steps=150))
calib = [{"tokens": jnp.asarray(corpus.sample(batch)[0])} for _ in range(4)]
sess = MemoSession.build(model, params, spec, batches=calib,
                         key=jax.random.PRNGKey(1), verbose=True)
db = sess.store.db
print(f"[e2e] DB {len(db)} APMs / {db.nbytes/1e6:.1f} MB")
sess.autotune([{"tokens": jnp.asarray(corpus.sample(batch)[0])}],
              level="moderate")

toks = jnp.asarray(corpus.sample(batch)[0])
logits_p, _ = sess.infer({"tokens": toks}, use_memo=False)
logits_m, st = sess.infer({"tokens": toks})
# memoized scoring must stay close in next-token ranking
agree = (np.argmax(np.asarray(logits_p), -1)
         == np.argmax(np.asarray(logits_m), -1)).mean()
print(f"[e2e] memo-rate {st.memo_rate*100:.0f}%  "
      f"next-token agreement {agree*100:.1f}%")

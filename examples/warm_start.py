"""Warm-start serving — the paper's offline-built database, shipped.

AttMemo assumes the memo database is built offline and served from big
memory (paper §5.1); ``MemoSession.save``/``load`` makes that real: one
process calibrates and persists the populated store (codec arenas, index
state, sim_cal, entry lengths, trained embedder, full spec), another
loads it and serves immediately — no calibration pass, no embedder
training, identical lookups.

    PYTHONPATH=src python examples/warm_start.py
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import TemplateCorpus
from repro.memo import EmbedSpec, MemoSession, MemoSpec, RuntimeSpec
from repro.models import build_model

SEQ = 32
cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2)
model = build_model(cfg, layer_loop="unroll")
params = model.init(jax.random.PRNGKey(0))
corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, seed=3,
                        n_templates=6, slot_fraction=0.2)

# --- the "offline" leg: calibrate, autotune, persist -------------------
spec = MemoSpec(runtime=RuntimeSpec(mode="bucket"),
                embed=EmbedSpec(steps=60))
calib = [{"tokens": jnp.asarray(corpus.sample(16)[0])} for _ in range(4)]
t0 = time.perf_counter()
offline = MemoSession.build(model, params, spec, batches=calib,
                            key=jax.random.PRNGKey(1))
offline.autotune([{"tokens": jnp.asarray(corpus.sample(16)[0])}],
                 level="aggressive")
build_s = time.perf_counter() - t0
path = os.path.join(tempfile.mkdtemp(), "memo_store.npz")
offline.save(path)
print(f"[offline] built in {build_s:.1f}s, saved "
      f"{os.path.getsize(path)/1e6:.2f} MB "
      f"({offline.store.live_count} entries, "
      f"{offline.store.codec.name} codec) -> {path}")

# --- the "serving" leg: load and serve, no calibration -----------------
t0 = time.perf_counter()
warm = MemoSession.load(path, model, params)
load_s = time.perf_counter() - t0
print(f"[warm] loaded in {load_s:.2f}s "
      f"({build_s / max(load_s, 1e-9):.0f}x faster than rebuilding)")

toks = jnp.asarray(corpus.sample(16)[0])
out_off, st_off = offline.infer({"tokens": toks})
out_warm, st_warm = warm.infer({"tokens": toks})
same = np.array_equal(np.asarray(out_off), np.asarray(out_warm))
print(f"[warm] hit rate {st_warm.memo_rate:.2f} "
      f"(offline session: {st_off.memo_rate:.2f}); "
      f"logits identical: {same}")
assert same and st_warm.memo_rate == st_off.memo_rate

# serve an open-loop trace straight off the loaded store
rng = np.random.default_rng(5)
wl, t = [], 0.0
for _ in range(24):
    t += float(rng.exponential(0.01))
    wl.append((t, np.asarray(corpus.sample(1)[0][0])))
with warm.serve(buckets=(SEQ,), max_batch=8) as server:
    server.warmup()
    comps = server.run(wl)
lat = np.asarray([c.latency for c in comps]) * 1e3
print(f"[warm] served {len(comps)} requests | p50 "
      f"{np.percentile(lat, 50):.1f} ms | hit rate "
      f"{server.stats.memo_rate * 100:.0f}%")

"""Per-assigned-architecture smoke tests (reduced configs, CPU).

For each architecture: instantiate a reduced variant of the same family
(<=2-3 layers, d_model<=512, <=4 experts), run one forward and one train
step, assert output shapes and no NaNs; plus prefill/decode-vs-full
consistency for every decodable arch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import build_model

ASSIGNED = [a for a in ARCH_IDS if a not in ("bert_base", "gpt2_small")]


def _batch(cfg, key, B=2, S=16):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.encoder.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = _batch(cfg, key)
    logits, _, aux = m.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not jnp.any(jnp.isnan(logits))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
    assert np.isfinite(float(loss))
    # apply a plain SGD step and ensure the loss is still finite
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = m.train_loss(params2, batch)
    assert np.isfinite(float(loss2))
    leaves = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(v) for v in leaves)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "bert_base"])
def test_decode_matches_full(arch):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = m.init(key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    tok = batch["tokens"]
    full_logits, _, _ = m.forward(params, batch)
    last, caches = m.prefill(params, dict(batch, tokens=tok[:, :S - 1]),
                             cache_len=S + 4)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-4, atol=2e-4)
    dec, _ = m.decode_step(params, tok[:, S - 1:S], caches, pos=S - 1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned dimensions."""
    expect = {
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "qwen3_8b": (36, 4096, 32, 8, 12288, 151936),
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (arch, got, expect)


def test_param_counts_sane():
    """Analytic param counts land in the advertised ballparks."""
    assert 3e9 < get_config("minicpm3_4b").param_count() < 5.5e9
    assert 2.2e9 < get_config("rwkv6_3b").param_count() < 4.5e9
    assert 5e9 < get_config("deepseek_7b").param_count() < 8e9
    assert 1.1e11 < get_config("dbrx_132b").param_count() < 1.6e11
    assert 3e10 < get_config("chameleon_34b").param_count() < 4.5e10
    assert 6.5e9 < get_config("qwen3_8b").param_count() < 9.5e9
    k = get_config("kimi_k2_1t_a32b")
    assert 0.85e12 < k.param_count() < 1.25e12
    assert 2.2e10 < k.active_param_count() < 4.5e10


def test_memoizable_layers():
    assert get_config("rwkv6_3b").memoizable_layers() == ()
    rg = get_config("recurrentgemma_2b")
    # every third layer is local attention
    assert all(i % 3 == 2 for i in rg.memoizable_layers())
    assert len(get_config("qwen3_8b").memoizable_layers()) == 36


def test_sliding_window_mask_decode():
    """Rolling-buffer windowed decode == windowed full forward (note:
    sliding-window receptive fields grow with depth, so the reference is a
    window-masked full forward, not a truncated context)."""
    cfg = get_reduced("qwen3_8b")
    m = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init(key)
    B, S, W = 1, 10, 4
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    # rolling-buffer decode with window W: buffer holds only W slots
    caches = m.init_caches(B, W, window=W)
    logits_w = None
    for t in range(S):
        logits_w, caches = m.decode_step(params, tok[:, t:t + 1], caches,
                                         pos=t, window=W)
    full, _, _ = m.forward(params, {"tokens": tok}, window=W)
    np.testing.assert_allclose(np.asarray(logits_w),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)
    # and the rolling buffer == a full-length cache with window masking
    caches2 = m.init_caches(B, S)
    for t in range(S):
        logits_f, caches2 = m.decode_step(params, tok[:, t:t + 1], caches2,
                                          pos=t, window=W)
    np.testing.assert_allclose(np.asarray(logits_w), np.asarray(logits_f),
                               rtol=1e-4, atol=1e-4)


def test_optimized_config_variants():
    """Adopted hillclimb configs exist and still smoke-test (reduced)."""
    from repro.configs import minicpm3_4b, rwkv6_3b
    for mod in (minicpm3_4b, rwkv6_3b):
        cfg = mod.optimized()
        assert cfg.act_shard_batch == ("data", "model")
        # reduced structural check: the knob doesn't break single-device
        red = mod.reduced().replace(act_shard_batch=None)
        m = build_model(red)
        params = m.init(jax.random.PRNGKey(0))
        logits, _, _ = m.forward(params, _batch(red, jax.random.PRNGKey(1)))
        assert not jnp.any(jnp.isnan(logits))

"""CLI launcher smoke tests (subprocess, reduced configs)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH="src")


def _run(args, timeout=900):
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=ENV, cwd=REPO, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_launcher_reduced(tmp_path):
    ck = os.path.join(tmp_path, "ck.npz")
    out = _run(["repro.launch.train", "--arch", "gpt2_small", "--reduced",
                "--steps", "12", "--batch", "4", "--seq", "32",
                "--ckpt", ck])
    assert "done: loss" in out
    assert os.path.exists(ck)
    # loss must decrease
    import re
    m = re.search(r"loss (\d+\.\d+) -> (\d+\.\d+)", out)
    assert float(m.group(2)) < float(m.group(1))


def test_serve_launcher_reduced():
    out = _run(["repro.launch.serve", "--arch", "bert_base", "--requests",
                "16", "--batch", "8", "--seq", "48", "--calib-batches", "2",
                "--level", "aggressive"])
    assert "memo rate" in out
    assert "baseline" in out


def test_dryrun_cli_single_combo(tmp_path):
    out = _run(["repro.launch.dryrun", "--arch", "qwen2_1_5b", "--shape",
                "decode_32k", "--single-pod-only", "--no-correct",
                "--out", str(tmp_path)], timeout=1200)
    assert "-> ok" in out
    assert os.path.exists(
        os.path.join(tmp_path, "qwen2_1_5b_decode_32k_pod256.json"))

"""Dry-run machinery on a small fake-device mesh (subprocess isolated —
device count locks at first jax init). One representative arch per family
x one shape per kind keeps CI tractable; the full 10x4x2 sweep is
``python -m repro.launch.dryrun --all`` (results in experiments/dryrun)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, jax
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_step
from repro.launch.hlo_utils import collective_bytes, cost_summary

arch, shape = sys.argv[1], sys.argv[2]
mesh = make_host_mesh(4, 2)
built = build_step(arch, shape, mesh)
assert built is not None
# newer jax wants the ambient mesh set; the NamedShardings below carry
# the mesh themselves, so older jax just lowers without the context
import contextlib
set_mesh = getattr(jax, "set_mesh", None)
with (set_mesh(mesh) if set_mesh else contextlib.nullcontext()):
    lowered = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                      out_shardings=built["out_shardings"]).lower(*built["args"])
    compiled = lowered.compile()
ma = compiled.memory_analysis()
assert ma is not None and ma.argument_size_in_bytes > 0
cs = cost_summary(compiled)
assert cs["flops"] > 0
cb = collective_bytes(compiled.as_text())
print("DRYRUN-OK", cs["flops"], cb["total"])
"""


def _run(arch, shape):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", CODE, arch, shape],
                         capture_output=True, text=True, env=env, cwd=REPO,
                         timeout=1200)
    assert "DRYRUN-OK" in out.stdout, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("arch,shape", [
    ("qwen2_1_5b", "train_4k"),          # dense train
    ("dbrx_132b", "decode_32k"),         # MoE decode (EP small-T path)
    ("rwkv6_3b", "long_500k"),           # ssm long-context decode
    ("recurrentgemma_2b", "prefill_32k"),  # hybrid prefill
    ("whisper_medium", "train_4k"),      # enc-dec train
    ("minicpm3_4b", "decode_32k"),       # MLA absorbed decode
])
def test_dryrun_lowers_small_mesh(arch, shape):
    _run(arch, shape)


def test_production_dryrun_artifacts_exist():
    """The committed artifact sweep must cover every (arch x shape) on the
    single-pod mesh with ok/skipped status (run via launch.dryrun --all)."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 40:
        pytest.skip("full dry-run sweep artifacts not present")
    bad = []
    for f in os.listdir(d):
        if f.endswith("_pod256.json"):
            r = json.load(open(os.path.join(d, f)))
            if r["status"] not in ("ok", "skipped"):
                bad.append((f, r.get("error", "")[:100]))
    assert not bad, bad

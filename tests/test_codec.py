"""Compressed memo tiers (ISSUE 3 / DESIGN.md §2.6).

Covers: codec round-trip error bounds (int8 per-row scale bound, lowrank
truncation-energy bound), host/device decode parity (bit-exact for int8),
quantized-store serve parity vs the select reference under every codec
(select and the fast paths decode the SAME stored entry, so they must
agree), the int8 fused-dequant kernel path end to end, the
ClusteredDeviceIndex recall@1 ≥ 0.95 property vs the ExactIndex oracle,
the flat→clustered crossover in MemoStore.sync, and the
one-barrier-per-batch invariant on the quantized + clustered fast path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st_h

import repro.core.engine as engine_mod
from repro.core.codec import F16Codec, Int8Codec, LowRankCodec, get_codec
from repro.core.index import ClusteredDeviceIndex, ExactIndex, recall_at_1
from repro.core.store import MemoStore

CODECS = ["f16", "int8", "lowrank"]


def _rand_apms(seed, n=8, h=2, l=16):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, h, l, l))
    e = np.exp(x - x.max(-1, keepdims=True))
    return (e / e.sum(-1, keepdims=True)).astype(np.float16)


# ------------------------------------------------------------ round trips

def test_int8_roundtrip_error_bounded_by_row_scale():
    """|decode(encode(x)) − x| ≤ the per-row quantization step (scale):
    half a step from rounding plus f16 scale storage slack."""
    apms = _rand_apms(0)
    c = Int8Codec(apms.shape[1:])
    codes, scales = c.encode(apms)
    dec = c.decode((codes, scales)).astype(np.float32)
    err = np.abs(dec - apms.astype(np.float32))
    bound = scales.astype(np.float32)[..., None]        # one full step
    assert (err <= bound + 1e-6).all()
    # softmax rows have amax ≤ 1 → absolute error ≤ 1/127 everywhere
    assert err.max() <= 1.0 / 127 + 1e-6
    # decoded rows still ~sum to 1 (the memo kernel's no-renorm shortcut)
    assert np.abs(dec.sum(-1) - 1.0).max() < 0.05


def test_int8_decode_bit_parity_host_vs_device():
    """The host (numpy) and device (jnp) decoders perform the identical
    f32-multiply → f16-round sequence — bit-for-bit equal, which is what
    keeps select vs fast-path logits parity EXACT under compression."""
    apms = _rand_apms(1)
    c = Int8Codec(apms.shape[1:])
    parts = c.encode(apms)
    host = c.decode(parts)
    dev = np.asarray(c.decode_rows(tuple(jnp.asarray(p) for p in parts)))
    np.testing.assert_array_equal(host, dev)


def test_lowrank_roundtrip_error_bounded_by_truncation_energy():
    """‖APM − decode‖_F per (entry, head) is bounded by the discarded
    singular mass (the rank-r optimum) plus int8 quantization slack."""
    apms = _rand_apms(2)
    c = LowRankCodec(apms.shape[1:], rank=6)
    dec = c.decode(c.encode(apms)).astype(np.float32)
    x = apms.astype(np.float32)
    _, s, _ = np.linalg.svd(x)
    tail = np.sqrt((s[..., c.rank:] ** 2).sum(-1))      # (n, h)
    frob = np.sqrt(((dec - x) ** 2).sum((-1, -2)))
    # quant slack: per-row step ≤ amax/127 over L·r elements per factor
    assert (frob <= tail + 0.35).all(), (frob.max(), tail.max())


@pytest.mark.parametrize("codec,atol", [("f16", 0.0), ("int8", 2e-6),
                                        ("lowrank", 5e-3)])
def test_roundtrip_is_stable(codec, atol):
    """Re-encoding a decoded value doesn't drift: exactly reproduced for
    f16/int8 (rounding is a projection), within one quantization step for
    lowrank (the SVD of U·Vᵀ re-rotates the factors before requantizing)
    — admissions re-captured from served outputs stay put."""
    apms = _rand_apms(3)
    c = get_codec(codec, apms.shape[1:])
    dec1 = c.decode(c.encode(apms))
    dec2 = c.decode(c.encode(dec1))
    np.testing.assert_allclose(dec2.astype(np.float32),
                               dec1.astype(np.float32), atol=atol, rtol=0)


def test_codec_bytes_ratios():
    """The acceptance bookkeeping: codec-true entry bytes vs the logical
    f16 entry. int8 ≈ 0.5× + scales; lowrank(r) ≈ (r+2)/L."""
    h, l = 4, 64
    base = h * l * l * 2
    assert F16Codec((h, l, l)).entry_nbytes == base
    i8 = Int8Codec((h, l, l)).entry_nbytes
    assert i8 == h * l * l + h * l * 2
    assert 0.5 <= i8 / base <= 0.55
    lr = LowRankCodec((h, l, l), rank=8).entry_nbytes
    assert lr == 2 * (h * l * 8 + h * l * 2)
    assert lr / base <= 0.30                 # the compressed-tier target


# ------------------------------------------------- store-level integration

@pytest.mark.parametrize("codec", ["int8", "lowrank"])
def test_store_roundtrip_and_sync_ship_compressed_bytes(codec):
    apm_shape, dim = (2, 16, 16), 8
    s = MemoStore(apm_shape, dim, capacity=4, codec=codec)
    apms = _rand_apms(4, n=6, h=2, l=16)
    rng = np.random.default_rng(4)
    embs = rng.normal(0, 0.01, (6, dim)).astype(np.float32)
    embs[:, 0] += 10 * np.arange(1, 7)
    slots = s.admit(apms, embs)
    c = s.codec
    np.testing.assert_allclose(
        s.db.get(slots, count_reuse=False).astype(np.float32),
        c.decode(c.encode(apms)).astype(np.float32), atol=2e-6, rtol=0)
    r = s.sync()
    assert r["kind"] == "full"
    # the device tier holds compressed rows; bytes/entry < the f16 layout
    assert s.device_db.entry_nbytes < s.db.logical_entry_nbytes
    apms2 = _rand_apms(5, n=2, h=2, l=16)
    embs2 = rng.normal(0, 0.01, (2, dim)).astype(np.float32)
    embs2[:, 0] += 1000.0
    s.admit(apms2, embs2)
    r = s.sync()
    assert r["kind"] == "delta"
    # delta ships ≤ padded compressed rows (+ index f32 rows + slot ids),
    # strictly less than the equivalent f16 shipment
    f16_equiv = 2 * (s.db.logical_entry_nbytes + dim * 4 + 16)
    assert r["bytes"] < f16_equiv
    np.testing.assert_allclose(
        np.asarray(s.device_db.gather(jnp.asarray(slots[:3]))).astype(
            np.float32),
        s.db.get(slots[:3], count_reuse=False).astype(np.float32),
        atol=2e-6, rtol=0)


def test_store_flips_flat_to_clustered_at_crossover():
    apm_shape, dim = (1, 4, 4), 8
    s = MemoStore(apm_shape, dim, capacity=4, cluster_crossover=12)
    rng = np.random.default_rng(6)

    def batch(n, off):
        apms = rng.random((n, *apm_shape)).astype(np.float16)
        embs = rng.normal(0, 0.01, (n, dim)).astype(np.float32)
        embs[:, 0] += 10.0 * (off + np.arange(n))
        return apms, embs

    s.admit(*batch(6, 1))
    s.sync()
    assert type(s.device_index).__name__ == "DeviceIndex"
    s.admit(*batch(10, 100))
    s.sync()
    assert isinstance(s.device_index, ClusteredDeviceIndex)
    # device search still finds every live entry (near-dup regime)
    q = jnp.asarray(s._embs_host[: len(s.db)])
    _, idx = s.device_index.search_device(q)
    np.testing.assert_array_equal(np.asarray(idx)[:, 0],
                                  np.arange(len(s.db)))


def test_clustered_sync_routes_evictions_through_remove():
    """Regression: the sync delta path must tombstone evicted slots via
    remove(), not assign() — an assign would append the tombstone row to
    the clustered index's always-scored overflow buffer and count toward
    the rebuild trigger, so a steady eviction stream would force
    spurious k-means rebuilds mid-serving."""
    apm_shape, dim = (1, 4, 4), 8
    s = MemoStore(apm_shape, dim, capacity=4, cluster_crossover=1)
    rng = np.random.default_rng(11)
    apms = rng.random((12, *apm_shape)).astype(np.float16)
    embs = rng.normal(0, 0.01, (12, dim)).astype(np.float32)
    embs[:, 0] += 10.0 * np.arange(1, 13)
    slots = s.admit(apms, embs)
    s.sync()
    di = s.device_index
    assert isinstance(di, ClusteredDeviceIndex)
    rebuilds0 = di.n_rebuilds
    ev = s.evict(3)
    s.sync()
    # no overflow pollution, no spurious rebuild
    assert not any(int(e) in di._opos for e in ev)
    assert di.n_rebuilds == rebuilds0
    # evicted entries can never be returned, even for their own embedding
    for e in ev:
        _, idx = di.search(embs[list(slots).index(e)][None], 1)
        assert int(idx[0, 0]) != int(e)


# ----------------------------------------------- clustered index properties

@settings(max_examples=10, deadline=None)
@given(seed=st_h.integers(0, 10 ** 6))
def test_clustered_recall_at_1_property(seed):
    """Serving-regime recall: a request batch drawn from a handful of
    templates, each query near a stored entry (the memo-hit case — far
    queries are threshold-rejected misses regardless of which stranger
    wins the argmin, and batch-shared probing guarantees stage-1
    exactness while the batch's distinct top-1 clusters fit in nprobe).
    recall@1 ≥ 0.95 vs the exact oracle."""
    rng = np.random.default_rng(seed)
    n_centers = int(rng.integers(4, 24))
    dim = int(rng.choice([16, 32, 64]))
    centers = rng.normal(size=(n_centers, dim)) * 5
    db = (centers[rng.integers(0, n_centers, 1500)]
          + rng.normal(size=(1500, dim))).astype(np.float32)
    n_templates = int(rng.integers(1, 9))       # requests per batch cluster
    rows = db[rng.integers(0, 1500, n_templates)]
    q = (rows[rng.integers(0, n_templates, 64)]
         + 0.1 * rng.normal(size=(64, dim))).astype(np.float32)
    exact = ExactIndex(dim)
    exact.add(db)
    cl = ClusteredDeviceIndex(dim, seed=seed % 17)
    cl.add(db)
    assert recall_at_1(cl, exact, q) >= 0.95


def test_clustered_lifecycle_assign_remove_topk():
    rng = np.random.default_rng(7)
    db = rng.normal(size=(600, 32)).astype(np.float32)
    cl = ClusteredDeviceIndex(32, nprobe=6)
    cl.add(db)
    # fresh admissions are findable immediately (overflow buffer, no
    # rebuild needed)
    rebuilds0 = cl.n_rebuilds
    extra = rng.normal(size=(4, 32)).astype(np.float32) + 50.0
    cl.assign(np.arange(600, 604), extra)
    assert cl.n_rebuilds == rebuilds0
    _, idx = cl.search(extra, 1)
    np.testing.assert_array_equal(idx[:, 0], np.arange(600, 604))
    # removed entries can never be returned, even for their own embedding
    cl.remove([600])
    _, idx = cl.search(extra[:1], 1)
    assert int(idx[0, 0]) != 600
    # top-k comes back sorted
    d, i = cl.search(db[:5], 3)
    assert i.shape == (5, 3)
    assert (d[:, 0] <= d[:, 1]).all() and (d[:, 1] <= d[:, 2]).all()
    np.testing.assert_array_equal(i[:, 0], np.arange(5))


def test_clustered_rebuild_absorbs_overflow():
    rng = np.random.default_rng(8)
    db = rng.normal(size=(200, 16)).astype(np.float32)
    # default nprobe ≥ C here → every cluster probed: the test isolates
    # overflow/rebuild bookkeeping, not probe selectivity
    cl = ClusteredDeviceIndex(16, rebuild_frac=0.1)
    cl.add(db)
    cl.search(db[:1], 1)                        # force the initial build
    r0 = cl.n_rebuilds
    assert r0 == 1
    extra = rng.normal(size=(40, 16)).astype(np.float32)
    cl.assign(np.arange(200, 240), extra)       # 40 > 0.1·N → rebuild
    assert cl.n_rebuilds > r0
    assert len(cl._overflow) == 0
    _, idx = cl.search(extra, 1)
    np.testing.assert_array_equal(idx[:, 0], np.arange(200, 240))


def test_clustered_search_traceable_and_retraces_on_rebuild():
    rng = np.random.default_rng(9)
    db = rng.normal(size=(300, 16)).astype(np.float32)
    cl = ClusteredDeviceIndex(16, nprobe=4)
    cl.add(db)
    traces = []

    @jax.jit
    def fused(q, args):
        traces.append(1)
        d2, idx = cl.search_device(q, args=args)
        return idx[:, 0]

    q = jnp.asarray(db[:4])
    i1 = fused(q, cl.search_args)
    np.testing.assert_array_equal(np.asarray(i1), np.arange(4))
    fused(q, cl.search_args)
    assert len(traces) == 1                     # cache hit, no retrace
    cl.assign(np.arange(300, 364),              # force a rebuild
              rng.normal(size=(64, 16)).astype(np.float32))
    cl.rebuild()
    i2 = fused(q, cl.search_args)               # new shapes → retrace
    np.testing.assert_array_equal(np.asarray(i2), np.arange(4))
    assert len(traces) == 2


# ------------------------------------------------- engine-level serve parity

@pytest.fixture(scope="module")
def engine_factory():
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256, n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, n_templates=6,
                            slot_fraction=0.2)
    batches = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)]
    cache = {}

    def make(**mc_kw):
        key = tuple(sorted(mc_kw.items()))
        if key not in cache:
            eng = MemoEngine(m, params, MemoSpec.flat(
                threshold=0.6, embed_steps=40, mode="bucket", **mc_kw))
            eng.build(jax.random.PRNGKey(1), batches)
            cache[key] = eng
        return cache[key], corpus

    return make


def _select_logits(eng, toks):
    mode = eng.mc.mode
    eng.mc.mode = "select"
    try:
        out, st = eng.infer({"tokens": toks})
    finally:
        eng.mc.mode = mode
    return np.asarray(out), st


@pytest.mark.parametrize("codec", CODECS)
def test_quantized_store_fast_path_matches_select(engine_factory, codec):
    """Select and the device fast path decode the SAME stored entry, so
    compression cannot break parity: logits agree within the float
    tolerance for every codec (bit-identical decode for f16/int8; matmul
    reassociation only for lowrank)."""
    eng, corpus = engine_factory(apm_codec=codec)
    toks = jnp.asarray(corpus.sample(8)[0])
    ref, st_ref = _select_logits(eng, toks)
    out, st = eng.infer({"tokens": toks})
    assert st.n_hits == st_ref.n_hits          # same hit decisions
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_int8_kernel_mode_fused_dequant_matches_select(engine_factory):
    """End-to-end int8 kernel path: the Pallas memo_attention variant
    gathers int8 tiles + scale slivers and dequantizes in VMEM."""
    eng, corpus = engine_factory(apm_codec="int8")
    toks = jnp.asarray(corpus.sample(4)[0])
    ref, _ = _select_logits(eng, toks)
    eng.mc.mode = "kernel"
    try:
        out, st = eng.infer({"tokens": toks})
    finally:
        eng.mc.mode = "bucket"
    assert st.n_layer_attempts == 4 * 2
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_int8_store_tracks_uncompressed_reference(engine_factory):
    """The gap to an UNcompressed store is codec error only: predictions
    agree and logits stay close (the documented tolerance)."""
    eng8, corpus = engine_factory(apm_codec="int8")
    eng16, _ = engine_factory(apm_codec="f16")
    toks = jnp.asarray(corpus.sample(16)[0])
    out8, st8 = eng8.infer({"tokens": toks})
    out16, st16 = eng16.infer({"tokens": toks})
    assert st8.n_hits == st16.n_hits           # index tier is uncompressed
    agree = (np.argmax(np.asarray(out8), -1)
             == np.argmax(np.asarray(out16), -1)).mean()
    assert agree >= 0.99
    assert np.max(np.abs(np.asarray(out8) - np.asarray(out16))) < 0.25


class _Counting:
    def __init__(self, real, counted):
        self._real = real
        self.counts = {name: 0 for name in counted}
        for name in counted:
            setattr(self, name, self._wrap(name))

    def _wrap(self, name):
        real_fn = getattr(self._real, name)

        def fn(*a, **k):
            self.counts[name] += 1
            return real_fn(*a, **k)
        return fn

    def __getattr__(self, name):
        return getattr(self._real, name)


def test_quantized_clustered_fast_path_one_barrier(engine_factory,
                                                   monkeypatch):
    """The ISSUE-3 acceptance invariant: int8 store + clustered device
    index still serve with exactly ONE host barrier per batch — the
    two-stage search and the fused dequant both trace inside the layer
    jit."""
    eng, corpus = engine_factory(apm_codec="int8", device_index="clustered",
                                 cluster_crossover=1)
    assert isinstance(eng.device_index, ClusteredDeviceIndex)
    toks = jnp.asarray(corpus.sample(8)[0])
    eng.infer({"tokens": toks})              # compile outside the count
    fake_jax = _Counting(jax, ["block_until_ready"])
    fake_np = _Counting(np, ["asarray", "nonzero"])
    monkeypatch.setattr(engine_mod, "jax", fake_jax)
    monkeypatch.setattr(engine_mod, "np", fake_np)
    _, st = eng.infer({"tokens": toks})
    assert fake_jax.counts["block_until_ready"] == 1
    assert fake_np.counts["asarray"] <= 2
    assert fake_np.counts["nonzero"] == 0
    assert st.n_layer_attempts == 8 * 2

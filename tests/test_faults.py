"""Failure model: fault injection, supervised maintenance, degradation
(ISSUE 6 / DESIGN.md §2.9).

Covers: FaultInjector trigger semantics and RuntimeSpec arming, arena
checksum quarantine (corrupt rows tombstoned before publication, no
hits on quarantined entries), eviction-policy output validation,
delta-sync failure atomicity, the supervised maintenance worker
(bounded retries with backoff, HEALTHY → DEGRADED → MEMO_DISABLED,
exact-attention logits parity in MEMO_DISABLED, ``recover()``),
maintenance-queue shedding under overflow, ``drain_maintenance``
timeout + worker liveness, and ``MemoSession.load`` failing with an
actionable ``MemoStoreError`` on truncated / bit-flipped /
spec-mismatched files (satellite).
"""
import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import MemoEngine, MemoStats
from repro.core.faults import (CHAOS_PRESETS, FAULT_POINTS, FaultInjector,
                               MemoStoreError, fire)
from repro.core.index import TOMBSTONE
from repro.core.runtime import Health, MemoMaintenanceError, MemoServer
from repro.core.store import MemoStore
from repro.memo import MemoSession, MemoSpec

SEQ = 32
APM_SHAPE = (2, 4, 4)
EMB_DIM = 8


# ------------------------------------------------------ injector semantics

def test_injector_every_and_count():
    inj = FaultInjector()
    inj.arm("store.sync_fail", every=2, count=2)
    hits = [inj.fire("store.sync_fail") is not None for _ in range(8)]
    # fires on probes 2 and 4, then the count cap holds
    assert hits == [False, True, False, True, False, False, False, False]
    assert inj.fired["store.sync_fail"] == 2
    assert inj.activations["store.sync_fail"] == 8


def test_injector_at_default_and_disarm():
    inj = FaultInjector()
    inj.arm("server.maint_crash")          # no trigger kwargs -> at=1
    assert inj.fire("server.maint_crash") is not None
    inj.disarm("server.maint_crash")
    assert inj.fire("server.maint_crash") is None
    # un-armed points never fire but still count activations
    assert inj.fire("store.corrupt_row") is None
    assert inj.activations["store.corrupt_row"] == 1


def test_injector_args_ride_along():
    inj = FaultInjector()
    inj.arm("server.maint_stall", at=1, stall_s=0.25)
    assert inj.fire("server.maint_stall") == {"stall_s": 0.25}


def test_injector_rejects_unknown_point():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultInjector().arm("bogus.point")


def test_from_spec_production_path_is_none():
    assert FaultInjector.from_spec(None) is None
    # and the site helper short-circuits without an injector
    assert fire(None, "store.sync_fail") is None
    inj = FaultInjector.from_spec({})
    assert inj is not None and not inj.armed("store.sync_fail")
    inj2 = FaultInjector.from_spec(CHAOS_PRESETS["corrupt_row"])
    assert inj2.armed("store.corrupt_row")


def test_runtime_spec_validates_fault_points():
    with pytest.raises(ValueError, match="fault point"):
        MemoSpec.flat(faults={"bogus.point": {}})
    spec = MemoSpec.flat(faults={"store.sync_fail": {"p": 0.5}})
    assert spec.runtime.faults == {"store.sync_fail": {"p": 0.5}}


# --------------------------------------------------------- store integrity

def _entries(rng, n):
    apms = rng.random((n, *APM_SHAPE)).astype(np.float16)
    embs = rng.normal(0, 0.01, (n, EMB_DIM)).astype(np.float32)
    embs[:, 0] += 10.0 * np.arange(1, n + 1)
    return apms, embs


def _mk_store(faults=None):
    return MemoStore(APM_SHAPE, EMB_DIM, capacity=4, faults=faults)


def test_corrupt_row_quarantined_before_publication():
    inj = FaultInjector()
    s = _mk_store(faults=inj)
    rng = np.random.default_rng(0)
    apms, embs = _entries(rng, 6)
    s.admit(apms[:4], embs[:4])
    inj.arm("store.corrupt_row", at=1, count=1)
    bad_slot = int(s.admit(apms[4:5], embs[4:5])[0])
    s.admit(apms[5:], embs[5:])
    # the sync integrity gate must catch the corrupt row and tombstone it
    s.sync()
    assert s.stats.n_quarantined == 1
    assert not s.db._live[bad_slot]
    assert np.all(s._embs_host[bad_slot] == TOMBSTONE)
    # lookups can never return the quarantined slot
    _, idx = s.lookup(embs, 1)
    assert bad_slot not in set(int(i) for i in idx[:, 0])
    # the survivors are intact and found
    _, idx5 = s.lookup(embs[5:], 1)
    assert s.db._live[int(idx5[0, 0])]


def test_verify_integrity_finds_manual_corruption():
    s = _mk_store()
    rng = np.random.default_rng(1)
    apms, embs = _entries(rng, 3)
    slots = s.admit(apms, embs)
    victim = int(slots[1])
    row = s.db._arenas[0][victim]
    row.view(np.uint8).reshape(-1)[0] ^= 0xFF
    quarantined = s.verify_integrity(quarantine=True)
    assert quarantined == [victim]
    assert s.stats.n_quarantined == 1
    assert s.verify_integrity() == []      # second sweep: clean


def test_evict_bogus_policy_output_is_rejected():
    inj = FaultInjector()
    s = _mk_store(faults=inj)
    rng = np.random.default_rng(2)
    apms, embs = _entries(rng, 5)
    s.admit(apms, embs)
    live_before = s.live_count
    inj.arm("store.evict_bogus", at=1, count=1)
    evicted = s.evict(2)
    # duplicate + out-of-range + dead slots were all refused; the store
    # still evicted valid entries and its invariants held
    assert s.stats.n_evict_rejected >= 1
    assert len(evicted) == len(set(evicted))
    assert all(0 <= sl < s.db._n for sl in evicted)
    assert s.live_count == live_before - len(evicted)


def test_sync_fail_raises_before_any_mutation_then_recovers():
    inj = FaultInjector()
    s = _mk_store(faults=inj)
    rng = np.random.default_rng(3)
    apms, embs = _entries(rng, 4)
    s.admit(apms, embs)
    gen = s.generation
    inj.arm("store.sync_fail", at=1, count=1)
    with pytest.raises(MemoStoreError, match="delta-sync"):
        s.sync()
    # nothing moved: the host tier is untouched and still dirty
    assert s.generation == gen
    assert s.device_stale
    s.sync()                                # injector spent -> clean
    assert not s.device_stale
    assert s.live_count == 4


# ----------------------------------------------------- supervised serving

@pytest.fixture(scope="module")
def fault_engine():
    from repro.configs import get_reduced
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256, n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, n_templates=6,
                            slot_fraction=0.2)
    spec = MemoSpec.flat(threshold=0.6, embed_steps=40, mode="bucket",
                         device_slack=8.0, admit=True, budget_mb=64.0,
                         faults={})
    eng = MemoEngine(m, params, spec)
    eng.build(jax.random.PRNGKey(1),
              [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)])
    assert eng.faults is not None           # faults={} arms nothing but
    assert eng.store._faults is eng.faults  # builds the shared injector
    return eng, corpus, m, params


@pytest.fixture()
def clean_faults(fault_engine):
    eng = fault_engine[0]
    eng.faults.disarm()
    eng.faults.reset()
    yield eng.faults
    eng.faults.disarm()
    eng.faults.reset()


def _make_server(eng, **kw):
    return MemoServer(eng, buckets=(SEQ,), max_batch=8, max_delay=1e-4,
                      **kw)


def _serve_some(srv, corpus, n=4):
    comps = []
    for _ in range(n):
        toks = corpus.sample(8)[0]
        for r in range(8):
            srv.submit(np.asarray(toks[r], np.int32))
        comps.extend(srv.step(flush=True))
    return comps


def test_healthy_serving_stays_healthy(fault_engine, clean_faults):
    eng, corpus, _, _ = fault_engine
    srv = _make_server(eng)
    try:
        comps = _serve_some(srv, corpus)
        srv.drain_maintenance(timeout=30)
        assert len(comps) == 32
        assert srv.health is Health.HEALTHY
        assert not srv.health_log           # no transitions at all
    finally:
        srv.close()


def test_maint_crash_disables_memo_and_serves_exact(fault_engine,
                                                    clean_faults):
    """Worker crashes exhaust retries -> DEGRADED -> MEMO_DISABLED; every
    request still completes, and MEMO_DISABLED logits bit-match the
    engine's no-memo path (acceptance: graceful degradation)."""
    eng, corpus, _, _ = fault_engine
    clean_faults.arm("server.maint_crash", p=1.0)
    srv = _make_server(eng, maint_retries=1, maint_backoff_s=0.005,
                       disable_after=2)
    try:
        comps = _serve_some(srv, corpus, n=6)
        assert len(comps) == 48             # zero dropped requests
        deadline = time.monotonic() + 10
        while (srv.health is not Health.MEMO_DISABLED
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert srv.health is Health.MEMO_DISABLED, srv.health_log
        # satellite: the maintenance error keeps its traceback and names
        # the payload generation it was applying
        e0 = srv.maintenance_errors[0]
        assert isinstance(e0, MemoMaintenanceError)
        assert e0.__cause__ is not None
        assert "generation" in str(e0) and "attempt" in str(e0)
        # exact-attention parity while disabled
        toks = corpus.sample(8)[0]
        for r in range(8):
            srv.submit(np.asarray(toks[r], np.int32))
        got = srv.step(flush=True)
        assert srv.n_exact_batches >= 1
        batch = {"tokens": jnp.asarray(np.asarray(toks, np.int32)),
                 "lengths": np.full(8, SEQ, np.int32), "n_valid": 8}
        ref = np.asarray(eng.infer(batch, stats=MemoStats(),
                                   use_memo=False)[0])
        for i, c in enumerate(got):
            assert np.array_equal(c.logits, ref[i]), f"row {i} differs"
        # recover(): back to HEALTHY, memo path serves hits again
        clean_faults.disarm()
        info = srv.recover()
        assert srv.health is Health.HEALTHY
        assert info["live_entries"] > 0
        hits_before = srv.stats.n_hits
        _serve_some(srv, corpus, n=2)
        srv.drain_maintenance(timeout=30)
        assert srv.health is Health.HEALTHY
        assert srv.stats.n_hits > hits_before
    finally:
        srv.close()


def test_transient_failure_is_retried_to_success(fault_engine,
                                                 clean_faults):
    eng, corpus, _, _ = fault_engine
    clean_faults.arm("store.sync_fail", p=1.0, count=1)
    srv = _make_server(eng, maint_retries=2, maint_backoff_s=0.005)
    try:
        _serve_some(srv, corpus, n=2)
        srv.drain_maintenance(timeout=30)
        assert srv.health is Health.HEALTHY, srv.health_log
        assert srv.n_maint_retries >= 1
        assert srv.maintenance_errors == []
    finally:
        srv.close()


def test_queue_overflow_sheds_payload_not_requests(fault_engine,
                                                   clean_faults):
    eng, corpus, _, _ = fault_engine
    clean_faults.arm("server.queue_overflow", p=1.0)
    srv = _make_server(eng, maint_put_timeout=0.01)
    try:
        comps = _serve_some(srv, corpus, n=3)
        assert len(comps) == 24             # every request answered
        assert srv.n_maint_shed >= 1
        assert srv.health is Health.DEGRADED
        clean_faults.disarm()
        srv.recover()
        assert srv.health is Health.HEALTHY
    finally:
        srv.close()


def test_drain_timeout_and_stall_watchdog(fault_engine, clean_faults):
    eng, corpus, _, _ = fault_engine
    clean_faults.arm("server.maint_stall", p=1.0, stall_s=0.3)
    srv = _make_server(eng, watchdog_s=0.05, maint_retries=0)
    try:
        _serve_some(srv, corpus, n=2)
        with pytest.raises(TimeoutError, match="timed out"):
            srv.drain_maintenance(timeout=0.01)
        clean_faults.disarm()
        srv.drain_maintenance(timeout=30)   # stall passes, then drains
    finally:
        srv.close()


def test_drain_raises_on_dead_worker_with_pending_payloads(fault_engine,
                                                           clean_faults):
    eng, corpus, _, _ = fault_engine
    srv = _make_server(eng)
    try:
        _serve_some(srv, corpus, n=1)
        srv.drain_maintenance(timeout=30)
        # simulate a hard worker death with work still queued
        srv._maint_q.put(object())
        w = srv._worker
        srv._worker = None
        with pytest.raises(MemoMaintenanceError, match="not alive"):
            srv.drain_maintenance(timeout=5)
        srv._worker = w
        srv._maint_q.get()
        srv._maint_q.task_done()
    finally:
        srv.close()


# --------------------------------------------- session persistence faults

@pytest.fixture(scope="module")
def saved_store(fault_engine, tmp_path_factory):
    eng, _, m, params = fault_engine
    eng.faults.disarm()
    path = str(tmp_path_factory.mktemp("faults") / "store.npz")
    MemoSession(eng).save(path)
    return path, m, params


def test_load_roundtrip(saved_store):
    path, m, params = saved_store
    sess = MemoSession.load(path, m, params)
    assert sess.store.live_count > 0


def test_load_rejects_truncated_file(saved_store, tmp_path):
    path, m, params = saved_store
    torn = str(tmp_path / "torn.npz")
    shutil.copy(path, torn)
    with open(torn, "rb+") as f:
        f.truncate(os.path.getsize(torn) // 2)
    with pytest.raises(MemoStoreError, match="truncated or corrupt"):
        MemoSession.load(torn, m, params)


def test_load_rejects_bitflip_on_disk(saved_store, tmp_path):
    path, m, params = saved_store
    flipped = str(tmp_path / "flip.npz")
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(flipped, "wb").write(bytes(data))
    with pytest.raises(MemoStoreError):
        MemoSession.load(flipped, m, params)


def test_load_bitflip_fault_point_hits_checksum_gate(saved_store):
    path, m, params = saved_store
    inj = FaultInjector()
    inj.arm("session.load_bitflip", at=1, count=1)
    with pytest.raises(MemoStoreError, match="checksum mismatch"):
        MemoSession.load(path, m, params, faults=inj)
    # the injector is spent: the same file loads cleanly afterwards
    sess = MemoSession.load(path, m, params, faults=inj)
    assert sess.store.live_count > 0


def test_save_truncate_fault_produces_torn_write(fault_engine,
                                                 clean_faults, tmp_path):
    eng, _, m, params = fault_engine
    clean_faults.arm("session.save_truncate", at=1, count=1)
    torn = str(tmp_path / "torn.npz")
    MemoSession(eng).save(torn)
    with pytest.raises(MemoStoreError, match="truncated or corrupt"):
        MemoSession.load(torn, m, params)


@pytest.mark.parametrize("save_format", [2, 3])
def test_torn_save_never_clobbers_existing_file(fault_engine, clean_faults,
                                                tmp_path, save_format):
    """Atomic save: the crash window between temp write and publish
    (session.save_truncate) must leave a previously saved GOOD file
    loadable — saves go through temp + fsync + os.replace, never
    in-place."""
    eng, _, m, params = fault_engine
    clean_faults.disarm()
    sess = MemoSession(eng)
    path = str(tmp_path / f"good_{save_format}.bin")
    sess.save(path, save_format=save_format)
    before = open(path, "rb").read()
    clean_faults.arm("session.save_truncate", at=1, count=1)
    sess.save(path, save_format=save_format)       # torn re-save
    assert open(path, "rb").read() == before       # old bytes intact
    loaded = MemoSession.load(path, m, params)
    assert loaded.store.live_count == sess.store.live_count


def _rewrite_meta(path, out, mutate):
    from repro.core.capacity import is_format3, read_format3, write_format3
    if is_format3(path):
        meta, arrays = read_format3(path)
        mutate(meta)
        write_format3(out, meta, arrays)
        return
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["meta"]))
        arrays = {k: data[k] for k in data.files if k != "meta"}
    mutate(meta)
    with open(out, "wb") as f:
        np.savez_compressed(f, meta=json.dumps(meta), **arrays)


def test_load_rejects_spec_mismatch(saved_store, tmp_path):
    path, m, params = saved_store
    bad = str(tmp_path / "mismatch.npz")
    _rewrite_meta(path, bad,
                  lambda meta: meta["spec"]["embed"].update(dim=999))
    with pytest.raises(MemoStoreError, match="saved under a different"):
        MemoSession.load(bad, m, params)


def test_load_rejects_unknown_format(saved_store, tmp_path):
    path, m, params = saved_store
    bad = str(tmp_path / "fmt.npz")
    _rewrite_meta(path, bad, lambda meta: meta.update(format=999))
    with pytest.raises(MemoStoreError, match="format"):
        MemoSession.load(bad, m, params)

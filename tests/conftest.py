"""Test scaffolding.

The container may lack ``hypothesis``; property tests only use a tiny
slice of its API (``given`` / ``settings`` / three strategies), so when
the real package is missing we register a deterministic shim in
``sys.modules`` before collection. Seeded sampling keeps the property
tests meaningful (many examples per test) and reproducible.
"""
from __future__ import annotations

import sys
import types


def _install_hypothesis_shim():
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def floats(lo, hi, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def sampled_from(xs):
        xs = list(xs)
        return _Strategy(lambda rng: xs[int(rng.integers(0, len(xs)))])

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            import inspect

            # parameters NOT drawn from strategies (pytest.mark.parametrize
            # / fixtures) pass straight through; pytest must see exactly
            # those in the signature — not the strategy names, hence the
            # exec-built wrapper instead of functools.wraps
            passthrough = [p for p in inspect.signature(fn).parameters
                           if p not in strats]

            def body(*args):
                # read max_examples lazily: @settings usually sits ABOVE
                # @given, so it decorates (and tags) this wrapper
                n = getattr(wrapper, "_shim_max_examples", 10)
                rng = np.random.default_rng(0)
                kw = dict(zip(passthrough, args))
                for _ in range(n):
                    fn(**kw, **{k: s.draw(rng) for k, s in strats.items()})

            if passthrough:
                ns = {"body": body}
                argstr = ", ".join(passthrough)
                exec(f"def wrapper({argstr}):\n    return body({argstr})", ns)
                wrapper = ns["wrapper"]
            else:
                def wrapper():
                    return body()
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    hyp = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - exercised implicitly at collection time
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()

"""Sharding rules: spec construction, divisibility legalization, conflicts,
the memo-store row rules (ISSUE 9) and the decode-cache B=1 branch."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import abstract_mesh, make_host_mesh
from repro.sharding.rules import (_spec_for, cache_shardings,
                                  logical_to_shardings, make_rules,
                                  memo_row_spec, memo_store_rules,
                                  memo_store_shardings)


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def _mesh16():
    # abstract Mesh for rule math; no devices needed beyond host
    return abstract_mesh(data=16, model=16)


def test_spec_basic(mesh):
    m = _mesh16()
    rules = {"embed": None, "ff": "model", "experts": "data"}
    assert _spec_for(("embed", "ff"), rules, m) == P(None, "model")
    assert _spec_for(("experts", "embed", "ff"), rules, m) == \
        P("data", None, "model")


def test_spec_conflict_same_axis(mesh):
    """A mesh axis may appear at most once per spec."""
    m = _mesh16()
    rules = {"a": "model", "b": "model"}
    assert _spec_for(("a", "b"), rules, m) == P("model")


def test_spec_divisibility_legalization():
    m = _mesh16()
    rules = {"vocab": "model", "embed": None}
    # 73448 % 16 != 0 -> vocab axis dropped (minicpm3's actual vocab)
    assert _spec_for(("vocab", "embed"), rules, m,
                     shape=(73448, 2560)) == P()
    assert _spec_for(("vocab", "embed"), rules, m,
                     shape=(73728, 2560)) == P("model")


def test_rules_for_every_arch_produce_valid_shardings():
    """Every arch's spec tree maps to shardings whose sharded dims divide."""
    m = _mesh16()
    from repro.launch.steps import abstract_params
    from repro.models import build_model
    for arch in ("qwen3_8b", "rwkv6_3b", "recurrentgemma_2b", "dbrx_132b",
                 "whisper_medium", "minicpm3_4b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        params_abs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), dtype=jnp.bfloat16))
        rules = make_rules(cfg, m)
        sh = logical_to_shardings(model.specs(), rules, m, params_abs)

        def check(s, ab):
            spec = s.spec
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= m.shape[a]
                assert ab.shape[i] % size == 0, (arch, ab.shape, spec)
        jax.tree.map(check, sh, params_abs)


def test_fsdp_threshold():
    m = _mesh16()
    small = get_config("qwen2_1_5b")
    big = get_config("chameleon_34b")
    assert make_rules(small, m)["embed"] is None
    assert make_rules(big, m)["embed"] == "data"


def test_rules_overrides():
    m = _mesh16()
    cfg = get_config("qwen3_8b")
    r = make_rules(cfg, m, overrides={"ff": ("data", "model")})
    assert r["ff"] == ("data", "model")


def _store_mesh8():
    return abstract_mesh(store=8)


def test_memo_store_rules_names_and_axis():
    r = memo_store_rules("store")
    assert r == {"memo_rows": "store", "memo_part": None,
                 "memo_repl": None}
    assert memo_store_rules("tier")["memo_rows"] == "tier"


def test_memo_row_spec_shards_rows_legalizes_indivisible():
    m = _store_mesh8()
    # 64 rows over 8 shards: dim 0 sharded, trailing dims replicated
    assert memo_row_spec(m, 3, shape=(64, 4, 4)) == P("store")
    assert memo_row_spec(m, 1, shape=(64,)) == P("store")
    # 60 % 8 != 0 -> the row axis legalizes to replicated, not a pjit
    # error (same `_spec_for` divisibility contract as model params)
    assert memo_row_spec(m, 2, shape=(60, 4)) == P()
    # no shape: trust the caller (ShardedMemoStore sizes M * n_shards)
    assert memo_row_spec(m, 2) == P("store")


def test_memo_store_shardings_tree():
    m = _store_mesh8()
    tree = {
        "table": jax.ShapeDtypeStruct((64, 16), jnp.float32),
        "slot_at": jax.ShapeDtypeStruct((64,), jnp.int32),
        "odd": jax.ShapeDtypeStruct((9, 16), jnp.float32),
    }
    sh = memo_store_shardings(m, tree, axis="store")
    assert sh["table"].spec == P("store")
    assert sh["slot_at"].spec == P("store")
    assert sh["odd"].spec == P()          # 9 % 8 != 0: replicated


def test_cache_shardings_b1_long_context():
    """B=1 decode caches spread the sequence axis over (data, model)
    when it divides the full product, over model alone when only that
    divides, else replicate."""
    m = _mesh16()                          # data=16, model=16 -> 256
    def spec(B, S):
        t = jnp.zeros((B, S, 2, 4))
        return cache_shardings(
            jax.eval_shape(lambda: t), m)  # ShapeDtypeStruct tree
    assert spec(1, 512).spec == P(None, ("data", "model"), None, None)
    assert spec(1, 48).spec == P(None, "model", None, None)  # 48 % 16 == 0
    assert spec(1, 50).spec == P()         # divides neither
    # divisible batch: dp over data, seq over model
    assert spec(16, 512).spec == P("data", "model", None, None)
    # rank-1 leaves replicate
    one_d = cache_shardings(jax.eval_shape(lambda: jnp.zeros((7,))), m)
    assert one_d.spec == P()

"""Sharding rules: spec construction, divisibility legalization, conflicts."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import _spec_for, logical_to_shardings, make_rules


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def _mesh16():
    # abstract Mesh for rule math; no devices needed beyond host
    import numpy as np
    from jax.sharding import AbstractMesh
    return AbstractMesh((16, 16), ("data", "model"))


def test_spec_basic(mesh):
    m = _mesh16()
    rules = {"embed": None, "ff": "model", "experts": "data"}
    assert _spec_for(("embed", "ff"), rules, m) == P(None, "model")
    assert _spec_for(("experts", "embed", "ff"), rules, m) == \
        P("data", None, "model")


def test_spec_conflict_same_axis(mesh):
    """A mesh axis may appear at most once per spec."""
    m = _mesh16()
    rules = {"a": "model", "b": "model"}
    assert _spec_for(("a", "b"), rules, m) == P("model")


def test_spec_divisibility_legalization():
    m = _mesh16()
    rules = {"vocab": "model", "embed": None}
    # 73448 % 16 != 0 -> vocab axis dropped (minicpm3's actual vocab)
    assert _spec_for(("vocab", "embed"), rules, m,
                     shape=(73448, 2560)) == P()
    assert _spec_for(("vocab", "embed"), rules, m,
                     shape=(73728, 2560)) == P("model")


def test_rules_for_every_arch_produce_valid_shardings():
    """Every arch's spec tree maps to shardings whose sharded dims divide."""
    m = _mesh16()
    from repro.launch.steps import abstract_params
    from repro.models import build_model
    for arch in ("qwen3_8b", "rwkv6_3b", "recurrentgemma_2b", "dbrx_132b",
                 "whisper_medium", "minicpm3_4b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        params_abs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), dtype=jnp.bfloat16))
        rules = make_rules(cfg, m)
        sh = logical_to_shardings(model.specs(), rules, m, params_abs)

        def check(s, ab):
            spec = s.spec
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= m.shape[a]
                assert ab.shape[i] % size == 0, (arch, ab.shape, spec)
        jax.tree.map(check, sh, params_abs)


def test_fsdp_threshold():
    m = _mesh16()
    small = get_config("qwen2_1_5b")
    big = get_config("chameleon_34b")
    assert make_rules(small, m)["embed"] is None
    assert make_rules(big, m)["embed"] == "data"


def test_rules_overrides():
    m = _mesh16()
    cfg = get_config("qwen3_8b")
    r = make_rules(cfg, m, overrides={"ff": ("data", "model")})
    assert r["ff"] == ("data", "model")

"""Sharded memo store (ISSUE 9 / DESIGN.md §2.12).

Covers: the ONE-collective-per-batch invariant in meshed mode (trace
counted by patching ``shard._ALL_GATHER``), top-1 + payload parity with
the admitted entries, per-shard generation publish, the replicated hot
set absorbing centroid-routing masks, shard-local eviction/spill
bookkeeping, the host-index guard, engine-level logits parity vs the
select reference, and the real 8-way mesh in a subprocess (device count
locks at first jax init, so the in-process tests run the same code on
the clamped 1-shard mesh and the subprocess runs S=8).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.shard as shard
from repro.core.faults import MemoStoreError
from repro.core.shard import ShardedMemoStore, ShardSnapshot

APM = (2, 4, 4)
DIM = 8
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _entries(rng, n):
    """n unique, well-separated entries (same recipe as test_store)."""
    apms = rng.random((n, *APM)).astype(np.float16)
    embs = rng.normal(0, 0.01, (n, DIM)).astype(np.float32)
    embs[:, 0] += 10.0 * np.arange(1, n + 1)
    return apms, embs


def _mk(n_shards=1, **kw):
    kw.setdefault("index_kind", "exact")
    kw.setdefault("codec", "f16")
    kw.setdefault("capacity", 8)
    return ShardedMemoStore(APM, DIM, n_shards=n_shards, **kw)


# ------------------------------------------------------------- guards

def test_rejects_host_device_index_kind():
    """The sharded store owns the device layout; a single-host 'device'
    host index would duplicate the table unsharded."""
    with pytest.raises(MemoStoreError, match="single-host"):
        _mk(index_kind="device")


# ------------------------------------------------- search + collectives

def test_top1_parity_and_fetched_payload():
    """Every admitted entry finds ITSELF (global slot id through the
    combine) and ``search_fetch`` returns the winner's own codec rows —
    the engine never re-gathers from the sharded arenas."""
    rng = np.random.default_rng(0)
    s = _mk()
    apms, embs = _entries(rng, 12)
    slots = s.admit(apms, embs)
    s.sync(force_full=True)
    di = s.device_index
    d2, got, rows = di.search_fetch(jnp.asarray(embs), args=di.search_args,
                                    parts=s.device_db.parts)
    np.testing.assert_array_equal(np.asarray(got)[:, 0], slots)
    assert np.all(np.asarray(d2)[:, 0] < 0.1)
    dec = np.asarray(s.codec.decode_rows(rows), np.float32)
    np.testing.assert_allclose(dec, np.asarray(apms, np.float32),
                               atol=1e-3, rtol=0)
    # host-compat API agrees (L2, not squared)
    _, idx = di.search(embs)
    np.testing.assert_array_equal(idx[:, 0], slots)


def test_search_fetch_traces_exactly_one_collective(monkeypatch):
    """The sharded search+fetch — distances, slot ids AND codec rows —
    must combine through ONE all_gather (acceptance criterion, ISSUE 9):
    the one-barrier-per-batch invariant from the single-host fast path
    holds in meshed mode. Counted at trace time via the module-level
    ``_ALL_GATHER`` indirection every combine routes through."""
    rng = np.random.default_rng(1)
    s = _mk()
    apms, embs = _entries(rng, 8)
    s.admit(apms, embs)
    s.sync(force_full=True)
    calls = []
    real = shard._ALL_GATHER

    def counting(*a, **k):
        calls.append(a)
        return real(*a, **k)

    monkeypatch.setattr(shard, "_ALL_GATHER", counting)
    di = s.device_index
    di.search_fetch(jnp.asarray(embs), args=di.search_args,
                    parts=s.device_db.parts)
    assert len(calls) == 1
    # the rows ride the same gather: its payload is a pytree, not a
    # second collective per codec part
    calls.clear()
    di.search_device(jnp.asarray(embs))
    assert len(calls) == 1


# -------------------------------------------------- publish + snapshots

def test_publish_carries_per_shard_snapshots():
    rng = np.random.default_rng(2)
    s = _mk()
    apms, embs = _entries(rng, 6)
    s.admit(apms, embs)
    s.sync(force_full=True)
    s.publish()
    snaps = s.shard_snapshots
    assert len(snaps) == s.n_shards
    assert all(isinstance(x, ShardSnapshot) for x in snaps)
    assert sum(x.live for x in snaps) == 6
    occ = s.shard_occupancy()
    assert occ.sum() == 6
    st = s.shard_stats()
    assert st["n_shards"] == s.n_shards
    assert sum(st["occupancy"]) == 6
    assert st["imbalance"] >= 1.0
    assert s.per_shard_budget_bytes == s._pos_per_shard * s.entry_nbytes


def test_delta_sync_bumps_touched_generations():
    rng = np.random.default_rng(3)
    s = _mk()
    apms, embs = _entries(rng, 6)
    s.admit(apms, embs)
    s.sync(force_full=True)
    s.publish()
    g0 = [x.generation for x in s.shard_snapshots]
    a2, e2 = _entries(rng, 2)
    e2[:, 0] += 200.0
    s.admit(a2, e2)
    s.sync()                       # delta: 2 dirty slots route + ship
    s.publish()
    g1 = [x.generation for x in s.shard_snapshots]
    assert any(b > a for a, b in zip(g0, g1))
    assert sum(x.live for x in s.shard_snapshots) == 8


# -------------------------------------------------------------- hot set

def test_hot_set_absorbs_routing_mask():
    """A query masked away from the shard owning its nearest entry is
    still served when that entry is in the replicated hot set: score the
    index with centroids that route EVERY query to a far-off region, so
    only the hot scores can win."""
    rng = np.random.default_rng(4)
    s = _mk(hot_k=2, route_nprobe=1)
    apms, embs = _entries(rng, 8)
    slots = s.admit(apms, embs)
    s.sync(force_full=True)
    di = s.device_index
    # route everything toward a centroid far from every entry; with
    # nprobe=1 a shard only competes for queries probing its centroid
    far = np.full((1, DIM), 1e6, np.float32)
    di.set_centroids(far, np.zeros((1,), np.int32))
    # make slot[3] hot: every shard scores the replicated hot rows
    hot = 3
    table = np.full((max(1, di.hot_k), DIM), shard.TOMBSTONE, np.float32)
    hslots = np.full((max(1, di.hot_k),), -1, np.int32)
    parts = [np.zeros((max(1, di.hot_k),) + p.shape, p.dtype)
             for p in s.codec.parts]
    table[0] = embs[hot]
    hslots[0] = slots[hot]
    rows = s.db.parts_at(np.asarray([slots[hot]]))
    for dst, src in zip(parts, rows):
        dst[0] = src[0]
    di.set_hot(table, hslots, tuple(parts))
    d2, idx = di.search_device(jnp.asarray(embs[hot][None]))
    assert int(np.asarray(idx)[0, 0]) == int(slots[hot])
    assert float(np.asarray(d2)[0, 0]) < 0.1


def test_sync_refreshes_hot_set_by_reuse():
    """The maintenance sync ships the top reuse-count rows as the hot
    set, in fixed-H arrays (no consumer retrace across refreshes)."""
    rng = np.random.default_rng(5)
    s = _mk(hot_k=2)
    apms, embs = _entries(rng, 6)
    slots = s.admit(apms, embs)
    s.sync(force_full=True)
    di = s.device_index
    shape0 = (di._hot_table.shape, di._hot_slots.shape)
    s.db.get(np.asarray([slots[4], slots[4], slots[4], slots[1]]))
    s.admit(*_entries(np.random.default_rng(6), 1))  # dirty -> delta sync
    s.sync()
    hs = set(int(x) for x in np.asarray(di._hot_slots))
    assert int(slots[4]) in hs
    assert (di._hot_table.shape, di._hot_slots.shape) == shape0


# ------------------------------------------------------- engine parity

@pytest.fixture(scope="module")
def sharded_engine():
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.data import TemplateCorpus
    from repro.memo import MemoSpec
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256,
                                           n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, n_templates=6,
                            slot_fraction=0.2)
    eng = MemoEngine(m, params, MemoSpec.flat(
        threshold=0.6, embed_steps=40, mode="bucket", shards=1,
        shard_hot=8))
    batches = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)]
    eng.build(jax.random.PRNGKey(1), batches)
    return eng, corpus


def test_engine_builds_sharded_store_from_spec(sharded_engine):
    eng, _ = sharded_engine
    assert isinstance(eng.store, ShardedMemoStore)
    assert eng.store.hot_k == 8
    assert getattr(eng.store.device_index, "is_sharded", False)


@pytest.mark.parametrize("thr", [-1e9, 0.6, 1e9])
def test_engine_sharded_matches_select(sharded_engine, thr):
    """Memoized serving through the sharded tier == the select reference
    across all-hit / mixed / all-miss thresholds (acceptance criterion,
    ISSUE 9: logits matching select parity)."""
    eng, corpus = sharded_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    eng.mc.mode = "select"
    try:
        ref, _ = eng.infer({"tokens": toks}, threshold=thr)
    finally:
        eng.mc.mode = "bucket"
    out, st = eng.infer({"tokens": toks}, threshold=thr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    if thr == -1e9:
        assert st.memo_rate == 1.0
    if thr == 1e9:
        assert st.memo_rate == 0.0


# ---------------------------------- centroid refresh (ISSUE 10 satellite)

def test_centroid_refresh_trigger_and_fixed_shapes():
    """Routing-drift repair between full syncs (ROADMAP item 1): once
    the spill counter crosses ``refresh_spills``, the NEXT delta sync
    refits centroids from the resident embeddings in place — fixed
    centroid count (no search_args retrace), no row movement, counter
    reset — and routed search still resolves every entry. Pressure
    itself needs a full preferred shard while others have room, which
    the clamped 1-shard mesh cannot produce; the 8-way subprocess test
    drives that end-to-end, so here the drift clock is primed directly
    to pin down the trigger + refresh mechanics."""
    rng = np.random.default_rng(5)
    s = _mk(refresh_spills=2)
    apms, embs = _entries(rng, 10)
    slots = s.admit(apms, embs)
    s.sync(force_full=True)
    shape0 = s._centroids_host.shape
    assert s.n_centroid_refreshes == 0
    pos0 = dict(s._slot_pos)
    s._spills_since_refresh = 2          # primed past the threshold
    a2, e2 = _entries(rng, 2)
    e2[:, 0] += 120.0                    # clear of the first batch
    new = s.admit(a2, e2)
    s.sync()
    assert s.n_centroid_refreshes == 1
    assert s._spills_since_refresh == 0  # fresh fit restarts the clock
    assert s.shard_stats()["n_centroid_refreshes"] == 1
    # the refresh ships only the tiny replicated routing state: the
    # centroid table keeps its shape and no resident row moved
    assert s._centroids_host.shape == shape0
    assert all(s._slot_pos.get(k) == v for k, v in pos0.items()
               if k in s._slot_pos)
    q = np.concatenate([embs, e2])
    _, idx = s.device_index.search(q)
    np.testing.assert_array_equal(idx[:, 0], np.concatenate([slots, new]))
    # a full sync refits from scratch and restarts the drift clock
    s._spills_since_refresh = 1
    s.sync(force_full=True)
    assert s._spills_since_refresh == 0
    assert s.n_centroid_refreshes == 1   # full sync is not a "refresh"


def test_centroid_refresh_disabled_by_default():
    """``refresh_spills=0`` (the default) never refreshes between full
    syncs no matter how much placement pressure accumulates."""
    rng = np.random.default_rng(6)
    s = _mk()
    assert s.refresh_spills == 0
    apms, embs = _entries(rng, 6)
    s.admit(apms, embs)
    s.sync(force_full=True)
    s._spills_since_refresh = 10 ** 6
    a2, e2 = _entries(rng, 2)
    e2[:, 0] += 120.0
    s.admit(a2, e2)
    s.sync()
    assert s.n_centroid_refreshes == 0
    assert s.shard_stats()["n_centroid_refreshes"] == 0


# ---------------------------------------------------------- 8-way mesh

_MESH8_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
import repro.core.shard as shard
from repro.core.shard import ShardedMemoStore

APM, DIM, N = (2, 4, 4), 8, 96
rng = np.random.default_rng(0)
apms = rng.random((N, *APM)).astype(np.float16)
embs = rng.normal(0, 0.01, (N, DIM)).astype(np.float32)
embs[:, 0] += 10.0 * np.arange(1, N + 1)

s = ShardedMemoStore(APM, DIM, n_shards=8, capacity=16, hot_k=4,
                     route_nprobe=2, index_kind="exact", codec="f16",
                     refresh_spills=6)
assert s.n_shards == 8, s.n_shards
slots = s.admit(apms, embs)
s.sync(force_full=True)
C0 = s._centroids_host.shape[0]
st = s.shard_stats()
occ = np.asarray(st["occupancy"])
assert occ.sum() == N, occ
assert (occ > 0).all(), occ                      # every shard holds rows
assert st["imbalance"] <= 2.0, st
# parity under ACTIVE routing masks: nprobe=2 of >=8 centroids means
# most shards submit +inf for any query, yet every entry finds itself
di = s.device_index
d2, idx, rows = di.search_fetch(jnp.asarray(embs), args=di.search_args,
                                parts=s.device_db.parts)
assert (np.asarray(idx)[:, 0] == slots).all()
assert np.asarray(d2).max() < 0.1
dec = np.asarray(s.codec.decode_rows(rows), np.float32)
np.testing.assert_allclose(dec, np.asarray(apms, np.float32), atol=1e-3)
# ONE cross-shard collective on the REAL 8-way mesh
calls = []
real = shard._ALL_GATHER
shard._ALL_GATHER = lambda *a, **k: (calls.append(a) or real(*a, **k))
di.search_fetch(jnp.asarray(embs[:8]), args=di.search_args,
                parts=s.device_db.parts)
shard._ALL_GATHER = real
assert len(calls) == 1, len(calls)
# delta sync touches only the routed shards' generations
s.publish()
g0 = np.asarray([x.generation for x in s.shard_snapshots])
a2, e2 = apms[:3].copy(), embs[:3].copy()
e2[:, 0] += 0.05                                  # near existing entries
s.admit(a2, e2)
s.sync()
s.publish()
g1 = np.asarray([x.generation for x in s.shard_snapshots])
bumped = int((g1 > g0).sum())
assert 1 <= bumped < 8, (g0.tolist(), g1.tolist())
# skewed burst at one centroid region: the target shard runs out of
# free positions -> shard-local CLOCK eviction and/or spill
burst = 40
ab = rng.random((burst, *APM)).astype(np.float16)
eb = rng.normal(0, 0.01, (burst, DIM)).astype(np.float32)
eb[:, 0] += 10.0                                  # all near entry 1
s.admit(ab, eb)
s.sync()
assert s.n_shard_evictions + s.n_spills > 0, \
    (s.n_shard_evictions, s.n_spills)
occ2 = s.shard_occupancy()
live = int(s.db.live_mask[: len(s.db)].sum())
assert occ2.sum() == live, (occ2.tolist(), live)
# the same pressure is the drift signal: it crossed refresh_spills=6,
# so a delta-sync centroid refresh re-fit routing to the RESIDENT
# distribution (fixed C — no search_args retrace) without moving rows
assert s.n_centroid_refreshes >= 1, s._spills_since_refresh
assert s.shard_stats()["n_centroid_refreshes"] == s.n_centroid_refreshes
assert s._centroids_host.shape[0] == C0, (s._centroids_host.shape, C0)
d3, idx3 = s.device_index.search(eb[:8])   # post-refresh routing works
assert np.asarray(d3)[:, 0].max() < 1.0, np.asarray(d3)[:, 0]
print("SHARD8-OK", st["imbalance"], bumped, s.n_shard_evictions,
      s.n_spills, s.n_centroid_refreshes)
"""


def test_eight_way_mesh_subprocess():
    """The full sharded tier on a real 8-device mesh: balanced
    occupancy, routed-search parity, one collective, selective
    generation bumps, shard-local eviction under skew."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _MESH8_CODE],
                         capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=560)
    assert "SHARD8-OK" in out.stdout, out.stderr[-3000:]

"""Device-resident serving fast path (ISSUE 1 / DESIGN.md §2).

Covers: zero per-layer host synchronization on the bucket/kernel hot
paths, bucket-mode edge cases vs the select reference, DeviceIndex
parity with the host ExactIndex, and AttentionDB capacity accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core.database import AttentionDB
from repro.core.index import DeviceIndex, ExactIndex


@pytest.fixture(scope="module")
def fast_engine():
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256, n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, n_templates=6,
                            slot_fraction=0.2)
    eng = MemoEngine(m, params, MemoSpec.flat(threshold=0.6, embed_steps=40,
                                           mode="bucket"))
    batches = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)]
    eng.build(jax.random.PRNGKey(1), batches)
    return eng, corpus


class _CountingModule:
    """Delegating stand-in for a module that counts specific attrs."""

    def __init__(self, real, counted):
        self._real = real
        self.counts = {name: 0 for name in counted}
        for name in counted:
            setattr(self, name, self._wrap(name))

    def _wrap(self, name):
        real_fn = getattr(self._real, name)

        def fn(*a, **k):
            self.counts[name] += 1
            return real_fn(*a, **k)
        return fn

    def __getattr__(self, name):
        return getattr(self._real, name)


@pytest.mark.parametrize("mode", ["bucket", "kernel"])
def test_fast_path_zero_per_layer_host_sync(fast_engine, monkeypatch, mode):
    """The whole forward must issue exactly ONE block_until_ready (the
    trailing barrier) and at most the one-shot stats materialization —
    independent of layer count (acceptance criterion, ISSUE 1). The
    engine default is the int8 APM codec (ISSUE 3), so this also pins
    the QUANTIZED fast path: on-device dequant must not add host syncs
    (the clustered-index variant is pinned in tests/test_codec.py)."""
    eng, corpus = fast_engine
    assert eng.store.codec.name == "int8"
    eng.mc.mode = mode
    try:
        toks = jnp.asarray(corpus.sample(8)[0])
        eng.infer({"tokens": toks})          # compile outside the count
        fake_jax = _CountingModule(jax, ["block_until_ready"])
        fake_np = _CountingModule(np, ["asarray", "nonzero"])
        monkeypatch.setattr(engine_mod, "jax", fake_jax)
        monkeypatch.setattr(engine_mod, "np", fake_np)
        _, st = eng.infer({"tokens": toks})
        assert fake_jax.counts["block_until_ready"] == 1
        # stats drain: two stacked transfers per batch, not per layer
        assert fake_np.counts["asarray"] <= 2
        assert fake_np.counts["nonzero"] == 0
        assert st.n_layer_attempts == 8 * 2      # stats still collected
        assert st.t_total > 0.0
    finally:
        eng.mc.mode = "bucket"


def test_kernel_mode_single_pallas_dispatch(fast_engine, monkeypatch):
    """Kernel mode is ONE fused Pallas dispatch per memoized layer
    (acceptance criterion, ISSUE 7): with the search prologue forced to
    its one-matmul form (``fused=True``), tracing the serving layer must
    construct exactly one pallas_call — memo_attention — and nothing
    else (no separate nn_search kernel, no gather kernel). Counted at
    trace time by patching the ``pl`` module both kernel packages
    share; both fixture layers reuse one jit entry, so one trace total."""
    import repro.kernels.memo_attention.kernel as mk
    eng, corpus = fast_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    old_cache, old_mode = eng._jit_cache, eng.mc.mode
    eng.mc.mode = "kernel"
    eng.mc.kernel_impl = "pallas"        # pin the kernel (CPU would pick xla)
    try:
        eng._jit_cache = {}              # force a fresh trace under the patch
        calls = []
        real = mk.pl.pallas_call

        def counting(*a, **k):
            calls.append(a)
            return real(*a, **k)

        monkeypatch.setattr(mk.pl, "pallas_call", counting)
        out, st = eng.infer({"tokens": toks})
        assert len(calls) == 1
        assert np.isfinite(np.asarray(out)).all()
        assert st.n_layer_attempts == 8 * 2
    finally:
        eng._jit_cache = old_cache
        eng.mc.mode = old_mode
        eng.mc.kernel_impl = None


def test_kernel_mode_varlen_matches_select(fast_engine):
    """Variable-length batches serve through kernel mode (the lengths
    operand masks padded keys per sequence) and match the select
    reference; the length gate still forces misses for lengths with no
    same-length entry."""
    eng, corpus = fast_engine
    toks = np.asarray(corpus.sample(6)[0])
    lens = np.asarray([32, 32, 24, 17, 32, 24], np.int32)
    for i, ln in enumerate(lens):
        toks[i, ln:] = 0
    batch = {"tokens": jnp.asarray(toks), "lengths": lens}
    for thr in (-1e9, 0.6, 1e9):
        eng.mc.mode = "select"
        try:
            ref_vl, _ = eng.infer(batch, threshold=thr)
        finally:
            eng.mc.mode = "bucket"
        eng.mc.mode = "kernel"
        try:
            out, st = eng.infer(batch, threshold=thr)
        finally:
            eng.mc.mode = "bucket"
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_vl),
                                   rtol=2e-3, atol=2e-3)
        if thr == -1e9:
            # only full-length rows can pass the length gate
            assert st.n_hits == 3 * len(eng.layers)


def test_host_path_syncs_per_layer(fast_engine, monkeypatch):
    """Sanity check for the counter itself: the host-synchronous path
    (device_fast_path=False) blocks at every layer, so the counting
    harness must see it — otherwise the zero-sync assertion above could
    pass vacuously."""
    eng, corpus = fast_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    eng.mc.device_fast_path = False
    try:
        eng.infer({"tokens": toks})
        fake_jax = _CountingModule(jax, ["block_until_ready"])
        monkeypatch.setattr(engine_mod, "jax", fake_jax)
        eng.infer({"tokens": toks})
        assert fake_jax.counts["block_until_ready"] >= 2   # per layer
    finally:
        eng.mc.device_fast_path = None


# ----------------------------------------------------- bucket edge cases

def _select_logits(eng, toks, thr):
    eng.mc.mode = "select"
    try:
        out, _ = eng.infer({"tokens": toks}, threshold=thr)
    finally:
        eng.mc.mode = "bucket"
    return np.asarray(out)


@pytest.mark.parametrize("fast", [True, False])
@pytest.mark.parametrize("thr,expect_rate", [(-1e9, 1.0), (1e9, 0.0)])
def test_bucket_all_hit_and_all_miss_match_select(fast_engine, fast, thr,
                                                  expect_rate):
    eng, corpus = fast_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    ref = _select_logits(eng, toks, thr)
    eng.mc.device_fast_path = fast
    try:
        out, st = eng.infer({"tokens": toks}, threshold=thr)
    finally:
        eng.mc.device_fast_path = None
    assert st.memo_rate == expect_rate
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("fast", [True, False])
def test_bucket_quantum_exceeds_batch(fast_engine, fast):
    """Quantum > B: the host path must clamp pad_to at B (hit_idx.size ==
    B case) and the device path must fall back to one whole-batch
    quantum; numerics match select either way."""
    eng, corpus = fast_engine
    toks = jnp.asarray(corpus.sample(4)[0])
    old_q = eng.mc.bucket_quantum
    eng.mc.bucket_quantum = 16                  # > batch of 4
    eng.mc.device_quanta = 16                   # > batch: whole-batch fall
    eng.mc.device_fast_path = fast
    try:
        ref = _select_logits(eng, toks, -1e9)   # all hit: hit_idx.size == B
        out, st = eng.infer({"tokens": toks}, threshold=-1e9)
        assert st.memo_rate == 1.0
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3,
                                   atol=2e-3)
        mixed_ref = _select_logits(eng, toks, 0.6)
        mixed, _ = eng.infer({"tokens": toks}, threshold=0.6)
        np.testing.assert_allclose(np.asarray(mixed), mixed_ref, rtol=2e-3,
                                   atol=2e-3)
    finally:
        eng.mc.bucket_quantum = old_q
        eng.mc.device_quanta = 1
        eng.mc.device_fast_path = None


@pytest.mark.parametrize("quanta", [1, 2, 4])
def test_bucket_mixed_matches_select_threshold_sweep(fast_engine, quanta):
    """Mixed batches across thresholds and device-quanta granularities
    (whole-batch conditional and hit-first sorted quanta): fast bucket ==
    select numerics."""
    eng, corpus = fast_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    eng.mc.device_quanta = quanta
    try:
        for thr in (0.4, 0.6, 0.8):
            ref = _select_logits(eng, toks, thr)
            out, _ = eng.infer({"tokens": toks}, threshold=thr)
            np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3,
                                       atol=2e-3)
    finally:
        eng.mc.device_quanta = 1


# ------------------------------------------------------------ DeviceIndex

def test_device_index_matches_exact_host_api():
    rng = np.random.default_rng(0)
    db = rng.normal(size=(333, 32)).astype(np.float32)   # N-padding tail
    q = rng.normal(size=(5, 32)).astype(np.float32)      # B < block_q
    exact = ExactIndex(32)
    exact.add(db)
    dev = DeviceIndex(32)
    dev.add(db)
    de, ie = exact.search(q, 1)
    dd, idd = dev.search(q, 1)
    np.testing.assert_array_equal(idd, ie)
    np.testing.assert_allclose(dd, de, rtol=1e-4, atol=1e-4)
    assert len(dev) == 333


def test_device_index_forced_kernel_matches_exact():
    """The Pallas nn_search kernel wired through DeviceIndex (interpret
    mode on CPU) agrees with ExactIndex, incl. the padded DB tail."""
    rng = np.random.default_rng(1)
    db = rng.normal(size=(250, 16)).astype(np.float32)
    q = rng.normal(size=(3, 16)).astype(np.float32)
    exact = ExactIndex(16)
    exact.add(db)
    dev = DeviceIndex(16, use_kernel=True, interpret=True, block_q=16,
                      block_n=64)                        # 250 % 64 != 0
    dev.add(db)
    de, ie = exact.search(q, 1)
    dd, idd = dev.search(q, 1)
    np.testing.assert_array_equal(idd, ie)
    np.testing.assert_allclose(dd, de, rtol=1e-4, atol=1e-4)


def test_device_index_topk_and_growth():
    rng = np.random.default_rng(2)
    dev = DeviceIndex(8)
    exact = ExactIndex(8)
    for chunk in (rng.normal(size=(40, 8)), rng.normal(size=(25, 8))):
        chunk = chunk.astype(np.float32)
        dev.add(chunk)
        exact.add(chunk)
    q = rng.normal(size=(6, 8)).astype(np.float32)
    de, ie = exact.search(q, 3)
    dd, idd = dev.search(q, 3)
    np.testing.assert_array_equal(idd, ie)
    np.testing.assert_allclose(dd, de, rtol=1e-4, atol=1e-4)


def test_device_index_search_device_traceable_in_jit():
    rng = np.random.default_rng(3)
    db = rng.normal(size=(64, 16)).astype(np.float32)
    dev = DeviceIndex(16)
    dev.add(db)

    @jax.jit
    def fused(q, table):
        d2, idx = dev.search_device(q, table=table)
        return jnp.sqrt(jnp.maximum(d2[:, 0], 0.0)), idx[:, 0]

    q = jnp.asarray(rng.normal(size=(7, 16)), jnp.float32)
    d, i = fused(q, dev.table)
    de, ie = ExactIndex(16), None
    de.add(db)
    dist_ref, idx_ref = de.search(np.asarray(q), 1)
    np.testing.assert_array_equal(np.asarray(i), idx_ref[:, 0])
    np.testing.assert_allclose(np.asarray(d), dist_ref[:, 0], rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------- arena capacity math

def test_attention_db_growth_is_geometric_and_tight():
    db = AttentionDB((1, 2, 2), capacity=4)
    apms = np.random.default_rng(0).random((6, 1, 2, 2)).astype(np.float16)
    db.add(apms)                       # 6 > 4 → grow to max(8, 6) = 8
    assert db.capacity == 8
    assert db._arena.shape[0] == db.capacity        # allocation == capacity
    assert db.reuse_counts.shape[0] == db.capacity
    db.add(apms[:2])                   # 8 fits exactly: no growth
    assert db.capacity == 8 and len(db) == 8
    db.add(np.random.default_rng(1).random((9, 1, 2, 2)).astype(np.float16))
    assert db.capacity == max(16, 17) == 17         # tight jump, not 2×+n
    assert db._arena.shape[0] == 17
    # data survives every reallocation
    np.testing.assert_array_equal(db.get(np.arange(6), count_reuse=False),
                                  apms)


def test_attention_db_growth_preserves_reuse_counts():
    db = AttentionDB((1, 2, 2), capacity=2)
    a = np.random.default_rng(3).random((2, 1, 2, 2)).astype(np.float16)
    db.add(a)
    db.get([1, 1])
    db.add(a)                          # forces growth
    assert db.reuse_counts[1] == 2 and db.reuse_counts[0] == 0


# ------------------------------------------------------- device tier sync

def test_engine_resyncs_device_tier_after_db_growth(fast_engine):
    eng, corpus = fast_engine
    toks = jnp.asarray(corpus.sample(4)[0])
    eng.infer({"tokens": toks})
    n0 = len(eng.device_db)
    extra = np.random.default_rng(5).random(
        (3,) + eng.db.apm_shape).astype(np.float16)
    eng.db.add(extra)
    eng.index.add(np.random.default_rng(6).normal(
        size=(3, eng.mc.embed_dim)).astype(np.float32))
    out, _ = eng.infer({"tokens": toks})
    assert len(eng.device_db) == n0 + 3
    assert len(eng.device_index) == len(eng.device_db)
    assert np.isfinite(np.asarray(out)).all()

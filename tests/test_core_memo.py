"""AttMemo core: similarity metric, embedder, indexes, database, engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.database import AttentionDB, DeviceDB
from repro.core.embedding import Embedder, train_embedder
from repro.core.index import ExactIndex, IVFIndex, recall_at_1
from repro.core.similarity import (
    memo_rate, pairwise_similarity, similarity_score)


# ------------------------------------------------------------- similarity

def _rand_apm(key, shape):
    return jax.nn.softmax(jax.random.normal(key, shape), -1)


def test_similarity_identity_and_range():
    a = _rand_apm(jax.random.PRNGKey(0), (4, 16, 16))
    assert float(similarity_score(a, a)) == pytest.approx(1.0, abs=1e-6)
    b = _rand_apm(jax.random.PRNGKey(1), (4, 16, 16))
    s = float(similarity_score(a, b))
    assert 0.0 <= s <= 1.0


@given(seed=st.integers(0, 1000), L=st.integers(2, 24))
@settings(max_examples=20, deadline=None)
def test_similarity_properties(seed, L):
    """Symmetry, [0,1] bounds, and SC(A,A)=1 for arbitrary APMs (Eq. 1)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = _rand_apm(k1, (L, L)), _rand_apm(k2, (L, L))
    sab, sba = float(similarity_score(a, b)), float(similarity_score(b, a))
    assert sab == pytest.approx(sba, abs=1e-6)
    assert -1e-6 <= sab <= 1.0 + 1e-6
    assert float(similarity_score(a, a)) == pytest.approx(1.0, abs=1e-6)


def test_similarity_disjoint_is_zero():
    """Disjoint one-hot rows -> TV distance 1 -> similarity 0."""
    L = 8
    a = jnp.eye(L)
    b = jnp.roll(jnp.eye(L), 1, axis=1)
    assert float(similarity_score(a, b)) == pytest.approx(0.0, abs=1e-6)


def test_batched_similarity_shape():
    a = _rand_apm(jax.random.PRNGKey(2), (3, 2, 8, 8))
    b = _rand_apm(jax.random.PRNGKey(3), (5, 2, 8, 8))
    m = pairwise_similarity(a, b)
    assert m.shape == (3, 5)
    s00 = float(similarity_score(a[0], b[0]))
    assert float(m[0, 0]) == pytest.approx(s00, abs=1e-5)


def test_memo_rate():
    assert memo_rate(42, 10, 12) == pytest.approx(42 / 120)


# -------------------------------------------------------------- embedding

def test_embedder_shapes_and_training_reduces_loss():
    key = jax.random.PRNGKey(0)
    L, H, n = 32, 64, 96
    hiddens = jax.random.normal(key, (n, L, H))
    apms = _rand_apm(jax.random.PRNGKey(1), (n, 2, L, L))
    emb = Embedder.init(key, L, H, pool=8)
    out = emb(hiddens[:5])
    assert out.shape == (5, 128)
    emb2, hist = train_embedder(jax.random.PRNGKey(2), emb, hiddens, apms,
                                steps=60, pair_batch=32)
    assert hist[-1] < hist[0] * 0.8, (hist[0], hist[-1])


# ------------------------------------------------------------------ index

def test_exact_index_topk():
    idx = ExactIndex(16)
    db = np.random.default_rng(0).normal(size=(100, 16)).astype(np.float32)
    idx.add(db)
    d, i = idx.search(db[:7], k=3)
    assert i.shape == (7, 3)
    np.testing.assert_array_equal(i[:, 0], np.arange(7))
    assert (d[:, 0] <= d[:, 1]).all() and (d[:, 1] <= d[:, 2]).all()


def test_ivf_recall_reasonable():
    rng = np.random.default_rng(1)
    # clustered data (ivf's favourable + realistic regime)
    centers = rng.normal(size=(8, 32)) * 5
    db = (centers[rng.integers(0, 8, 600)]
          + rng.normal(size=(600, 32))).astype(np.float32)
    exact = ExactIndex(32)
    exact.add(db)
    ivf = IVFIndex(32, n_lists=8, nprobe=3)
    ivf.add(db)
    q = (centers[rng.integers(0, 8, 50)]
         + rng.normal(size=(50, 32))).astype(np.float32)
    assert recall_at_1(ivf, exact, q) >= 0.9


# --------------------------------------------------------------- database

def test_attention_db_roundtrip_and_growth():
    db = AttentionDB((2, 8, 8), capacity=4)
    apms = np.random.default_rng(0).random((6, 2, 8, 8)).astype(np.float16)
    idx = db.add(apms)                       # forces growth past capacity
    np.testing.assert_array_equal(idx, np.arange(6))
    got = db.get([3, 1, 3])
    np.testing.assert_array_equal(got[0], apms[3])
    np.testing.assert_array_equal(got[1], apms[1])
    assert db.reuse_counts[3] == 2 and db.reuse_counts[1] == 1
    hist = db.reuse_histogram()
    assert hist.sum() == 6


def test_attention_db_naive_matches_arena_gather():
    db = AttentionDB((1, 4, 4), capacity=8)
    apms = np.random.default_rng(2).random((8, 1, 4, 4)).astype(np.float16)
    db.add(apms)
    ids = [5, 0, 5, 7]
    np.testing.assert_array_equal(db.get(ids, count_reuse=False),
                                  db.get_naive(ids))


def test_device_db_gather():
    apms = jnp.asarray(np.random.default_rng(3).random((5, 2, 4, 4)),
                       jnp.float32)
    ddb = DeviceDB(apms)
    out = ddb.gather(jnp.array([4, 0]))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(apms[4]))


# ----------------------------------------------------------------- engine

@pytest.fixture(scope="module")
def tiny_engine():
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256, n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, n_templates=6,
                            slot_fraction=0.2)
    eng = MemoEngine(m, params, MemoSpec.flat(threshold=0.6, embed_steps=40))
    batches = [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)]
    eng.build(jax.random.PRNGKey(1), batches)
    return eng, corpus


def test_engine_build_populates(tiny_engine):
    eng, _ = tiny_engine
    assert len(eng.db) == 3 * 16 * 2          # batches × B × layers
    assert len(eng.index) == len(eng.db)


def test_engine_select_vs_no_memo(tiny_engine):
    eng, corpus = tiny_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    logits_on, st = eng.infer({"tokens": toks})
    logits_off, _ = eng.infer({"tokens": toks}, use_memo=False)
    assert logits_on.shape == logits_off.shape
    assert st.n_layer_attempts == 8 * 2
    # memoized run stays numerically close on high-similarity inputs
    assert np.isfinite(np.asarray(logits_on)).all()


def test_engine_threshold_monotone(tiny_engine):
    """Lower threshold -> memo rate can only grow (paper Fig. 4)."""
    eng, corpus = tiny_engine
    toks = jnp.asarray(corpus.sample(16)[0])
    rates = []
    for thr in (0.95, 0.6, 0.0):
        _, st = eng.infer({"tokens": toks}, threshold=thr)
        rates.append(st.memo_rate)
    assert rates[0] <= rates[1] <= rates[2]
    assert rates[2] == 1.0                     # threshold 0 = all memo


def test_engine_bucket_matches_select(tiny_engine):
    eng, corpus = tiny_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    eng.mc.mode = "select"
    a, _ = eng.infer({"tokens": toks})
    eng.mc.mode = "bucket"
    b, _ = eng.infer({"tokens": toks})
    eng.mc.mode = "select"
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


def test_engine_whisper_encoder_memo():
    """Enc-dec support: whisper's encoder self-attention is memoized (the
    paper's sweet spot — fixed-length bidirectional APMs)."""
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.models import build_model

    cfg = get_reduced("whisper_medium")
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    B, S = 4, 12
    key = jax.random.PRNGKey(1)

    def mkbatch(k):
        return {"frames": jax.random.normal(
                    k, (B, cfg.encoder.n_frames, cfg.encoder.d_model)),
                "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}

    eng = MemoEngine(model, params, MemoSpec.flat(threshold=0.5,
                                               embed_steps=30))
    eng.build(jax.random.PRNGKey(2), [mkbatch(k) for k in
                                      jax.random.split(key, 2)])
    assert eng.layers == list(range(cfg.encoder.n_layers))
    assert len(eng.db) == 2 * B * cfg.encoder.n_layers
    batch = mkbatch(jax.random.PRNGKey(3))
    logits_m, st = eng.infer(batch)
    logits_p, _ = eng.infer(batch, use_memo=False)
    assert logits_m.shape == (B, S, cfg.vocab)
    assert st.n_layer_attempts == B * cfg.encoder.n_layers
    assert np.isfinite(np.asarray(logits_m)).all()
    # threshold 0 memoizes everything
    _, st_all = eng.infer(batch, threshold=-1.0)
    assert st_all.memo_rate == 1.0


def test_distributed_search_multidevice():
    """Device-sharded DB top-1 == exact search (8 fake devices,
    subprocess-isolated)."""
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from contextlib import nullcontext
from repro.core.shard import mesh_search
from repro.kernels.nn_search.ref import nn_search_ref
mesh_kw = {}
if hasattr(jax.sharding, "AxisType"):
    mesh_kw["axis_types"] = (jax.sharding.AxisType.Auto,)
mesh = jax.make_mesh((8,), ("data",), **mesh_kw)
db = jax.random.normal(jax.random.PRNGKey(0), (256, 32))
q = jax.random.normal(jax.random.PRNGKey(1), (17, 32))
ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else nullcontext()
with ctx:
    dbs = jax.device_put(db, NamedSharding(mesh, P("data", None)))
    d, i = jax.jit(lambda a, b: mesh_search(a, b, mesh))(dbs, q)
dr, ir = nn_search_ref(q, db)
np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
np.testing.assert_allclose(np.asarray(d), np.asarray(dr), rtol=1e-4, atol=1e-4)
print("DSEARCH-OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH="src"),
                         cwd=repo, timeout=600)
    assert "DSEARCH-OK" in out.stdout, out.stderr[-2000:]


def test_engine_kernel_mode_matches_select(tiny_engine):
    """'kernel' mode serves hits through the fused Pallas memo_attention
    (device DB, scalar-prefetched gather, interpret on CPU) and must agree
    with the reference select path."""
    eng, corpus = tiny_engine
    toks = jnp.asarray(corpus.sample(8)[0])
    eng.mc.mode = "select"
    a, _ = eng.infer({"tokens": toks}, threshold=0.5)
    eng.mc.mode = "kernel"
    b, st = eng.infer({"tokens": toks}, threshold=0.5)
    eng.mc.mode = "select"
    assert st.n_layer_attempts > 0
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3,
                               atol=3e-3)


def test_engine_hybrid_recurrentgemma():
    """§Arch-applicability: memoization applies to recurrentgemma's 1-in-3
    local-attention layers; RG-LRU layers pass through untouched."""
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("recurrentgemma_2b")      # pattern (rglru, rglru, attn)
    model = build_model(cfg, layer_loop="unroll")
    params = model.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=32, seed=9)
    eng = MemoEngine(model, params, MemoSpec.flat(threshold=0.5,
                                               embed_steps=30))
    assert eng.layers == [2]                     # only the attention layer
    eng.build(jax.random.PRNGKey(1),
              [{"tokens": jnp.asarray(corpus.sample(8)[0])}
               for _ in range(2)])
    toks = jnp.asarray(corpus.sample(8)[0])
    logits_m, st = eng.infer({"tokens": toks}, threshold=-1e9)
    logits_p, _ = eng.infer({"tokens": toks}, use_memo=False)
    assert st.memo_rate == 1.0
    assert logits_m.shape == logits_p.shape
    assert np.isfinite(np.asarray(logits_m)).all()

"""MoE: expert-parallel shard_map path vs dense reference + properties."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod


def _cfg(E=4, k=2, d_ff=64, cf=8.0, chunks=2):
    return get_reduced("dbrx_132b").replace(
        moe=MoEConfig(n_experts=E, top_k=k, d_ff=d_ff,
                      capacity_factor=cf, dispatch_chunks=chunks))


def test_ref_shapes_and_aux():
    cfg = _cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_mod.moe_ref(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 1.0 - 1e-3


def test_router_topk_weights_normalized():
    cfg = _cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    _, w, ids, _ = moe_mod._router(x, params["w_router"], cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert int(jnp.max(ids)) < cfg.moe.n_experts


@given(t=st.integers(2, 17), buckets=st.integers(1, 5),
       cap=st.integers(1, 8), seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_bucketize_property(t, buckets, cap, seed):
    """_bucketize: every kept row lands in a unique (bucket, slot<cap);
    per-bucket keeps == min(count, cap)."""
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, buckets, t), jnp.int32)
    order, ks, pos, keep = moe_mod._bucketize(keys, buckets, cap)
    order, ks, pos, keep = map(np.asarray, (order, ks, pos, keep))
    assert (np.sort(order) == np.arange(t)).all()
    assert (ks == keys[order]).all()
    seen = set()
    for b, p, k in zip(ks, pos, keep):
        if k:
            assert p < cap
            assert (b, p) not in seen
            seen.add((b, p))
    for b in range(buckets):
        cnt = int((keys == b).sum())
        assert int(keep[ks == b].sum()) == min(cnt, cap)


def test_ep_equivalence_multidevice():
    """Run the EP path on a 4x2 fake-device mesh in a subprocess (device
    count is locked at first jax init, so this must be isolated)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import contextlib
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_host_mesh
from repro.models import moe as moe_mod

mesh = make_host_mesh(4, 2)
cfg = get_reduced("dbrx_132b").replace(
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=8.0,
                  dispatch_chunks=2))
params = moe_mod.moe_init(jax.random.PRNGKey(1), cfg)
x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model)) * 0.5
y_ref, _ = moe_mod.moe_ref(params, x, cfg)
set_mesh = getattr(jax, "set_mesh", None)
with (set_mesh(mesh) if set_mesh else contextlib.nullcontext()):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y_ep, _ = jax.jit(lambda p, xx: moe_mod.moe_apply_ep(p, xx, cfg, mesh))(params, xs)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-4)
txt = jax.jit(lambda p, xx: moe_mod.moe_apply_ep(p, xx, cfg, mesh)
              ).lower(params, xs).compile().as_text()
assert "all-to-all" in txt, "EP dispatch must lower to all-to-all"
print("EP-OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "EP-OK" in out.stdout, out.stderr[-3000:]


def test_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop, but the output stays
    finite and within a sane norm of the reference."""
    cfg = _cfg(cf=1.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_ref, _ = moe_mod.moe_ref(params, x, cfg)
    # single-device mesh exercise of the EP code path
    from repro.launch.mesh import make_host_mesh
    import contextlib
    mesh = make_host_mesh(1, 1)
    set_mesh = getattr(jax, "set_mesh", None)
    with (set_mesh(mesh) if set_mesh else contextlib.nullcontext()):
        y_ep, _ = moe_mod.moe_apply_ep(params, x, cfg, mesh)
    assert np.isfinite(np.asarray(y_ep)).all()
    # dropped tokens produce zero expert output -> norm can only shrink
    assert (np.linalg.norm(np.asarray(y_ep))
            <= np.linalg.norm(np.asarray(y_ref)) * 1.05)


def test_ep_small_token_path_equivalence():
    """Decode-time MoE path (replicated tokens, local experts + psum) ==
    dense reference, on a 4x2 fake-device mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import contextlib
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.launch.mesh import make_host_mesh
from repro.models import moe as moe_mod

mesh = make_host_mesh(4, 2)
cfg = get_reduced("dbrx_132b").replace(
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=8.0))
params = moe_mod.moe_init(jax.random.PRNGKey(1), cfg)
# T=6 tokens < 4*dp_size -> the small path triggers
x = jax.random.normal(jax.random.PRNGKey(2), (6, cfg.d_model)) * 0.5
y_ref, _ = moe_mod.moe_ref(params, x, cfg)
set_mesh = getattr(jax, "set_mesh", None)
with (set_mesh(mesh) if set_mesh else contextlib.nullcontext()):
    y_ep, _ = jax.jit(lambda p, xx: moe_mod.moe_apply_ep(p, xx, cfg, mesh))(params, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-4)
print("SMALL-EP-OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "SMALL-EP-OK" in out.stdout, out.stderr[-3000:]

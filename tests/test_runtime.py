"""MemoServer runtime + variable-length serving (ISSUE 4 / DESIGN.md §2.7).

Covers: mask-aware embedding/lookup/logits parity between padded
variable-length batches and unpadded per-length runs, the
zero-per-layer-host-sync invariant under the runtime, async-vs-sync
maintenance equivalence, the bounded jit-shape set, thread-safe stats
accumulation, and the atomic snapshot publish protocol.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core.engine import MemoStats, SimReservoir
from repro.core.runtime import MemoServer, pow2_buckets
from repro.core.store import StoreSnapshot
from repro.models import backbone as bb

SEQ = 32


@pytest.fixture(scope="module")
def vl_engine():
    from repro.configs import get_reduced
    from repro.core.engine import MemoEngine
    from repro.memo import MemoSpec
    from repro.data import TemplateCorpus
    from repro.models import build_model

    cfg = get_reduced("bert_base").replace(n_classes=4, n_layers=2,
                                           d_model=128, d_ff=256, n_heads=4)
    m = build_model(cfg, layer_loop="unroll")
    params = m.init(jax.random.PRNGKey(0))
    corpus = TemplateCorpus(vocab=cfg.vocab, seq_len=SEQ, n_templates=6,
                            slot_fraction=0.2)
    eng = MemoEngine(m, params, MemoSpec.flat(threshold=0.6, embed_steps=40,
                                           mode="bucket", device_slack=8.0))
    eng.build(jax.random.PRNGKey(1),
              [{"tokens": jnp.asarray(corpus.sample(16)[0])}
               for _ in range(3)])
    return eng, corpus


def _varlen_batch(corpus, lens, pad_to):
    toks = np.asarray(corpus.sample(len(lens))[0][:, :pad_to])
    lens = np.asarray(lens, np.int32)
    for i, ln in enumerate(lens):
        toks[i, ln:] = 0
    return toks, lens


# ------------------------------------------- mask-aware padding parity

def test_masked_embedding_parity_padded_vs_unpadded(vl_engine):
    """The same sequence embeds identically whether it arrives padded to
    a bucket or at its exact length — the property that makes the memo
    lookup mask-aware (satellite: embedding parity)."""
    eng, corpus = vl_engine
    cfg = eng.cfg
    lens = [SEQ, SEQ // 2, SEQ - 8, SEQ // 2]
    toks, lens_np = _varlen_batch(corpus, lens, SEQ)
    lp0 = eng._iter_layers()[0][2]
    h = bb.embed_tokens(eng.params, jnp.asarray(toks), cfg)
    x = bb.norm_apply(lp0["norm1"], h, cfg.norm)
    e_pad = np.asarray(eng._embed(x, lengths=lens_np))
    for i, ln in enumerate(lens):
        h_i = bb.embed_tokens(eng.params, jnp.asarray(toks[i:i + 1, :ln]),
                              cfg)
        x_i = bb.norm_apply(lp0["norm1"], h_i, cfg.norm)
        e_i = np.asarray(eng._embed(x_i, lengths=np.asarray([ln])))
        np.testing.assert_allclose(e_pad[i], e_i[0], rtol=1e-5, atol=1e-5)


def test_padded_batch_matches_unpadded_per_length_run(vl_engine):
    """A padded variable-length batch produces the same per-sequence hit
    decisions and logits as running each length group unpadded at its own
    sequence length (acceptance: padded-row APM gather parity)."""
    eng, corpus = vl_engine
    lens = [SEQ, SEQ, SEQ // 2, SEQ // 2]
    toks, lens_np = _varlen_batch(corpus, lens, SEQ)
    batch = {"tokens": jnp.asarray(toks), "lengths": lens_np}
    prep = eng.prepare_batch(batch, threshold=0.6)
    eng.run_layers(prep)
    out_pad, _, _ = eng.finalize(prep)
    hits_pad = np.asarray(jnp.stack([p[2] for p in prep.pend]))  # (L, B)
    out_pad = np.asarray(out_pad)
    for ln in sorted(set(lens)):
        rows = [i for i, x in enumerate(lens) if x == ln]
        sub = {"tokens": jnp.asarray(toks[rows][:, :ln]),
               "lengths": np.full(len(rows), ln, np.int32)}
        prep_u = eng.prepare_batch(sub, threshold=0.6)
        eng.run_layers(prep_u)
        out_u, _, _ = eng.finalize(prep_u)
        hits_u = np.asarray(jnp.stack([p[2] for p in prep_u.pend]))
        np.testing.assert_array_equal(hits_pad[:, rows], hits_u)
        np.testing.assert_allclose(out_pad[rows], np.asarray(out_u),
                                   rtol=2e-3, atol=2e-3)


def test_varlen_fast_path_matches_select(vl_engine):
    """Fast-path logits == select reference on the same padded batch, and
    the length gate forces misses for lengths with no same-length entry
    (the calibration corpus is all full-length)."""
    eng, corpus = vl_engine
    toks, lens_np = _varlen_batch(corpus, [SEQ, SEQ - 4, SEQ // 2, SEQ], SEQ)
    batch = {"tokens": jnp.asarray(toks), "lengths": lens_np}
    out_fast, st = eng.infer(batch, threshold=-1e9)
    eng.mc.mode = "select"
    try:
        out_sel, st_sel = eng.infer(batch, threshold=-1e9)
    finally:
        eng.mc.mode = "bucket"
    np.testing.assert_allclose(np.asarray(out_fast), np.asarray(out_sel),
                               rtol=2e-3, atol=2e-3)
    # threshold −∞ admits everything similarity-wise, so the ONLY misses
    # are length-gate misses: rows 1 and 2 have no same-length entries
    n_layers = len(eng.layers)
    assert st.n_hits == 2 * n_layers
    assert st_sel.n_hits == 2 * n_layers


def test_varlen_admission_learns_new_lengths(vl_engine):
    """Captured misses are admitted at their true length and hit on the
    next same-length batch (the store adapts per length)."""
    eng, corpus = vl_engine
    eng.mc.admit = True
    try:
        toks, lens_np = _varlen_batch(corpus, [SEQ - 8] * 4, SEQ)
        batch = {"tokens": jnp.asarray(toks), "lengths": lens_np}
        _, st1 = eng.infer(batch, threshold=0.6)
        assert st1.n_admitted > 0
        lens_stored = eng.store.entry_lengths(
            np.arange(len(eng.db)))
        assert (lens_stored == SEQ - 8).sum() == st1.n_admitted
        _, st2 = eng.infer(batch, threshold=0.6)
        assert st2.n_hits == len(eng.layers) * 4      # exact replay hits
    finally:
        eng.mc.admit = False


# ------------------------------------------------- runtime invariants

def test_runtime_zero_per_layer_host_sync(vl_engine, monkeypatch):
    """One batch through MemoServer.step issues exactly ONE
    block_until_ready and at most the two stacked stats transfers —
    the fast path's invariant survives the runtime (acceptance)."""
    eng, corpus = vl_engine
    server = MemoServer(eng, buckets=(SEQ // 2, SEQ), max_batch=4,
                        batch_quantum=4, async_maintenance=False)
    server.warmup(batch_sizes=[4])
    for ln in (SEQ, SEQ - 2, SEQ, SEQ):
        server.submit(np.asarray(corpus.sample(1)[0][0, :ln]))
    server.step(flush=True)           # drain a first batch post-warmup
    assert server.queued == 0
    for ln in (SEQ, SEQ - 2, SEQ, SEQ):
        server.submit(np.asarray(corpus.sample(1)[0][0, :ln]))

    class _Counting:
        def __init__(self, real, counted):
            self._real, self.counts = real, {n: 0 for n in counted}
            for n in counted:
                def mk(name, fn=getattr(real, n)):
                    def f(*a, **k):
                        self.counts[name] += 1
                        return fn(*a, **k)
                    return f
                setattr(self, n, mk(n))

        def __getattr__(self, name):
            return getattr(self._real, name)

    fake_jax = _Counting(jax, ["block_until_ready"])
    fake_np = _Counting(np, ["asarray", "nonzero"])
    monkeypatch.setattr(engine_mod, "jax", fake_jax)
    monkeypatch.setattr(engine_mod, "np", fake_np)
    comps = server.step(flush=True)
    assert len(comps) == 4
    assert fake_jax.counts["block_until_ready"] == 1
    assert fake_np.counts["asarray"] <= 2
    assert fake_np.counts["nonzero"] == 0
    server.close()


def test_runtime_bounded_jit_shape_set(vl_engine):
    """Arbitrary request lengths compile at most
    len(buckets) x len(row-paddings) fused shapes per layer kind."""
    eng, corpus = vl_engine
    server = MemoServer(eng, buckets=(SEQ // 2, SEQ), max_batch=4,
                        batch_quantum=2, async_maintenance=False)
    rng = np.random.default_rng(3)
    for _ in range(6):
        for __ in range(int(rng.integers(1, 5))):
            ln = int(rng.integers(4, SEQ + 1))
            server.submit(np.asarray(corpus.sample(1)[0][0, :ln]))
        server.step(flush=True)
    fused_shapes = {k[4] for k in eng._jit_cache
                    if isinstance(k, tuple) and k[0] == "fused" and k[-1]}
    # buckets {16, 32} x row paddings {2, 4} = 4 shapes max
    assert len(fused_shapes) <= 4
    server.close()


def test_runtime_async_matches_sync_serving(vl_engine):
    """With maintenance idle (no admission), async and sync runtimes are
    the same serving machine: identical logits for identical requests."""
    eng, corpus = vl_engine
    reqs = [np.asarray(corpus.sample(1)[0][0, :ln])
            for ln in (SEQ, SEQ - 4, SEQ // 2, SEQ)]
    outs = {}
    for mode in (False, True):
        server = MemoServer(eng, buckets=(SEQ // 2, SEQ), max_batch=4,
                            async_maintenance=mode)
        with server:
            for r in reqs:
                server.submit(r)
            comps = []
            while server.queued:
                comps.extend(server.step(flush=True))
        outs[mode] = {c.rid: c.logits for c in comps}
    assert outs[False].keys() == outs[True].keys()
    for rid in outs[False]:
        np.testing.assert_allclose(outs[False][rid], outs[True][rid],
                                   rtol=1e-5, atol=1e-5)


def test_runtime_async_maintenance_applies_and_publishes(vl_engine):
    """Async mode: admissions queued by finalize are applied off-thread;
    after drain the snapshot generation advanced atomically and a repeat
    batch hits on the admitted entries."""
    eng, corpus = vl_engine
    eng.mc.admit = True
    try:
        gen0 = eng.store.snapshot.generation
        n0 = eng.store.stats.n_admitted
        server = MemoServer(eng, buckets=(SEQ // 2, SEQ), max_batch=4,
                            async_maintenance=True)
        toks = [np.asarray(corpus.sample(1)[0][0, :SEQ - 12])
                for _ in range(4)]
        with server:
            for t in toks:
                server.submit(t)
            server.step(flush=True)
            server.drain_maintenance()
            snap = eng.store.snapshot
            assert isinstance(snap, StoreSnapshot)
            assert snap.generation > gen0
            assert eng.store.stats.n_admitted > n0
            for t in toks:                      # same requests again
                server.submit(t)
            comps = server.step(flush=True)
        hit_counts = server.stats.n_hits
        assert len(comps) == 4
        assert hit_counts >= len(eng.layers) * 4   # second pass all hit
        assert not server.maintenance_errors
    finally:
        eng.mc.admit = False


def test_fixed_length_queries_never_replay_shorter_entries(vl_engine):
    """The length gate is ALWAYS on: a fixed-length batch (no lengths)
    must not hit an entry admitted at a shorter true length — its APM
    rows past that length are hard zeros, so replaying it would silently
    zero the query's tail attention."""
    eng, corpus = vl_engine
    store = eng.store
    toks = jnp.asarray(corpus.sample(4)[0])
    # poison the store: entries whose embeddings EXACTLY match this
    # batch's layer-0 fixed-length embeddings, but stored at length 10
    lp0 = eng._iter_layers()[0][2]
    h = bb.embed_tokens(eng.params, toks, eng.cfg)
    x = bb.norm_apply(lp0["norm1"], h, eng.cfg.norm)
    embs = np.asarray(eng._embed(x))
    apms = np.zeros((4,) + store.apm_shape, np.float16)
    store.admit(apms, embs, lengths=np.full(4, 10, np.int32))
    store.sync()
    out, st = eng.infer({"tokens": toks}, threshold=-1e9)
    # layer 0's top-1 is the distance-0 poisoned entry — without the
    # gate all 4 rows would hit it; with it they are length-gated misses
    assert st.per_layer_hits.get(eng.layers[0], 0) == 0
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------- thread-safe stats

def test_sim_reservoir_concurrent_append_is_lossless():
    res = SimReservoir(cap=128)
    n_threads, per = 8, 500

    def work(seed):
        for i in range(per):
            res.append(float(seed * per + i))

    ts = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert res.seen == n_threads * per
    assert len(res) == 128


def test_memostats_concurrent_merge():
    total = MemoStats()
    n_threads, per = 6, 50

    def work():
        for _ in range(per):
            st = MemoStats(n_layer_attempts=4, n_hits=2,
                           per_layer_hits={0: 1, 1: 1})
            st.sims.extend([0.5, 0.6])
            total.merge(st)
            total.add_admitted(1)

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    n = n_threads * per
    assert total.n_layer_attempts == 4 * n
    assert total.n_hits == 2 * n
    assert total.n_admitted == n
    assert total.per_layer_hits == {0: n, 1: n}
    assert total.sims.seen == 2 * n


# ----------------------------------------------- snapshot publication

def test_snapshot_is_stable_until_next_sync(vl_engine):
    """The published snapshot is immutable: host-tier mutation does not
    change it until the next sync commits a new generation — in-flight
    batches keep serving the arrays they captured."""
    eng, _ = vl_engine
    store = eng.store
    store.sync()
    snap = store.snapshot
    apms = np.random.default_rng(5).random(
        (2,) + store.apm_shape).astype(np.float16)
    embs = np.random.default_rng(6).normal(
        size=(2, store.embed_dim)).astype(np.float32)
    store.admit(apms, embs, lengths=np.asarray([7, 9], np.int32))
    assert store.snapshot is snap                 # not yet published
    assert store.device_stale
    store.sync()
    snap2 = store.snapshot
    assert snap2 is not snap
    assert snap2.generation > snap.generation
    # the superseded snapshot's arrays are still alive and consistent
    assert snap.db_parts[0].shape == snap2.db_parts[0].shape


def test_pow2_buckets():
    assert pow2_buckets(64) == (16, 32, 64)
    assert pow2_buckets(32, n=2) == (16, 32)
    assert pow2_buckets(8) == (8,)
